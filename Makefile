# DARKFormer build/verify entry points.
#
# `make verify` = tier-1 (build + tests, default features: the pure-Rust
# theory stack, no artifacts needed) plus formatting and lint gates.
#
# PJRT-dependent targets (the `darkformer` binary, integration tests, the
# coordinator/fig1 benches) need `--features pjrt`; they are excluded from
# tier-1 and skip gracefully when AOT artifacts are absent.

CARGO ?= cargo

.PHONY: verify build test lint fmt clippy chaos bench bench-json \
	bench-serving bench-diff bench-baseline pjrt-check clean

verify: build test lint

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

lint: fmt clippy

# Fault-injection suite for rfa::serve (rust/tests/rfa_chaos.rs), run at
# both ends of the SIMD dispatch — chaos schedules, quarantine membership
# and post-heal bitwise recovery must be ISA-independent — again at
# full observability verbosity: max-verbosity telemetry must not change
# one bit of any chaos outcome (the rfa::obs write-only rule), with the
# obs suite itself (rust/tests/rfa_obs.rs) pinning that contract
# directly — and once more with aggressive online resampling + frozen-
# epoch compaction, so fault injection covers the epoch state machine
# (maintained Cholesky factor, frozen ring, merge counter) through
# eviction, fault-in, quarantine and replay.
chaos:
	$(CARGO) test -q --test rfa_chaos
	RFA_SIMD=scalar $(CARGO) test -q --test rfa_chaos
	RFA_OBS=full $(CARGO) test -q --test rfa_chaos
	RFA_CHAOS_RESAMPLE=aggressive $(CARGO) test -q --test rfa_chaos
	$(CARGO) test -q --test rfa_obs

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Offline-runnable benches (no artifacts required). Each writes
# BENCH_<name>.json next to the stdout table (override with BENCH_OUT_DIR).
bench:
	$(CARGO) bench --bench variance
	$(CARGO) bench --bench linear_attention
	$(CARGO) bench --bench multihead
	$(CARGO) bench --bench serving
	$(CARGO) bench --bench substrates

bench-json: bench
	@ls -l BENCH_*.json 2>/dev/null || true

# Serving-layer throughput only (tokens/sec over concurrent sessions,
# thread scaling, eviction-churn cost) — writes BENCH_serving.json.
bench-serving:
	$(CARGO) bench --bench serving

# Compare the working tree's BENCH_*.json against the committed baseline
# (benches/baseline/); prints per-case and per-metric deltas so perf
# regressions are visible in review. Run `make bench` first.
bench-diff:
	$(CARGO) run --release --bin bench_diff

# Regenerate the committed baseline snapshots in benches/baseline/.
bench-baseline:
	BENCH_OUT_DIR=benches/baseline $(MAKE) bench

# Compile check for the PJRT-gated stack (links the vendored xla stub;
# executing artifacts additionally needs the real xla bindings).
pjrt-check:
	$(CARGO) build --release --features pjrt

clean:
	$(CARGO) clean
	rm -f BENCH_*.json
