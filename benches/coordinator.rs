//! Coordinator hot-path bench: train/eval step latency per attention
//! variant on the tiny artifacts, plus the host-side costs around them
//! (state literal conversion, checkpoint I/O).
//!
//! Run: `cargo bench --bench coordinator` (needs `make artifacts`).

use darkformer::bench::bench;
use darkformer::config::ExperimentConfig;
use darkformer::coordinator::{Trainer, Workbench};
use darkformer::rng::Pcg64;

fn main() {
    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("tiny").exists() {
        eprintln!("skipping coordinator bench: run `make artifacts` first");
        return;
    }
    let cache = std::path::PathBuf::from("runs/bench/_cache");
    let wb = Workbench::prepare(&artifacts, "tiny", 400, 42, &cache)
        .expect("workbench");
    let mut rng = Pcg64::seed(5);

    println!("== per-variant train/eval step latency (tiny) ==");
    for variant in ["exact", "performer", "darkformer", "lfk"] {
        let cfg = ExperimentConfig {
            variant: variant.into(),
            model_config: "tiny".into(),
            out_dir: format!("runs/bench/{variant}").into(),
            ..Default::default()
        };
        let trainer = match Trainer::new(cfg, &wb) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("  {variant}: {e:#}");
                continue;
            }
        };
        let mut state = trainer.initial_state().expect("init");
        let batch = wb.dataset.train_batch(wb.meta.batch_size, &mut rng);
        // Warm the executable, then time.
        bench(&format!("train_step/{variant}"), 2, 10, || {
            trainer
                .train_step(&mut state, &batch, rng.clone().next_u32(), 1e-3)
                .expect("step");
        });
        bench(&format!("eval/{variant}/4batches"), 1, 5, || {
            trainer.evaluate(&state, 4).expect("eval");
        });
    }

    println!("\n== host-side costs ==");
    let cfg = ExperimentConfig {
        variant: "darkformer".into(),
        model_config: "tiny".into(),
        out_dir: "runs/bench/host".into(),
        ..Default::default()
    };
    let trainer = Trainer::new(cfg, &wb).expect("trainer");
    let state = trainer.initial_state().expect("init");
    bench("host/state_to_literals", 2, 20, || {
        std::hint::black_box(state.state_literals().expect("literals"));
    });
    let ckpt_path = std::path::PathBuf::from("runs/bench/host/ck.dkft");
    bench("host/checkpoint_save", 1, 10, || {
        state.save(&ckpt_path).expect("save");
    });
    bench("host/checkpoint_load", 1, 10, || {
        std::hint::black_box(
            darkformer::checkpoint::Checkpoint::load(&ckpt_path).expect("load"),
        );
    });
    bench("host/train_batch_sample", 2, 50, || {
        std::hint::black_box(
            wb.dataset.train_batch(wb.meta.batch_size, &mut rng),
        );
    });
}
