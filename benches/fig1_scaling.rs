//! Fig. 1 bench: exact O(L^2 d) vs PRF O(L m d) attention wall-clock
//! across sequence lengths, using the AOT attention probes.
//!
//! Run: `cargo bench --bench fig1_scaling` (needs `make artifacts`).

use darkformer::bench::bench;
use darkformer::rng::Pcg64;
use darkformer::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts/scaling");
    if !dir.exists() {
        eprintln!("skipping fig1_scaling: run `make artifacts` first");
        return;
    }
    let runtime = Runtime::cpu().expect("PJRT cpu client");
    let mut rng = Pcg64::seed(11);
    let (h, dh) = (4usize, 32usize);

    println!("== Fig 1: attention latency vs sequence length ==");
    let mut rows = Vec::new();
    for l in [64usize, 128, 256, 512, 1024] {
        let mut pair = Vec::new();
        for variant in ["exact", "performer"] {
            let path = dir.join(format!("attn_{variant}_L{l}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            let program = runtime.load_program(&path).expect("load probe");
            let n = h * l * dh;
            let data: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let mk = || {
                xla::Literal::vec1(&data)
                    .reshape(&[1, h as i64, l as i64, dh as i64])
                    .unwrap()
            };
            let (q, k, v) = (mk(), mk(), mk());
            let seed = xla::Literal::scalar(3u32);
            let result = bench(&format!("attn/{variant}/L{l}"), 2, 8, || {
                program
                    .run(&[&q, &k, &v, &seed].map(Clone::clone))
                    .expect("probe run");
            });
            pair.push(result.mean_ms);
        }
        if pair.len() == 2 {
            rows.push((l, pair[0], pair[1]));
        }
    }
    println!("\n{:>8} {:>12} {:>12} {:>9}", "L", "exact ms", "prf ms", "ratio");
    for (l, e, p) in &rows {
        println!("{l:>8} {e:>12.3} {p:>12.3} {:>8.2}x", e / p);
    }
    // The paper's shape claim: the exact/PRF ratio must grow with L.
    if rows.len() >= 2 {
        let first = rows.first().unwrap();
        let last = rows.last().unwrap();
        let r0 = first.1 / first.2;
        let r1 = last.1 / last.2;
        println!(
            "\nratio growth {:.2}x -> {:.2}x across L={}..{} ({})",
            r0,
            r1,
            first.0,
            last.0,
            if r1 > r0 {
                "linear-attention advantage grows: OK"
            } else {
                "UNEXPECTED"
            }
        );
    }
}
