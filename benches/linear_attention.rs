//! Linear-attention scaling bench: exact softmax O(L²d) vs pure-Rust PRF
//! linear attention O(L·m·d), causal and non-causal, isotropic
//! (Performer) and data-aware (DARKFormer) banks, L ∈ {64..2048}.
//!
//! Prints the per-L latency table, checks the PRF forward against the
//! exact reference at a moderate L, fits the log-log scaling exponent of
//! the causal PRF path, and emits `BENCH_linear_attention.json`.
//!
//! Run: `cargo bench --bench linear_attention`.

use darkformer::bench::BenchSuite;
use darkformer::linalg::Matrix;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{attention, FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn main() {
    let d = 16;
    let dv = 16;
    let m = 64;
    let mut rng = Pcg64::seed(21);
    let mut suite = BenchSuite::new("linear_attention");

    let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
    let sigma = anisotropic_covariance(d, 0.8, 0.5, &mut rng);
    let dark = PrfEstimator::new(
        d,
        m,
        Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
    );
    let iso_bank = FeatureBank::draw(&iso, &mut rng);
    let dark_bank = FeatureBank::draw(&dark, &mut rng);

    // Agreement check first: the linear path must track exact softmax.
    {
        let l = 128;
        let big = PrfEstimator::new(d, 1024, Sampling::Isotropic);
        let big_bank = FeatureBank::draw(&big, &mut rng);
        let q = rows(l, d, 0.15, &mut rng);
        let k = rows(l, d, 0.15, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let qm = Matrix::from_rows(&q);
        let km = Matrix::from_rows(&k);
        let exact = attention::softmax_attention(&qm, &km, &v, true);
        let approx = attention::prf_attention(&big_bank, &q, &k, &v, true);
        let err = approx.max_abs_diff(&exact);
        println!("causal agreement at L={l}, m=1024: max |Δ| = {err:.4}");
        suite.metric("causal_max_abs_err_L128_m1024", err);
        if err > 0.25 {
            println!("UNEXPECTED: PRF attention drifted from exact reference");
        }
    }

    println!(
        "\n{:>6} {:>12} {:>14} {:>14} {:>16} {:>10}",
        "L", "exact ms", "prf ms", "prf-causal ms", "dark-causal ms", "speedup"
    );
    let seq_lens = [64usize, 128, 256, 512, 1024, 2048];
    let mut causal_times: Vec<(usize, f64)> = Vec::new();
    let mut exact_times: Vec<(usize, f64)> = Vec::new();
    for &l in &seq_lens {
        let q = rows(l, d, 0.15, &mut rng);
        let k = rows(l, d, 0.15, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let qm = Matrix::from_rows(&q);
        let km = Matrix::from_rows(&k);
        let iters = if l >= 1024 { 3 } else { 8 };

        let exact_ms = suite.bench(&format!("exact/L{l}"), 1, iters, || {
            std::hint::black_box(attention::softmax_attention(
                &qm, &km, &v, true,
            ));
        });
        let prf_ms = suite.bench(&format!("prf/L{l}"), 1, iters, || {
            std::hint::black_box(attention::prf_attention(
                &iso_bank, &q, &k, &v, false,
            ));
        });
        let causal_ms =
            suite.bench(&format!("prf_causal/L{l}"), 1, iters, || {
                std::hint::black_box(attention::prf_attention(
                    &iso_bank, &q, &k, &v, true,
                ));
            });
        let dark_ms =
            suite.bench(&format!("dark_causal/L{l}"), 1, iters, || {
                std::hint::black_box(attention::prf_attention(
                    &dark_bank, &q, &k, &v, true,
                ));
            });
        println!(
            "{:>6} {:>12.3} {:>14.3} {:>14.3} {:>16.3} {:>9.2}x",
            l,
            exact_ms,
            prf_ms,
            causal_ms,
            dark_ms,
            exact_ms / causal_ms
        );
        causal_times.push((l, causal_ms));
        exact_times.push((l, exact_ms));
    }

    // Log-log scaling exponents over the grid: linear attention must stay
    // sub-quadratic (≈1), exact softmax trends to 2.
    let slope = |times: &[(usize, f64)]| {
        let (l0, t0) = times.first().copied().unwrap();
        let (l1, t1) = times.last().copied().unwrap();
        (t1 / t0).ln() / (l1 as f64 / l0 as f64).ln()
    };
    let causal_slope = slope(&causal_times);
    let exact_slope = slope(&exact_times);
    println!(
        "\nscaling exponent (log-log, L={}..{}): prf-causal {:.2}, exact {:.2} {}",
        seq_lens[0],
        seq_lens[seq_lens.len() - 1],
        causal_slope,
        exact_slope,
        if causal_slope < 1.7 {
            "(sub-quadratic: OK)"
        } else {
            "(UNEXPECTED: not sub-quadratic)"
        }
    );
    suite.metric("causal_prf_scaling_exponent", causal_slope);
    suite.metric("exact_scaling_exponent", exact_slope);
    suite.metric(
        "speedup_at_L2048",
        exact_times.last().unwrap().1 / causal_times.last().unwrap().1,
    );

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
