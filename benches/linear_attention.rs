//! Linear-attention scaling bench: exact softmax O(L²d) vs pure-Rust PRF
//! linear attention O(L·m·d), causal and non-causal, isotropic
//! (Performer) and data-aware (DARKFormer) banks, L ∈ {64..2048}, plus
//! the chunked-engine long-sequence section at L=131072 (per-position vs
//! chunk-blocked f64 vs chunk-blocked f32 on shared feature matrices).
//!
//! Prints the per-L latency table, checks the PRF forward against the
//! exact reference at a moderate L, fits the log-log scaling exponent of
//! the causal PRF path, and emits `BENCH_linear_attention.json` with the
//! headline metrics `chunked_vs_perpos_causal_speedup_L131072` and
//! `f32_vs_f64_chunked_throughput_L131072`.
//!
//! Run: `cargo bench --bench linear_attention`.

use darkformer::bench::BenchSuite;
use darkformer::linalg::{simd, Matrix, Matrix32};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{attention, engine, FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn main() {
    let d = 16;
    let dv = 16;
    let m = 64;
    let mut rng = Pcg64::seed(21);
    let mut suite = BenchSuite::new("linear_attention");

    let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
    let sigma = anisotropic_covariance(d, 0.8, 0.5, &mut rng);
    let dark = PrfEstimator::new(
        d,
        m,
        Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
    );
    let iso_bank = FeatureBank::draw(&iso, &mut rng);
    let dark_bank = FeatureBank::draw(&dark, &mut rng);

    // Agreement check first: the linear path must track exact softmax.
    {
        let l = 128;
        let big = PrfEstimator::new(d, 1024, Sampling::Isotropic);
        let big_bank = FeatureBank::draw(&big, &mut rng);
        let q = rows(l, d, 0.15, &mut rng);
        let k = rows(l, d, 0.15, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let qm = Matrix::from_rows(&q);
        let km = Matrix::from_rows(&k);
        let exact = attention::softmax_attention(&qm, &km, &v, true);
        let approx = attention::prf_attention(&big_bank, &q, &k, &v, true);
        let err = approx.max_abs_diff(&exact);
        println!("causal agreement at L={l}, m=1024: max |Δ| = {err:.4}");
        suite.metric("causal_max_abs_err_L128_m1024", err);
        if err > 0.25 {
            println!("UNEXPECTED: PRF attention drifted from exact reference");
        }
    }

    println!(
        "\n{:>6} {:>12} {:>14} {:>14} {:>16} {:>10}",
        "L", "exact ms", "prf ms", "prf-causal ms", "dark-causal ms", "speedup"
    );
    let seq_lens = [64usize, 128, 256, 512, 1024, 2048];
    let mut causal_times: Vec<(usize, f64)> = Vec::new();
    let mut exact_times: Vec<(usize, f64)> = Vec::new();
    for &l in &seq_lens {
        let q = rows(l, d, 0.15, &mut rng);
        let k = rows(l, d, 0.15, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let qm = Matrix::from_rows(&q);
        let km = Matrix::from_rows(&k);
        let iters = if l >= 1024 { 3 } else { 8 };

        let exact_ms = suite.bench(&format!("exact/L{l}"), 1, iters, || {
            std::hint::black_box(attention::softmax_attention(
                &qm, &km, &v, true,
            ));
        });
        let prf_ms = suite.bench(&format!("prf/L{l}"), 1, iters, || {
            std::hint::black_box(attention::prf_attention(
                &iso_bank, &q, &k, &v, false,
            ));
        });
        let causal_ms =
            suite.bench(&format!("prf_causal/L{l}"), 1, iters, || {
                std::hint::black_box(attention::prf_attention(
                    &iso_bank, &q, &k, &v, true,
                ));
            });
        let dark_ms =
            suite.bench(&format!("dark_causal/L{l}"), 1, iters, || {
                std::hint::black_box(attention::prf_attention(
                    &dark_bank, &q, &k, &v, true,
                ));
            });
        println!(
            "{:>6} {:>12.3} {:>14.3} {:>14.3} {:>16.3} {:>9.2}x",
            l,
            exact_ms,
            prf_ms,
            causal_ms,
            dark_ms,
            exact_ms / causal_ms
        );
        causal_times.push((l, causal_ms));
        exact_times.push((l, exact_ms));
    }

    // Log-log scaling exponents over the grid: linear attention must stay
    // sub-quadratic (≈1), exact softmax trends to 2.
    let slope = |times: &[(usize, f64)]| {
        let (l0, t0) = times.first().copied().unwrap();
        let (l1, t1) = times.last().copied().unwrap();
        (t1 / t0).ln() / (l1 as f64 / l0 as f64).ln()
    };
    let causal_slope = slope(&causal_times);
    let exact_slope = slope(&exact_times);
    println!(
        "\nscaling exponent (log-log, L={}..{}): prf-causal {:.2}, exact {:.2} {}",
        seq_lens[0],
        seq_lens[seq_lens.len() - 1],
        causal_slope,
        exact_slope,
        if causal_slope < 1.7 {
            "(sub-quadratic: OK)"
        } else {
            "(UNEXPECTED: not sub-quadratic)"
        }
    );
    suite.metric("causal_prf_scaling_exponent", causal_slope);
    suite.metric("exact_scaling_exponent", exact_slope);
    suite.metric(
        "speedup_at_L2048",
        exact_times.last().unwrap().1 / causal_times.last().unwrap().1,
    );

    // ----------------------------------------------------------------
    // Long-sequence chunked-engine section: L=131072 single-head, on
    // shared precomputed feature matrices so the comparison isolates the
    // causal forward itself (per-position loop vs chunk-blocked engine,
    // f64 vs f32).
    // ----------------------------------------------------------------
    {
        let l = 131072usize;
        let chunk = 32usize;
        println!("\nlong-sequence causal engine, L={l}, m={m}, chunk={chunk}");
        let q = rows(l, d, 0.1, &mut rng);
        let k = rows(l, d, 0.1, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let phi_q = iso_bank.feature_matrix(&q);
        let phi_k = iso_bank.feature_matrix(&k);
        let phi_q32 = iso_bank.feature_matrix32(&q);
        let phi_k32 = iso_bank.feature_matrix32(&k);
        let v32 = Matrix32::from_f64(&v);

        let perpos_ms = suite.bench("causal_perpos_f64/L131072", 1, 3, || {
            std::hint::black_box(attention::causal_linear_attention(
                &phi_q, &phi_k, &v,
            ));
        });
        let chunked_ms =
            suite.bench("causal_chunked_f64/L131072", 1, 3, || {
                std::hint::black_box(engine::chunked_causal_linear_attention(
                    &phi_q, &phi_k, &v, chunk,
                ));
            });
        let chunked32_ms =
            suite.bench("causal_chunked_f32/L131072", 1, 3, || {
                std::hint::black_box(
                    engine::chunked_causal_linear_attention32(
                        &phi_q32, &phi_k32, &v32, chunk,
                    ),
                );
            });

        // Sanity: the three paths compute the same estimator.
        let ref64 = engine::chunked_causal_linear_attention(
            &phi_q, &phi_k, &v, chunk,
        );
        let diff32 = ref64.max_abs_diff(
            &engine::chunked_causal_linear_attention32(
                &phi_q32, &phi_k32, &v32, chunk,
            )
            .to_f64(),
        );
        println!("f32-vs-f64 chunked max |Δ| at L={l}: {diff32:.2e}");
        suite.metric("f32_vs_f64_chunked_max_abs_err_L131072", diff32);

        let speedup = perpos_ms / chunked_ms;
        let f32_throughput = chunked_ms / chunked32_ms;
        println!(
            "chunked-vs-per-position speedup: {speedup:.2}x {}",
            if speedup >= 2.0 { "(>=2x: OK)" } else { "(UNEXPECTED: <2x)" }
        );
        println!("f32-vs-f64 chunked throughput: {f32_throughput:.2}x");
        suite.metric("chunked_vs_perpos_causal_speedup_L131072", speedup);
        suite.metric("f32_vs_f64_chunked_throughput_L131072", f32_throughput);
    }

    // ----------------------------------------------------------------
    // SIMD dispatch A/B: the same chunked causal forward under the
    // forced-scalar fallback vs the dispatched kernels, both precisions.
    // Every ISA is bitwise-identical to the fallback, so the outputs are
    // asserted equal and the only delta is throughput.
    // ----------------------------------------------------------------
    {
        let l = 8192usize;
        let chunk = 32usize;
        println!("\nsimd-vs-scalar dispatch A/B, L={l}, m={m}, chunk={chunk}");
        let q = rows(l, d, 0.1, &mut rng);
        let k = rows(l, d, 0.1, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 0.5, &mut rng));
        let phi_q = iso_bank.feature_matrix(&q);
        let phi_k = iso_bank.feature_matrix(&k);
        let phi_q32 = iso_bank.feature_matrix32(&q);
        let phi_k32 = iso_bank.feature_matrix32(&k);
        let v32 = Matrix32::from_f64(&v);

        let prev = simd::set_isa(simd::Isa::Scalar);
        let scalar64_ms =
            suite.bench("causal_chunked_f64_scalar_kernels/L8192", 1, 5, || {
                std::hint::black_box(engine::chunked_causal_linear_attention(
                    &phi_q, &phi_k, &v, chunk,
                ));
            });
        let scalar32_ms =
            suite.bench("causal_chunked_f32_scalar_kernels/L8192", 1, 5, || {
                std::hint::black_box(
                    engine::chunked_causal_linear_attention32(
                        &phi_q32, &phi_k32, &v32, chunk,
                    ),
                );
            });
        let out_scalar =
            engine::chunked_causal_linear_attention(&phi_q, &phi_k, &v, chunk);
        simd::set_isa(prev);

        let simd64_ms =
            suite.bench("causal_chunked_f64_simd_kernels/L8192", 1, 5, || {
                std::hint::black_box(engine::chunked_causal_linear_attention(
                    &phi_q, &phi_k, &v, chunk,
                ));
            });
        let simd32_ms =
            suite.bench("causal_chunked_f32_simd_kernels/L8192", 1, 5, || {
                std::hint::black_box(
                    engine::chunked_causal_linear_attention32(
                        &phi_q32, &phi_k32, &v32, chunk,
                    ),
                );
            });
        let out_simd =
            engine::chunked_causal_linear_attention(&phi_q, &phi_k, &v, chunk);
        assert_eq!(
            out_scalar.data(),
            out_simd.data(),
            "dispatched kernels must be bitwise-identical to the fallback"
        );

        let speedup64 = scalar64_ms / simd64_ms;
        let speedup32 = scalar32_ms / simd32_ms;
        println!(
            "simd-vs-scalar chunked speedup ({}): f64 {:.2}x, f32 {:.2}x",
            simd::active_isa(),
            speedup64,
            speedup32
        );
        suite.metric("simd_vs_scalar_chunked_f64_L8192", speedup64);
        suite.metric("simd_vs_scalar_chunked_f32_L8192", speedup32);
    }
    suite.metric_str("active_isa", simd::active_isa());

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
