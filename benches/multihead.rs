//! Multi-head chunked-attention bench: heads ∈ {1, 4, 8} × L ∈ {1k, 8k,
//! 64k} on the f32 engine hot path, plus a threads-vs-heads scaling
//! probe (same 8-head workload on 1 worker vs all cores).
//!
//! Emits `BENCH_multihead.json`; the headline metric
//! `threads_vs_heads_scaling_h8_L8192` is the wall-clock ratio
//! single-worker / all-cores for 8 heads at L=8192 (ideal = min(8,
//! cores)), and `h8_over_h1_wallclock_L8192` shows how close 8 parallel
//! heads come to single-head latency.
//!
//! Run: `cargo bench --bench multihead`.

use darkformer::bench::BenchSuite;
use darkformer::linalg::Matrix;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::{engine, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn make_heads(
    n_heads: usize,
    l: usize,
    d: usize,
    dv: usize,
    rng: &mut Pcg64,
) -> Vec<engine::Head> {
    (0..n_heads)
        .map(|_| engine::Head {
            q: rows(l, d, 0.1, rng),
            k: rows(l, d, 0.1, rng),
            v: Matrix::from_rows(&rows(l, dv, 0.5, rng)),
        })
        .collect()
}

fn main() {
    let (d, dv, m, chunk) = (16usize, 16usize, 32usize, 32usize);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let mut rng = Pcg64::seed(0x6ead5);
    let mut suite = BenchSuite::new("multihead");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    suite.metric("available_cores", cores as f64);

    println!(
        "multi-head chunked f32 engine: d={d} dv={dv} m={m} chunk={chunk} \
         cores={cores}\n"
    );
    let head_counts = [1usize, 4, 8];
    let seq_lens = [1024usize, 8192, 65536];
    let mut grid: Vec<(usize, usize, f64)> = Vec::new();
    for &l in &seq_lens {
        for &h in &head_counts {
            let banks = engine::draw_head_banks(&est, h, &mut Pcg64::seed(7));
            let heads = make_heads(h, l, d, dv, &mut rng);
            let cfg = engine::EngineConfig { chunk, threads: 0 };
            let iters = if l >= 65536 { 2 } else { 4 };
            let ms =
                suite.bench(&format!("mh32/h{h}/L{l}"), 1, iters, || {
                    std::hint::black_box(
                        engine::multi_head_causal_attention32(
                            &banks, &heads, &cfg,
                        ),
                    );
                });
            grid.push((h, l, ms));
        }
    }

    // Threads-vs-heads scaling: identical 8-head workload, 1 worker vs
    // all cores. Head-order reduction makes the outputs identical; only
    // the wall clock moves.
    {
        let (h, l) = (8usize, 8192usize);
        let banks = engine::draw_head_banks(&est, h, &mut Pcg64::seed(7));
        let heads = make_heads(h, l, d, dv, &mut rng);
        let t1 = suite.bench("mh32/h8/L8192/threads1", 1, 3, || {
            let cfg = engine::EngineConfig { chunk, threads: 1 };
            std::hint::black_box(engine::multi_head_causal_attention32(
                &banks, &heads, &cfg,
            ));
        });
        let tall = suite.bench("mh32/h8/L8192/threads_all", 1, 3, || {
            let cfg = engine::EngineConfig { chunk, threads: 0 };
            std::hint::black_box(engine::multi_head_causal_attention32(
                &banks, &heads, &cfg,
            ));
        });
        let scaling = t1 / tall;
        println!(
            "\nthreads-vs-heads scaling (h=8, L=8192): {scaling:.2}x \
             across {cores} cores"
        );
        suite.metric("threads_vs_heads_scaling_h8_L8192", scaling);
    }

    // How close is 8-head wall clock to 1-head at the same L (ideal 1.0
    // with >= 8 free cores)?
    let at = |h: usize, l: usize| {
        grid.iter().find(|g| g.0 == h && g.1 == l).map(|g| g.2).unwrap()
    };
    suite.metric("h8_over_h1_wallclock_L8192", at(8, 8192) / at(1, 8192));
    suite.metric("h8_over_h1_wallclock_L65536", at(8, 65536) / at(1, 65536));
    suite.metric_str("active_isa", darkformer::linalg::simd::active_isa());

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
