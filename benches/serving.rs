//! Serving-layer bench: tokens/sec through the `rfa::serve` scheduler
//! over {1, 8, 32} concurrent sessions × {f64, f32} precision, a
//! thread-scaling probe, and the cost of LRU eviction/restore churn
//! under a one-session memory budget.
//!
//! Emits `BENCH_serving.json`. Headline metrics:
//! `tokens_per_sec_s{1,8,32}_{f64,f32}` (scheduled positions per second
//! at each concurrency), `serve_thread_scaling_s8_f32` (1 worker vs all
//! cores on the same workload) and `eviction_churn_slowdown_s8_f32`
//! (sequential per-session drains with snapshot churn vs without).
//!
//! Run: `cargo bench --bench serving`.

use darkformer::bench::BenchSuite;
use darkformer::linalg::Matrix;
use darkformer::rfa::engine::Head;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::serve::{
    BatchScheduler, Precision, ServeConfig, SessionPool, StepRequest,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 16;
const DV: usize = 16;
const M: usize = 32;
const N_HEADS: usize = 4;
const CHUNK: usize = 32;
const SEG: usize = 128;

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn serve_config(
    precision: Precision,
    threads: usize,
    memory_budget: usize,
) -> ServeConfig {
    ServeConfig {
        est: PrfEstimator::new(D, M, Sampling::Isotropic),
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: std::env::temp_dir()
            .join(format!("serving_bench_{}", std::process::id())),
    }
}

/// One pre-generated request segment per session (cloned per submit).
fn session_inputs(n_sessions: usize) -> Vec<Vec<Head>> {
    let mut rng = Pcg64::seed(0x5e11e);
    (0..n_sessions)
        .map(|_| {
            (0..N_HEADS)
                .map(|_| Head {
                    q: rows(SEG, D, 0.1, &mut rng),
                    k: rows(SEG, D, 0.1, &mut rng),
                    v: Matrix::from_rows(&rows(SEG, DV, 0.5, &mut rng)),
                })
                .collect()
        })
        .collect()
}

fn precision_tag(p: Precision) -> &'static str {
    match p {
        Precision::F64 => "f64",
        Precision::F32 => "f32",
    }
}

/// Mean ms for one scheduling round: every session submits one segment,
/// then the queue drains. `batched` coalesces all sessions into shared
/// ticks; sequential mode drains one session at a time (the pattern that
/// forces snapshot churn under a tight budget).
fn bench_round(
    suite: &mut BenchSuite,
    name: &str,
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    n_sessions: usize,
    batched: bool,
    iters: usize,
) -> f64 {
    let mut pool = SessionPool::new(serve_config(
        precision,
        threads,
        memory_budget,
    ));
    let ids: Vec<u64> = (0..n_sessions)
        .map(|s| pool.create_session(100 + s as u64).unwrap())
        .collect();
    let inputs = session_inputs(n_sessions);
    let mut sched = BatchScheduler::new(pool);
    suite.bench(name, 1, iters, || {
        if batched {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
            }
            let responses = sched.run_until_idle().unwrap();
            assert_eq!(responses.len(), n_sessions);
            std::hint::black_box(responses);
        } else {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
                std::hint::black_box(sched.run_until_idle().unwrap());
            }
        }
    })
}

fn main() {
    let mut suite = BenchSuite::new("serving");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    suite.metric("available_cores", cores as f64);
    println!(
        "serving scheduler: d={D} dv={DV} m={M} heads={N_HEADS} \
         chunk={CHUNK} segment={SEG} cores={cores}\n"
    );

    // Tokens/sec over {1, 8, 32} concurrent sessions, both precisions.
    for precision in [Precision::F64, Precision::F32] {
        let tag = precision_tag(precision);
        for n_sessions in [1usize, 8, 32] {
            let iters = if n_sessions >= 32 { 3 } else { 5 };
            let ms = bench_round(
                &mut suite,
                &format!("serve/{tag}/s{n_sessions}"),
                precision,
                0,
                0,
                n_sessions,
                true,
                iters,
            );
            let tokens_per_sec = (n_sessions * SEG) as f64 / (ms / 1e3);
            println!(
                "  -> {tokens_per_sec:>12.0} tokens/s \
                 ({n_sessions} sessions, {tag})"
            );
            suite.metric(
                format!("tokens_per_sec_s{n_sessions}_{tag}"),
                tokens_per_sec,
            );
        }
    }

    // Thread scaling: identical 8-session workload, 1 worker vs all
    // cores (outputs identical by the determinism contract).
    let t1 = bench_round(
        &mut suite,
        "serve/f32/s8/threads1",
        Precision::F32,
        1,
        0,
        8,
        true,
        3,
    );
    let tall = bench_round(
        &mut suite,
        "serve/f32/s8/threads_all",
        Precision::F32,
        0,
        0,
        8,
        true,
        3,
    );
    suite.metric("serve_thread_scaling_s8_f32", t1 / tall);
    println!(
        "\nthread scaling (8 sessions, f32): {:.2}x across {cores} cores",
        t1 / tall
    );

    // Eviction churn: sequential per-session drains with a one-session
    // budget (every switch snapshots one session out and faults another
    // in) vs the same drains with no budget pressure.
    let probe = {
        let mut pool = SessionPool::new(serve_config(Precision::F32, 1, 0));
        let id = pool.create_session(0).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };
    let no_churn = bench_round(
        &mut suite,
        "serve/f32/s8/sequential",
        Precision::F32,
        0,
        0,
        8,
        false,
        3,
    );
    let churn = bench_round(
        &mut suite,
        "serve/f32/s8/sequential_churn",
        Precision::F32,
        0,
        probe,
        8,
        false,
        3,
    );
    suite.metric("eviction_churn_slowdown_s8_f32", churn / no_churn);
    println!(
        "eviction/restore churn slowdown (8 sessions, 1-session budget): \
         {:.2}x",
        churn / no_churn
    );

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
