//! Serving-layer bench: tokens/sec through the `rfa::serve` scheduler
//! over {1, 8, 32} concurrent sessions × {f64, f32} precision, a
//! thread-scaling probe, and the cost of LRU eviction/restore churn
//! under a one-session memory budget.
//!
//! Emits `BENCH_serving.json`. Headline metrics:
//! `tokens_per_sec_s{1,8,32}_{f64,f32}` (scheduled positions per second
//! at each concurrency), `serve_thread_scaling_s8_f32` (1 worker vs all
//! cores on the same workload), `eviction_churn_slowdown_s8_f32`
//! (sequential per-session drains with snapshot churn vs without),
//! the fault-tolerance pair: `fault_recovery_overhead_f64` (the f64
//! churn workload over a seeded transient-only fault stream vs clean —
//! the price of the retry/backoff machinery) and
//! `quarantine_isolation_tokens_per_sec` (healthy-session throughput
//! with one session's snapshot path persistently dead and quarantined),
//! and the covariance-drift pair: `online_vs_static_variance`
//! (across-seed output variance of a static data-aware bank over the
//! drifted half of the stream, divided by the online-resampling
//! variance — > 1 means adapting the bank beats freezing it) with
//! `online_resample_overhead_f64` (wall-clock cost of the resampling
//! machinery on the same workload),
//! `simd_vs_scalar_serve_s8_{f64,f32}` (one scheduling round under the
//! forced-scalar fallback vs the dispatched SIMD kernels, with the
//! effective ISA recorded as `active_isa`), the observability
//! readout: `tick_latency_p50_ms`/`tick_latency_p99_ms` (from the obs
//! registry's tick histogram over a resampling 8-session round) with
//! `ess_mean` (mean per-head importance-weight effective sample size),
//! and the epoch-churn triplet for long-lived resampling sessions:
//! `resample_epoch_cost_ms` vs `resample_epoch_cost_ms_scratch` (one
//! epoch's factor maintenance — streamed rank-1 updates plus the O(d²)
//! boundary scale — against the from-scratch materialize+refactorize
//! O(d³) boundary it replaces, with `resample_epoch_speedup` as the
//! ratio), `frozen_readout_overhead` (wall-clock of an epoch-churn
//! stream carrying 8 frozen epochs vs 1), and `compaction_bytes_saved`
//! (resident session bytes with frozen-epoch compaction off vs on at
//! window 2, after the frozen tail has filled).
//!
//! Run: `cargo bench --bench serving`.

use darkformer::bench::BenchSuite;
use darkformer::linalg::{simd, Matrix};
use darkformer::obs::{ObsConfig, ObsLevel};
use darkformer::rfa::engine::Head;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{
    anisotropic_covariance, MultivariateGaussian, SecondMomentAccumulator,
};
use darkformer::rfa::serve::{
    BatchScheduler, CompactionConfig, Fault, FaultRule, FaultyStore,
    FsStore, Precision, ResampleConfig, SeededFaults, ServeConfig,
    SessionPool, StepRequest, StoreOp,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 16;
const DV: usize = 16;
const M: usize = 32;
const N_HEADS: usize = 4;
const CHUNK: usize = 32;
const SEG: usize = 128;

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn serve_config(
    precision: Precision,
    threads: usize,
    memory_budget: usize,
) -> ServeConfig {
    ServeConfig {
        est: PrfEstimator::new(D, M, Sampling::Isotropic),
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: std::env::temp_dir()
            .join(format!("serving_bench_{}", std::process::id())),
        resample: None,
    }
}

/// One pre-generated request segment per session (cloned per submit).
fn session_inputs(n_sessions: usize) -> Vec<Vec<Head>> {
    let mut rng = Pcg64::seed(0x5e11e);
    (0..n_sessions)
        .map(|_| {
            (0..N_HEADS)
                .map(|_| Head {
                    q: rows(SEG, D, 0.1, &mut rng),
                    k: rows(SEG, D, 0.1, &mut rng),
                    v: Matrix::from_rows(&rows(SEG, DV, 0.5, &mut rng)),
                })
                .collect()
        })
        .collect()
}

// ------------------------------------------ covariance-drift scenario

const DRIFT_SEG: usize = 64;
const DRIFT_ROUNDS: usize = 8;
const DRIFT_SEEDS: u64 = 8;

/// `(1-t)·A + t·B` — the key distribution sliding from A's geometry to
/// B's over the stream.
fn mixed_cov(a: &Matrix, b: &Matrix, t: f64) -> Matrix {
    let mut out = a.scale(1.0 - t);
    let bt = b.scale(t);
    for i in 0..out.rows() {
        for j in 0..out.cols() {
            out[(i, j)] += bt[(i, j)];
        }
    }
    out
}

/// The drift endpoints: two differently-rotated anisotropic covariances.
fn drift_covariances() -> (Matrix, Matrix) {
    let mut rng = Pcg64::seed(0xc0f);
    (
        anisotropic_covariance(D, 0.6, 0.45, &mut rng),
        anisotropic_covariance(D, 0.6, 0.45, &mut rng),
    )
}

/// One fixed drifting stream (shared across every bank seed): segment
/// `r` draws its queries and keys from `mixed_cov(A, B, r/(R-1))`.
fn drift_stream(cov_a: &Matrix, cov_b: &Matrix) -> Vec<Vec<Head>> {
    let mut rng = Pcg64::seed(0xd21f7);
    (0..DRIFT_ROUNDS)
        .map(|r| {
            let t = r as f64 / (DRIFT_ROUNDS - 1) as f64;
            let g = MultivariateGaussian::new(mixed_cov(cov_a, cov_b, t))
                .expect("mixed covariance stays SPD");
            (0..N_HEADS)
                .map(|_| Head {
                    q: (0..DRIFT_SEG).map(|_| g.sample(&mut rng)).collect(),
                    k: (0..DRIFT_SEG).map(|_| g.sample(&mut rng)).collect(),
                    v: Matrix::from_rows(&rows(DRIFT_SEG, DV, 0.5, &mut rng)),
                })
                .collect()
        })
        .collect()
}

/// Stream the drifting segments through one session and return the
/// flattened outputs of the second (fully drifted) half. Both arms
/// start from the same data-aware estimator against the start
/// covariance A; `resample` turns the online adaptation on.
fn drift_run(
    cov_a: &Matrix,
    stream: &[Vec<Head>],
    resample: Option<ResampleConfig>,
    seed: u64,
) -> Vec<f64> {
    let cfg = ServeConfig {
        est: PrfEstimator::new(
            D,
            M,
            Sampling::DataAware(
                MultivariateGaussian::new(cov_a.clone()).unwrap(),
            ),
        ),
        n_heads: N_HEADS,
        dv: DV,
        precision: Precision::F64,
        chunk: CHUNK,
        threads: 1,
        memory_budget: 0,
        snapshot_dir: std::env::temp_dir()
            .join(format!("serving_drift_{}", std::process::id())),
        resample,
    };
    let mut pool = SessionPool::new(cfg);
    let id = pool.create_session(seed).unwrap();
    let mut tail = Vec::new();
    for (r, heads) in stream.iter().enumerate() {
        let outs = pool.session_mut(id).unwrap().step(heads, CHUNK);
        if r >= DRIFT_ROUNDS / 2 {
            for out in &outs {
                tail.extend_from_slice(out.to_f64().data());
            }
        }
    }
    tail
}

/// Mean per-element variance across runs (each run = one bank seed over
/// the identical input stream) — the estimator-variance the paper's
/// data-aware argument is about, measured at serving time.
fn mean_variance(runs: &[Vec<f64>]) -> f64 {
    let n = runs.len() as f64;
    let len = runs[0].len();
    let mut acc = 0.0;
    for i in 0..len {
        let mean = runs.iter().map(|r| r[i]).sum::<f64>() / n;
        acc += runs.iter().map(|r| (r[i] - mean).powi(2)).sum::<f64>()
            / (n - 1.0);
    }
    acc / len as f64
}

fn precision_tag(p: Precision) -> &'static str {
    match p {
        Precision::F64 => "f64",
        Precision::F32 => "f32",
    }
}

/// Mean ms for one scheduling round: every session submits one segment,
/// then the queue drains. `batched` coalesces all sessions into shared
/// ticks; sequential mode drains one session at a time (the pattern that
/// forces snapshot churn under a tight budget).
fn bench_round(
    suite: &mut BenchSuite,
    name: &str,
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    n_sessions: usize,
    batched: bool,
    iters: usize,
) -> f64 {
    let mut pool = SessionPool::new(serve_config(
        precision,
        threads,
        memory_budget,
    ));
    let ids: Vec<u64> = (0..n_sessions)
        .map(|s| pool.create_session(100 + s as u64).unwrap())
        .collect();
    let inputs = session_inputs(n_sessions);
    let mut sched = BatchScheduler::new(pool);
    suite.bench(name, 1, iters, || {
        if batched {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
            }
            let responses = sched.run_until_idle().into_result().unwrap();
            assert_eq!(responses.len(), n_sessions);
            std::hint::black_box(responses);
        } else {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
                std::hint::black_box(
                    sched.run_until_idle().into_result().unwrap(),
                );
            }
        }
    })
}

// ------------------------------------------------ epoch-churn scenario

/// Boundary-factorization microbench shape: dimension, epoch length
/// (positions between boundaries), and how many consecutive epochs one
/// timed pass simulates. `CHOL_K << CHOL_D` is the regime the
/// incremental path is built for — the tighter the epochs, the more the
/// O(d³) refactorization dominates the from-scratch arm.
const CHOL_D: usize = 64;
const CHOL_K: usize = 8;
const CHOL_EPOCHS: usize = 32;
const CHOL_LAM: f64 = 0.05;

/// Stream `rounds` copies of one pre-generated segment through a single
/// long-lived resampling session and return its resident bytes at the
/// end. Timing callers wrap the whole run; pool construction and input
/// generation are identical across arms, so ratios isolate the
/// per-position cost under test.
fn churn_run(rc: &ResampleConfig, rounds: usize) -> usize {
    let mut cfg = serve_config(Precision::F64, 1, 0);
    cfg.resample = Some(rc.clone());
    let mut pool = SessionPool::new(cfg);
    let id = pool.create_session(0xE9).unwrap();
    let inputs = session_inputs(1).remove(0);
    for _ in 0..rounds {
        std::hint::black_box(
            pool.session_mut(id).unwrap().step(&inputs, CHUNK),
        );
    }
    pool.session_mut(id).unwrap().state_bytes()
}

fn main() {
    let mut suite = BenchSuite::new("serving");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    suite.metric("available_cores", cores as f64);
    println!(
        "serving scheduler: d={D} dv={DV} m={M} heads={N_HEADS} \
         chunk={CHUNK} segment={SEG} cores={cores}\n"
    );

    // Tokens/sec over {1, 8, 32} concurrent sessions, both precisions.
    for precision in [Precision::F64, Precision::F32] {
        let tag = precision_tag(precision);
        for n_sessions in [1usize, 8, 32] {
            let iters = if n_sessions >= 32 { 3 } else { 5 };
            let ms = bench_round(
                &mut suite,
                &format!("serve/{tag}/s{n_sessions}"),
                precision,
                0,
                0,
                n_sessions,
                true,
                iters,
            );
            let tokens_per_sec = (n_sessions * SEG) as f64 / (ms / 1e3);
            println!(
                "  -> {tokens_per_sec:>12.0} tokens/s \
                 ({n_sessions} sessions, {tag})"
            );
            suite.metric(
                format!("tokens_per_sec_s{n_sessions}_{tag}"),
                tokens_per_sec,
            );
        }
    }

    // Thread scaling: identical 8-session workload, 1 worker vs all
    // cores (outputs identical by the determinism contract).
    let t1 = bench_round(
        &mut suite,
        "serve/f32/s8/threads1",
        Precision::F32,
        1,
        0,
        8,
        true,
        3,
    );
    let tall = bench_round(
        &mut suite,
        "serve/f32/s8/threads_all",
        Precision::F32,
        0,
        0,
        8,
        true,
        3,
    );
    suite.metric("serve_thread_scaling_s8_f32", t1 / tall);
    println!(
        "\nthread scaling (8 sessions, f32): {:.2}x across {cores} cores",
        t1 / tall
    );

    // Eviction churn: sequential per-session drains with a one-session
    // budget (every switch snapshots one session out and faults another
    // in) vs the same drains with no budget pressure.
    let probe = {
        let mut pool = SessionPool::new(serve_config(Precision::F32, 1, 0));
        let id = pool.create_session(0).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };
    let no_churn = bench_round(
        &mut suite,
        "serve/f32/s8/sequential",
        Precision::F32,
        0,
        0,
        8,
        false,
        3,
    );
    let churn = bench_round(
        &mut suite,
        "serve/f32/s8/sequential_churn",
        Precision::F32,
        0,
        probe,
        8,
        false,
        3,
    );
    suite.metric("eviction_churn_slowdown_s8_f32", churn / no_churn);
    println!(
        "eviction/restore churn slowdown (8 sessions, 1-session budget): \
         {:.2}x",
        churn / no_churn
    );

    // Fault-injected recovery: the f64 sequential-churn workload (a
    // one-session budget makes every drain snapshot one session out and
    // fault the next in) over a seeded transient-only fault stream that
    // fails roughly every 4th snapshot-store op. Transient faults never
    // quarantine, so the ratio is the pure cost of the retry/backoff/
    // deferred-budget machinery riding a flaky disk.
    let probe64 = {
        let mut pool = SessionPool::new(serve_config(Precision::F64, 1, 0));
        let id = pool.create_session(0).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };
    let clean64 = bench_round(
        &mut suite,
        "serve/f64/s8/sequential_churn",
        Precision::F64,
        0,
        probe64,
        8,
        false,
        3,
    );
    let faulted64 = {
        let store = FaultyStore::new(Box::new(FsStore), Vec::new());
        let handle = store.handle();
        let mut pool = SessionPool::with_store(
            serve_config(Precision::F64, 0, probe64),
            Box::new(store),
        );
        let ids: Vec<u64> = (0..8)
            .map(|s| pool.create_session(100 + s).unwrap())
            .collect();
        let inputs = session_inputs(8);
        // Arm the stream only after the sessions exist, so setup cost
        // never depends on the schedule.
        handle.set_seeded(Some(SeededFaults {
            seed: 0xFA17,
            fault_every: 4,
            transient_only: true,
        }));
        let mut sched = BatchScheduler::new(pool);
        suite.bench("serve/f64/s8/sequential_churn_faulted", 1, 3, || {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
                std::hint::black_box(
                    sched.run_until_idle().into_result().unwrap(),
                );
            }
        })
    };
    suite.metric("fault_recovery_overhead_f64", faulted64 / clean64);
    println!(
        "fault-injected churn overhead (f64, transient fault every ~4th \
         store op): {:.2}x",
        faulted64 / clean64
    );

    // Quarantine isolation: one session's snapshot path fails every
    // read persistently, so the scheduler quarantines it during the
    // warmup round; the seven healthy sessions keep the pipeline full
    // afterwards. Tokens/sec over the healthy sessions only — carrying
    // a dead session costs its few failed attempts, not ongoing drag.
    let isolated_tps = {
        let store = FaultyStore::new(Box::new(FsStore), Vec::new());
        let handle = store.handle();
        let mut pool = SessionPool::with_store(
            serve_config(Precision::F64, 0, probe64),
            Box::new(store),
        );
        let ids: Vec<u64> = (0..8)
            .map(|s| pool.create_session(100 + s).unwrap())
            .collect();
        let inputs = session_inputs(8);
        handle.script(vec![FaultRule::on(StoreOp::Read, Fault::Persistent)
            .on_path(format!("session-{}.dkft", ids[3]))]);
        let mut sched = BatchScheduler::new(pool);
        let ms = suite.bench("serve/f64/s8/quarantine_isolation", 1, 3, || {
            for (id, heads) in ids.iter().zip(&inputs) {
                if sched.is_quarantined(*id) {
                    continue;
                }
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
            }
            let outcome = sched.run_until_idle();
            assert!(outcome.error.is_none());
            std::hint::black_box(outcome.responses);
        });
        assert_eq!(sched.quarantined_sessions(), vec![ids[3]]);
        (7 * SEG) as f64 / (ms / 1e3)
    };
    suite.metric("quarantine_isolation_tokens_per_sec", isolated_tps);
    println!(
        "quarantine isolation (8 sessions, 1 quarantined): \
         {isolated_tps:>12.0} healthy tokens/s"
    );

    // Covariance drift: the key distribution slides from Σ_A to Σ_B
    // over 8 segments. A bank frozen against Σ_A is mis-matched on the
    // second half; online resampling re-draws against the streamed
    // estimate every segment. Lower across-seed variance on the drifted
    // half = better-conditioned estimator.
    let rc = ResampleConfig {
        epoch_positions: DRIFT_SEG as u64,
        max_epochs: DRIFT_ROUNDS,
        shrinkage: 0.05,
        compaction: None,
    };
    let (cov_a, cov_b) = drift_covariances();
    let stream = drift_stream(&cov_a, &cov_b);
    let static_runs: Vec<Vec<f64>> = (0..DRIFT_SEEDS)
        .map(|s| drift_run(&cov_a, &stream, None, 9000 + s))
        .collect();
    let online_runs: Vec<Vec<f64>> = (0..DRIFT_SEEDS)
        .map(|s| drift_run(&cov_a, &stream, Some(rc.clone()), 9000 + s))
        .collect();
    let var_static = mean_variance(&static_runs);
    let var_online = mean_variance(&online_runs);
    suite.metric("drift_variance_static_bank", var_static);
    suite.metric("drift_variance_online_bank", var_online);
    suite.metric("online_vs_static_variance", var_static / var_online);
    println!(
        "\ncovariance drift ({DRIFT_SEEDS} seeds, {DRIFT_ROUNDS} segments \
         of {DRIFT_SEG}): static bank variance {var_static:.3e}, online \
         {var_online:.3e} — {:.2}x in favor of online",
        var_static / var_online
    );

    // What the adaptation costs: the same drifting workload with and
    // without the per-segment moment tracking + redraw.
    let t_static = suite.bench("serve/f64/drift/static", 1, 3, || {
        std::hint::black_box(drift_run(&cov_a, &stream, None, 1));
    });
    let t_online = suite.bench("serve/f64/drift/online", 1, 3, || {
        std::hint::black_box(drift_run(
            &cov_a,
            &stream,
            Some(rc.clone()),
            1,
        ));
    });
    suite.metric("online_resample_overhead_f64", t_online / t_static);
    println!(
        "online resampling overhead (f64, K={DRIFT_SEG}): {:.2}x",
        t_online / t_static
    );

    // SIMD dispatch A/B: one 8-session scheduling round per precision on
    // a single worker (isolating kernel throughput from scheduling),
    // forced-scalar fallback vs dispatched kernels. Outputs are bitwise-
    // identical by the dispatch contract, so only the wall-clock moves.
    let prev = simd::set_isa(simd::Isa::Scalar);
    let scalar64 = bench_round(
        &mut suite,
        "serve/f64/s8/scalar_kernels",
        Precision::F64,
        1,
        0,
        8,
        true,
        3,
    );
    let scalar32 = bench_round(
        &mut suite,
        "serve/f32/s8/scalar_kernels",
        Precision::F32,
        1,
        0,
        8,
        true,
        3,
    );
    simd::set_isa(prev);
    let simd64 = bench_round(
        &mut suite,
        "serve/f64/s8/simd_kernels",
        Precision::F64,
        1,
        0,
        8,
        true,
        3,
    );
    let simd32 = bench_round(
        &mut suite,
        "serve/f32/s8/simd_kernels",
        Precision::F32,
        1,
        0,
        8,
        true,
        3,
    );
    suite.metric("simd_vs_scalar_serve_s8_f64", scalar64 / simd64);
    suite.metric("simd_vs_scalar_serve_s8_f32", scalar32 / simd32);
    println!(
        "\nsimd-vs-scalar serve round (8 sessions, 1 worker, {}): \
         f64 {:.2}x, f32 {:.2}x",
        simd::active_isa(),
        scalar64 / simd64,
        scalar32 / simd32
    );
    suite.metric_str("active_isa", simd::active_isa());

    // Observability readout: an 8-session resampling workload against a
    // pinned Basic-level registry (histograms + gauges live, no ring).
    // Tick-latency quantiles come from the obs histogram itself — the
    // same numbers a Prometheus scrape would see — and ess_mean is the
    // kernel-quality headline: the mean per-head importance-weight
    // effective sample size after the banks have adapted to the keys.
    let (tick_p50, tick_p99, ess_mean) = {
        let mut cfg = serve_config(Precision::F32, 0, 0);
        cfg.resample = Some(ResampleConfig::every(64));
        let mut pool = SessionPool::with_obs(
            cfg,
            Box::new(FsStore),
            ObsConfig::at(ObsLevel::Basic),
        );
        let ids: Vec<u64> = (0..8)
            .map(|s| pool.create_session(100 + s).unwrap())
            .collect();
        let inputs = session_inputs(8);
        let mut sched = BatchScheduler::new(pool);
        for _ in 0..4 {
            for (id, heads) in ids.iter().zip(&inputs) {
                sched
                    .submit(StepRequest {
                        session_id: *id,
                        heads: heads.clone(),
                    })
                    .unwrap();
            }
            std::hint::black_box(
                sched.run_until_idle().into_result().unwrap(),
            );
        }
        let obs = sched.obs();
        (
            obs.tick_ms.quantile(0.5),
            obs.tick_ms.quantile(0.99),
            obs.ess_mean(),
        )
    };
    suite.metric("tick_latency_p50_ms", tick_p50);
    suite.metric("tick_latency_p99_ms", tick_p99);
    suite.metric("ess_mean", ess_mean);
    println!(
        "\nobs readout (8 sessions, resample K=64): tick p50 \
         {tick_p50:.3} ms, p99 {tick_p99:.3} ms, ess_mean {ess_mean:.2} \
         of m={M}"
    );

    // Epoch-churn cost structure of long-lived resampling sessions.
    //
    // (a) Boundary factorization A/B at the linalg level, from the same
    // moment stream: the incremental arm folds each epoch's K keys into
    // the maintained factor as √(1-λ)-scaled rank-1 updates (O(d²)
    // each, paid during stepping) and finishes the boundary with an
    // O(d²) scale; the from-scratch arm it replaces materializes the
    // floored moment and refactorizes O(d³) at every boundary. Moment
    // accumulation runs on both arms (common cost), so the ratio is the
    // factorization work alone.
    let (chol_acc, chol_l, chol_keys) = {
        let mut rng = Pcg64::seed(0xCAB1E);
        let mut acc = SecondMomentAccumulator::new(CHOL_D);
        for _ in 0..3 * CHOL_D {
            acc.accumulate(&rng.gaussian_vec(CHOL_D));
        }
        let mut u = acc.sum().scale(1.0 - CHOL_LAM);
        for i in 0..CHOL_D {
            u[(i, i)] += CHOL_LAM * acc.count() as f64;
        }
        let l = u.cholesky().expect("floored moment is SPD");
        let keys: Vec<Vec<f64>> = (0..CHOL_EPOCHS * CHOL_K)
            .map(|_| rng.gaussian_vec(CHOL_D))
            .collect();
        (acc, l, keys)
    };
    let scratch_ms = suite.bench("chol/boundary/scratch", 1, 5, || {
        let mut acc = chol_acc.clone();
        for e in 0..CHOL_EPOCHS {
            for x in &chol_keys[e * CHOL_K..(e + 1) * CHOL_K] {
                acc.accumulate(x);
            }
            let mut u = acc.sum().scale(1.0 - CHOL_LAM);
            for i in 0..CHOL_D {
                u[(i, i)] += CHOL_LAM * acc.count() as f64;
            }
            let l = u.cholesky().expect("floored moment stays SPD");
            std::hint::black_box(
                l.scale(1.0 / (acc.count() as f64).sqrt()),
            );
        }
    });
    let incremental_ms =
        suite.bench("chol/boundary/incremental", 1, 5, || {
            let mut acc = chol_acc.clone();
            let mut l = chol_l.clone();
            let up = (1.0 - CHOL_LAM).sqrt();
            for e in 0..CHOL_EPOCHS {
                for x in &chol_keys[e * CHOL_K..(e + 1) * CHOL_K] {
                    acc.accumulate(x);
                    let sx: Vec<f64> =
                        x.iter().map(|&v| up * v).collect();
                    l.cholesky_update_rank1(&sx);
                }
                std::hint::black_box(
                    l.scale(1.0 / (acc.count() as f64).sqrt()),
                );
            }
        });
    let per_boundary_incr = incremental_ms / CHOL_EPOCHS as f64;
    let per_boundary_scratch = scratch_ms / CHOL_EPOCHS as f64;
    suite.metric("resample_epoch_cost_ms", per_boundary_incr);
    suite.metric("resample_epoch_cost_ms_scratch", per_boundary_scratch);
    suite.metric("resample_epoch_speedup", scratch_ms / incremental_ms);
    println!(
        "\nepoch boundary factorization (d={CHOL_D}, K={CHOL_K}): \
         incremental {per_boundary_incr:.4} ms, from-scratch \
         {per_boundary_scratch:.4} ms — {:.2}x",
        scratch_ms / incremental_ms
    );

    // (b) What the frozen tail costs per position: the same epoch-churn
    // stream (4 × SEG positions, a boundary every 16) retaining 8
    // frozen epochs vs 1. Every live frozen epoch adds one extra
    // feature-map readout per position, so the ratio is the marginal
    // price of a deep attention window.
    let churn_rc = |max_epochs: usize,
                    compaction: Option<CompactionConfig>| {
        ResampleConfig {
            epoch_positions: 16,
            max_epochs,
            shrinkage: 0.05,
            compaction,
        }
    };
    const CHURN_ROUNDS: usize = 4;
    let t_shallow = suite.bench("serve/f64/churn/max_epochs1", 1, 3, || {
        std::hint::black_box(churn_run(&churn_rc(1, None), CHURN_ROUNDS));
    });
    let t_deep = suite.bench("serve/f64/churn/max_epochs8", 1, 3, || {
        std::hint::black_box(churn_run(&churn_rc(8, None), CHURN_ROUNDS));
    });
    suite.metric("frozen_readout_overhead", t_deep / t_shallow);
    println!(
        "frozen-epoch readout overhead (8 retained epochs vs 1, K=16): \
         {:.2}x",
        t_deep / t_shallow
    );

    // (c) What compaction buys: resident bytes of the same long-lived
    // session after 32 boundaries, frozen tail uncompacted (16 epochs
    // deep) vs merged down to a 2-epoch window.
    let bytes_off = churn_run(&churn_rc(16, None), CHURN_ROUNDS);
    let bytes_on =
        churn_run(&churn_rc(16, Some(CompactionConfig::keep(2))), CHURN_ROUNDS);
    suite.metric(
        "compaction_bytes_saved",
        bytes_off.saturating_sub(bytes_on) as f64,
    );
    println!(
        "frozen-epoch compaction (window 2 vs 16 retained): {bytes_off} \
         -> {bytes_on} resident bytes ({} saved)",
        bytes_off.saturating_sub(bytes_on)
    );

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
