//! Substrate throughput bench: tokenizer, corpus synthesis, JSON,
//! checkpoint CRC, linalg kernels — the non-XLA pieces of the hot path.
//!
//! Run: `cargo bench --bench substrates`.

use darkformer::bench::{bench, bench_throughput};
use darkformer::checkpoint::{Checkpoint, Tensor};
use darkformer::data::{CorpusGenerator, CorpusSpec};
use darkformer::linalg::Matrix;
use darkformer::rng::{GaussianExt, Pcg64};
use darkformer::ser::parse;
use darkformer::tokenizer::BpeTrainer;

fn main() {
    let mut rng = Pcg64::seed(9);

    // Corpus synthesis.
    let mut gen = CorpusGenerator::new(CorpusSpec::default(), 1);
    bench_throughput("corpus/generate_100_docs", 1, 5, 100.0, || {
        std::hint::black_box(gen.documents(100));
    });

    // Tokenizer.
    let mut gen2 = CorpusGenerator::new(CorpusSpec::default(), 2);
    let corpus = gen2.documents(800);
    let bpe = BpeTrainer::new(768).train(corpus.as_bytes()).expect("bpe");
    let sample = &corpus[..corpus.len().min(20_000)];
    bench_throughput(
        "bpe/encode_20kB",
        1,
        5,
        sample.len() as f64,
        || {
            std::hint::black_box(bpe.encode(sample));
        },
    );
    let ids = bpe.encode(sample);
    bench_throughput("bpe/decode", 1, 20, ids.len() as f64, || {
        std::hint::black_box(bpe.decode(&ids));
    });

    // JSON manifest parse.
    let manifest = std::fs::read_to_string("artifacts/tiny/darkformer/manifest.json")
        .unwrap_or_else(|_| {
            r#"{"variant":"x","config":"t","params":[{"name":"a","shape":[64,64],"dtype":"f32"}],"programs":[]}"#
                .to_string()
        });
    bench("json/parse_manifest", 5, 100, || {
        std::hint::black_box(parse(&manifest).expect("parse"));
    });

    // Checkpoint round trip (1M f32 ~ a small-config state).
    let data: Vec<f32> = (0..1_000_000).map(|_| rng.next_f32()).collect();
    let mut ck = Checkpoint::new();
    ck.insert("blob", Tensor::from_f32(vec![1000, 1000], &data));
    let path = std::path::PathBuf::from("runs/bench/substrate_ck.dkft");
    std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir");
    bench("checkpoint/save_4MB", 1, 5, || {
        ck.save(&path).expect("save");
    });
    bench("checkpoint/load_4MB", 1, 5, || {
        std::hint::black_box(Checkpoint::load(&path).expect("load"));
    });

    // Linalg.
    let a = Matrix::from_vec(
        128,
        128,
        (0..128 * 128).map(|_| rng.gaussian()).collect(),
    );
    let b = Matrix::from_vec(
        128,
        128,
        (0..128 * 128).map(|_| rng.gaussian()).collect(),
    );
    bench("linalg/matmul_128", 2, 20, || {
        std::hint::black_box(a.matmul(&b));
    });
    let spd = {
        let g = a.matmul(&a.transpose());
        g.add(&Matrix::identity(128).scale(128.0))
    };
    bench("linalg/cholesky_128", 2, 20, || {
        std::hint::black_box(spd.cholesky().expect("spd"));
    });

    // RNG.
    bench_throughput("rng/gaussian_1M", 1, 5, 1e6, || {
        for _ in 0..1_000_000 {
            std::hint::black_box(rng.gaussian());
        }
    });
}
