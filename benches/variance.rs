//! Theory bench: Theorem 3.2 variance table + estimator latency.
//!
//! Regenerates the expected-Monte-Carlo-variance comparison (isotropic vs
//! optimal proposal) at bench scale and times the estimator hot paths.
//! Run: `cargo bench --bench variance`.

use darkformer::bench::bench;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{optimal_proposal, variance, PrfEstimator};
use darkformer::rng::Pcg64;

fn main() {
    let d = 8;
    let m = 16;
    let mut rng = Pcg64::seed(3);

    println!("== Theorem 3.2 variance table (d={d}, m={m}) ==");
    println!(
        "{:>6} {:>14} {:>14} {:>9}",
        "eps", "V(p_I)", "V(psi*)", "ratio"
    );
    let mut ratios = Vec::new();
    for eps in [0.0, 0.4, 0.8] {
        let lambda = anisotropic_covariance(d, 0.2, eps, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let psi = MultivariateGaussian::new(
            optimal_proposal(&lambda).expect("valid lambda"),
        )
        .unwrap();
        let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
        let opt = PrfEstimator::new(d, m, Sampling::Proposal(psi));
        let v_iso =
            variance::expected_mc_variance(&iso, &dist, 50, 2000, &mut rng);
        let v_opt =
            variance::expected_mc_variance(&opt, &dist, 50, 2000, &mut rng);
        println!(
            "{:>6.2} {:>14.6e} {:>14.6e} {:>9.3}",
            eps,
            v_iso,
            v_opt,
            v_iso / v_opt
        );
        ratios.push((eps, v_iso / v_opt));
    }
    let grows = ratios.windows(2).all(|w| w[1].1 >= w[0].1 * 0.9);
    println!(
        "variance-reduction factor grows with anisotropy: {}",
        if grows { "OK" } else { "UNEXPECTED" }
    );

    // Ablation: Performer's orthogonal-random-feature coupling on top of
    // iid isotropic sampling (DESIGN.md: variance-reduction extensions).
    println!("\n== ablation: iid vs block-orthogonal features (m=8) ==");
    {
        use darkformer::rfa::orthogonal::orthogonal_prf_estimate;
        use darkformer::rng::GaussianExt;
        let d = 8;
        let m = 8;
        let q: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let k: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let reps = 4000;
        let var_of = |vals: &[f64]| {
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
                / (vals.len() - 1) as f64
        };
        let iid = PrfEstimator::new(d, m, Sampling::Isotropic);
        let v_iid = var_of(
            &(0..reps).map(|_| iid.estimate(&q, &k, &mut rng)).collect::<Vec<_>>(),
        );
        let v_ort = var_of(
            &(0..reps)
                .map(|_| orthogonal_prf_estimate(&q, &k, m, &mut rng))
                .collect::<Vec<_>>(),
        );
        println!(
            "estimator variance: iid {v_iid:.6e}  orthogonal {v_ort:.6e}  (ratio {:.3})",
            v_iid / v_ort
        );
    }

    println!("\n== estimator hot-path latency ==");
    let lambda = anisotropic_covariance(d, 0.2, 0.6, &mut rng);
    let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    let q = dist.sample(&mut rng);
    let k = dist.sample(&mut rng);
    let iso = PrfEstimator::new(d, 64, Sampling::Isotropic);
    bench("estimate/isotropic/m64", 3, 50, || {
        std::hint::black_box(iso.estimate(&q, &k, &mut rng.clone()));
    });
    let psi = MultivariateGaussian::new(optimal_proposal(&lambda).unwrap())
        .unwrap();
    let opt = PrfEstimator::new(d, 64, Sampling::Proposal(psi));
    bench("estimate/importance/m64", 3, 50, || {
        std::hint::black_box(opt.estimate(&q, &k, &mut rng.clone()));
    });
    let dark = PrfEstimator::new(
        d,
        64,
        Sampling::DataAware(MultivariateGaussian::new(lambda.clone()).unwrap()),
    );
    bench("estimate/data_aware/m64", 3, 50, || {
        std::hint::black_box(dark.estimate(&q, &k, &mut rng.clone()));
    });
    bench("cholesky/d64", 3, 50, || {
        let big = anisotropic_covariance(64, 0.2, 0.5, &mut rng.clone());
        std::hint::black_box(big.cholesky());
    });
    bench("jacobi_eigen/d32", 1, 10, || {
        let big = anisotropic_covariance(32, 0.2, 0.5, &mut rng.clone());
        std::hint::black_box(big.jacobi_eigen());
    });
}
