//! Theory bench: Theorem 3.2 variance table + the batched-engine speedup.
//!
//! Regenerates the expected-Monte-Carlo-variance comparison (isotropic vs
//! optimal proposal), measures the variance-engine hot path — the scalar
//! per-draw reference against the shared-bank, threaded batch engine at
//! the acceptance point (d=8, m=16, 50 pairs × 2000 draws) — and times
//! the estimator building blocks. Emits `BENCH_variance.json`.
//!
//! Run: `cargo bench --bench variance`.

use darkformer::bench::BenchSuite;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{batch, optimal_proposal, variance, PrfEstimator};
use darkformer::rng::Pcg64;

fn main() {
    let d = 8;
    let m = 16;
    let mut rng = Pcg64::seed(3);
    let mut suite = BenchSuite::new("variance");

    println!("== Theorem 3.2 variance table (d={d}, m={m}, batched engine) ==");
    println!(
        "{:>6} {:>14} {:>14} {:>9}",
        "eps", "V(p_I)", "V(psi*)", "ratio"
    );
    let mut ratios = Vec::new();
    for eps in [0.0, 0.4, 0.8] {
        let lambda = anisotropic_covariance(d, 0.2, eps, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let psi = MultivariateGaussian::new(
            optimal_proposal(&lambda).expect("valid lambda"),
        )
        .unwrap();
        let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
        let opt = PrfEstimator::new(d, m, Sampling::Proposal(psi));
        let (v_iso, v_opt) = batch::paired_expected_mc_variance_batched(
            &iso, &opt, &dist, 50, 2000, &mut rng,
        );
        println!(
            "{:>6.2} {:>14.6e} {:>14.6e} {:>9.3}",
            eps,
            v_iso,
            v_opt,
            v_iso / v_opt
        );
        suite.metric(format!("v_ratio_eps{eps}"), v_iso / v_opt);
        ratios.push((eps, v_iso / v_opt));
    }
    let grows = ratios.windows(2).all(|w| w[1].1 >= w[0].1 * 0.9);
    println!(
        "variance-reduction factor grows with anisotropy: {}",
        if grows { "OK" } else { "UNEXPECTED" }
    );

    // -----------------------------------------------------------------
    // Hot path: scalar per-draw engine vs shared-bank threaded engine at
    // the acceptance configuration (d=8, m=16, 50 pairs × 2000 draws),
    // on the data-aware arm whose per-draw Mahalanobis norms made the
    // scalar path quadratic in d.
    // -----------------------------------------------------------------
    println!("\n== variance engine hot path (d={d}, m={m}, 50 pairs x 2000 draws) ==");
    let lambda = anisotropic_covariance(d, 0.2, 0.6, &mut rng);
    let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    let dark = PrfEstimator::new(
        d,
        m,
        Sampling::DataAware(MultivariateGaussian::new(lambda.clone()).unwrap()),
    );
    // Seed-faithful baseline: per-draw `single_term` calls, which recompute
    // the two O(d²) Mahalanobis normalizers on every draw — the hot-path
    // shape this PR removed (the in-tree scalar engine now hoists them).
    let omega_dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    let seed_ms = suite.bench(
        "expected_mc_variance/scalar_per_draw_norms/data_aware",
        1,
        5,
        || {
            let mut r = Pcg64::seed(77);
            let mut acc = 0.0;
            for _ in 0..50 {
                let q = dist.sample(&mut r);
                let k = dist.sample(&mut r);
                let mut mean = 0.0;
                let mut m2 = 0.0;
                for i in 0..2000 {
                    let omega = omega_dist.sample(&mut r);
                    let z = dark.single_term(&q, &k, &omega);
                    let delta = z - mean;
                    mean += delta / (i + 1) as f64;
                    m2 += delta * (z - mean);
                }
                acc += m2 / 1999.0;
            }
            std::hint::black_box(acc / 50.0 / dark.m as f64);
        },
    );
    let scalar_ms = suite.bench("expected_mc_variance/scalar/data_aware", 1, 5, || {
        let mut r = Pcg64::seed(77);
        std::hint::black_box(variance::expected_mc_variance(
            &dark, &dist, 50, 2000, &mut r,
        ));
    });
    let batched_ms = suite.bench("expected_mc_variance/batched/data_aware", 1, 5, || {
        let mut r = Pcg64::seed(77);
        std::hint::black_box(batch::expected_mc_variance_batched(
            &dark, &dist, 50, 2000, &mut r,
        ));
    });
    let single_ms = suite.bench(
        "expected_mc_variance/batched_1thread/data_aware",
        1,
        5,
        || {
            let mut r = Pcg64::seed(77);
            std::hint::black_box(batch::expected_mc_variance_threaded(
                &dark, &dist, 50, 2000, 1, &mut r,
            ));
        },
    );
    let speedup = scalar_ms / batched_ms;
    println!(
        "per-draw-norms {seed_ms:.2} ms  scalar {scalar_ms:.2} ms  batched {batched_ms:.2} ms  (1 thread {single_ms:.2} ms)"
    );
    println!(
        "speedup: batched vs hoisted-scalar {speedup:.2}x, vs seed-style per-draw-norms {:.2}x",
        seed_ms / batched_ms
    );
    suite.metric("hot_path_scalar_per_draw_norms_ms", seed_ms);
    suite.metric("hot_path_scalar_ms", scalar_ms);
    suite.metric("hot_path_batched_ms", batched_ms);
    suite.metric("hot_path_batched_1thread_ms", single_ms);
    suite.metric("hot_path_speedup", speedup);
    suite.metric("hot_path_speedup_1thread", scalar_ms / single_ms);
    suite.metric("hot_path_speedup_vs_per_draw_norms", seed_ms / batched_ms);

    // Same comparison on the isotropic arm. Both engines are O(d) per draw
    // here (no Mahalanobis term to hoist), so this isolates the
    // allocation/bank/threading win from the normalizer-hoist win above.
    let iso16 = PrfEstimator::new(d, m, Sampling::Isotropic);
    let iso_scalar = suite.bench("expected_mc_variance/scalar/isotropic", 1, 5, || {
        let mut r = Pcg64::seed(78);
        std::hint::black_box(variance::expected_mc_variance(
            &iso16, &dist, 50, 2000, &mut r,
        ));
    });
    let iso_batched = suite.bench("expected_mc_variance/batched/isotropic", 1, 5, || {
        let mut r = Pcg64::seed(78);
        std::hint::black_box(batch::expected_mc_variance_batched(
            &iso16, &dist, 50, 2000, &mut r,
        ));
    });
    suite.metric("hot_path_speedup_isotropic", iso_scalar / iso_batched);

    // Ablation: Performer's orthogonal-random-feature coupling on top of
    // iid isotropic sampling (DESIGN.md: variance-reduction extensions).
    println!("\n== ablation: iid vs block-orthogonal features (m=8) ==");
    {
        use darkformer::rfa::FeatureBank;
        use darkformer::rng::GaussianExt;
        let d = 8;
        let m = 8;
        let q: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let k: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let reps = 4000;
        let var_of = |vals: &[f64]| {
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
                / (vals.len() - 1) as f64
        };
        let iid = PrfEstimator::new(d, m, Sampling::Isotropic);
        let v_iid = var_of(
            &(0..reps)
                .map(|_| FeatureBank::draw(&iid, &mut rng).estimate(&q, &k))
                .collect::<Vec<_>>(),
        );
        let v_ort = var_of(
            &(0..reps)
                .map(|_| {
                    FeatureBank::draw_orthogonal(&iid, &mut rng)
                        .estimate(&q, &k)
                })
                .collect::<Vec<_>>(),
        );
        println!(
            "estimator variance: iid {v_iid:.6e}  orthogonal {v_ort:.6e}  (ratio {:.3})",
            v_iid / v_ort
        );
        suite.metric("orf_variance_ratio", v_iid / v_ort);
    }

    println!("\n== estimator hot-path latency ==");
    let q = dist.sample(&mut rng);
    let k = dist.sample(&mut rng);
    let iso = PrfEstimator::new(d, 64, Sampling::Isotropic);
    suite.bench("estimate/isotropic/m64", 3, 50, || {
        std::hint::black_box(iso.estimate(&q, &k, &mut rng.clone()));
    });
    let psi = MultivariateGaussian::new(optimal_proposal(&lambda).unwrap())
        .unwrap();
    let opt = PrfEstimator::new(d, 64, Sampling::Proposal(psi));
    suite.bench("estimate/importance/m64", 3, 50, || {
        std::hint::black_box(opt.estimate(&q, &k, &mut rng.clone()));
    });
    let dark64 = PrfEstimator::new(
        d,
        64,
        Sampling::DataAware(MultivariateGaussian::new(lambda.clone()).unwrap()),
    );
    suite.bench("estimate/data_aware/m64", 3, 50, || {
        std::hint::black_box(dark64.estimate(&q, &k, &mut rng.clone()));
    });
    {
        use darkformer::rfa::FeatureBank;
        suite.bench("bank_draw+estimate/data_aware/m64", 3, 50, || {
            let mut r = rng.clone();
            let bank = FeatureBank::draw(&dark64, &mut r);
            std::hint::black_box(bank.estimate(&q, &k));
        });
    }
    suite.bench("cholesky/d64", 3, 50, || {
        let big = anisotropic_covariance(64, 0.2, 0.5, &mut rng.clone());
        std::hint::black_box(big.cholesky());
    });
    suite.bench("jacobi_eigen/d32", 1, 10, || {
        let big = anisotropic_covariance(32, 0.2, 0.5, &mut rng.clone());
        std::hint::black_box(big.jacobi_eigen());
    });
    suite.metric_str("active_isa", darkformer::linalg::simd::active_isa());

    if let Err(e) = suite.write() {
        eprintln!("could not write bench json: {e}");
    }
}
