//! Chunked multi-head attention engine demo (pure Rust, no artifacts).
//!
//! Streams a long causal sequence through an O(n·dv) `CausalState` chunk
//! by chunk, then runs the same workload multi-head across all cores on
//! the f32 hot path, printing agreement and throughput numbers.
//!
//! This demos the raw single-request forward — the middle of the stack.
//! The serving entry point is `rfa::serve` (multi-tenant session pool,
//! batch scheduler, resumable snapshots); see
//! `examples/serve_demo.rs` for the end-to-end serving loop built on
//! the exact state streamed here.
//!
//! Run: `cargo run --release --example chunked_attention`.

use std::time::Instant;

use darkformer::linalg::{Matrix, Matrix32};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::{engine, FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn main() {
    let (d, dv, m, chunk) = (16usize, 16usize, 64usize, 32usize);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let mut rng = Pcg64::seed(2026);
    let bank = FeatureBank::draw(&est, &mut rng);

    // ---- streaming: L = 100k positions, O(n·dv) state ----------------
    let l_total = 100_000usize;
    let block = 2048usize;
    let mut state = engine::CausalState32::new(m, dv);
    let mut checksum = 0.0f64;
    let t0 = Instant::now();
    let mut done = 0;
    while done < l_total {
        let c = block.min(l_total - done);
        let q = rows(c, d, 0.1, &mut rng);
        let k = rows(c, d, 0.1, &mut rng);
        let v = Matrix32::from_f64(&Matrix::from_rows(&rows(
            c, dv, 0.5, &mut rng,
        )));
        let phi_q = bank.feature_matrix32(&q);
        let phi_k = bank.feature_matrix32(&k);
        let out = state.forward(&phi_q, &phi_k, &v, chunk);
        checksum += out.data().iter().map(|&x| x as f64).sum::<f64>();
        done += c;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "streamed L={l_total} causal positions (f32 engine, {block}-row \
         segments, chunk={chunk}) in {secs:.2}s — {:.0} positions/s, state \
         is {m}x{dv} + {m}",
        l_total as f64 / secs,
    );
    println!("output checksum: {checksum:.4} (finite => normalized)");

    // ---- multi-head fan-out ------------------------------------------
    let (h, l) = (8usize, 8192usize);
    let banks = engine::draw_head_banks(&est, h, &mut Pcg64::seed(7));
    let heads: Vec<engine::Head> = (0..h)
        .map(|_| engine::Head {
            q: rows(l, d, 0.1, &mut rng),
            k: rows(l, d, 0.1, &mut rng),
            v: Matrix::from_rows(&rows(l, dv, 0.5, &mut rng)),
        })
        .collect();
    let time_with = |threads: usize| {
        let cfg = engine::EngineConfig { chunk, threads };
        let t0 = Instant::now();
        let out = engine::multi_head_causal_attention32(&banks, &heads, &cfg);
        (t0.elapsed().as_secs_f64(), out)
    };
    let (t1, out1) = time_with(1);
    let (tn, outn) = time_with(0);
    assert_eq!(out1.len(), outn.len());
    let max_diff: f64 = out1
        .iter()
        .zip(&outn)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0, f64::max);
    println!(
        "multi-head h={h}, L={l}: 1 worker {t1:.2}s, all cores {tn:.2}s \
         ({:.2}x), max |Δ| across thread counts = {max_diff:.1e}",
        t1 / tn
    );
    assert_eq!(max_diff, 0.0, "thread fan-out must be deterministic");
}
