//! End-to-end driver (the EXPERIMENTS.md §E2E run): exercises every layer
//! of the stack on a real small workload.
//!
//! Pipeline: synthesize a C4-like corpus -> train a byte-BPE tokenizer ->
//! PRETRAIN a Gemma-style decoder with exact softmax attention (the
//! stand-in for the paper's pretrained Gemma) -> FINETUNE from that
//! checkpoint with DARKFormer, Performer and exact attention -> report
//! the accuracy table and loss curves.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [model] [pretrain_steps] [finetune_steps]
//! # defaults: small 300 200   (use `tiny 60 40` for a fast smoke run)
//! ```

use anyhow::Result;
use darkformer::config::{ExperimentConfig, LrSchedule};
use darkformer::coordinator::{Trainer, Workbench};
use darkformer::metrics::MetricLogger;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("small").to_string();
    let pretrain_steps: u64 =
        args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let finetune_steps: u64 =
        args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(200);
    let out_root = std::path::PathBuf::from(format!("runs/e2e_{model}"));

    let base = ExperimentConfig {
        model_config: model.clone(),
        corpus_docs: 2000,
        ..Default::default()
    };
    let wb = Workbench::prepare(
        &base.artifacts_dir,
        &base.model_config,
        base.corpus_docs,
        base.seed,
        &out_root.join("_cache"),
    )?;
    println!(
        "== e2e: model={model} corpus={} tokens, bpe vocab={} ==",
        wb.dataset.n_tokens(),
        wb.bpe.vocab_size()
    );

    // Phase 1: pretrain with exact attention ("pretrained Gemma" stand-in).
    let mut pre_cfg = base.clone();
    pre_cfg.variant = "exact".into();
    pre_cfg.steps = pretrain_steps;
    pre_cfg.base_lr = 3e-3;
    pre_cfg.schedule = LrSchedule::WarmupCosine {
        warmup_steps: (pretrain_steps / 10).max(5),
        final_frac: 0.1,
    };
    pre_cfg.out_dir = out_root.join("pretrain_exact");
    pre_cfg.eval_every = (pretrain_steps / 4).max(1);
    let pre_report = Trainer::new(pre_cfg.clone(), &wb)?.run()?;
    println!(
        "pretrain(exact): loss {:.4} acc {:.4} ({:.0} ms/step)",
        pre_report.final_loss, pre_report.final_acc, pre_report.mean_step_ms
    );

    // Phase 2: finetune each attention variant from the same checkpoint.
    let mut rows = Vec::new();
    for variant in ["exact", "darkformer", "performer"] {
        let mut cfg = base.clone();
        cfg.variant = variant.into();
        cfg.steps = finetune_steps;
        cfg.base_lr = 1e-3;
        cfg.init_checkpoint = Some(pre_report.checkpoint_path.clone());
        cfg.out_dir = out_root.join(format!("finetune_{variant}"));
        cfg.eval_every = (finetune_steps / 2).max(1);
        let report = Trainer::new(cfg, &wb)?.run()?;
        println!(
            "finetune({variant}): loss {:.4} acc {:.4} tail_acc {:.4}",
            report.final_loss, report.final_acc, report.tail_acc
        );
        rows.push(report);
    }

    // Summary table (the headline comparison of the paper's Fig. 2).
    println!("\n== finetuning summary (higher tail accuracy is better) ==");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>12}",
        "variant", "loss", "acc", "tail_acc", "ms/step"
    );
    for r in &rows {
        println!(
            "{:<12} {:>10.4} {:>10.4} {:>10.4} {:>12.1}",
            r.variant, r.final_loss, r.final_acc, r.tail_acc, r.mean_step_ms
        );
    }

    // Loss-curve CSV for plotting.
    let mut csv = String::from("step,variant,loss,acc\n");
    for r in &rows {
        for rec in MetricLogger::read_all(&r.metrics_path)? {
            csv.push_str(&format!(
                "{},{},{},{}\n",
                rec.step, r.variant, rec.loss, rec.acc
            ));
        }
    }
    let csv_path = out_root.join("finetune_curves.csv");
    std::fs::write(&csv_path, csv)?;
    println!("\ncurves: {}", csv_path.display());
    Ok(())
}
