//! Long-context scaling demo (the paper's Fig. 1 story): exact softmax
//! attention is O(L^2 d) while PRF linear attention is O(L m d) — time
//! both AOT attention probes as the sequence length grows.
//!
//! ```bash
//! make artifacts     # emits artifacts/scaling/attn_*_L*.hlo.txt
//! cargo run --release --example long_context
//! ```

use std::time::Instant;

use anyhow::{Context, Result};
use darkformer::rng::Pcg64;
use darkformer::runtime::Runtime;
use darkformer::ser::parse;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from("artifacts/scaling");
    let meta = parse(&std::fs::read_to_string(dir.join("meta.json"))?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let h = meta.field("n_heads").and_then(|v| v.as_usize()).context("meta")?;
    let dh = meta.field("head_dim").and_then(|v| v.as_usize()).context("meta")?;
    let m = meta.field("m_features").and_then(|v| v.as_usize()).context("meta")?;
    let seq_lens: Vec<usize> = meta
        .field("seq_lens")
        .and_then(|v| v.as_arr())
        .context("meta")?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();

    let runtime = Runtime::cpu()?;
    let mut rng = Pcg64::seed(1);
    println!("attention probes: h={h} dh={dh} m={m}");
    println!(
        "{:>8} {:>14} {:>14} {:>10}",
        "L", "exact (ms)", "PRF (ms)", "speedup"
    );
    for &l in &seq_lens {
        let mut row = Vec::new();
        for variant in ["exact", "performer"] {
            let program = runtime
                .load_program(&dir.join(format!("attn_{variant}_L{l}.hlo.txt")))?;
            let n = h * l * dh;
            let data: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let mk = || {
                xla::Literal::vec1(&data)
                    .reshape(&[1, h as i64, l as i64, dh as i64])
                    .map_err(|e| anyhow::anyhow!("{e:?}"))
            };
            let (q, k, v) = (mk()?, mk()?, mk()?);
            let seed = xla::Literal::scalar(3u32);
            program.run(&[&q, &k, &v, &seed].map(Clone::clone))?; // warmup
            let reps = 5;
            let t0 = Instant::now();
            for _ in 0..reps {
                program.run(&[&q, &k, &v, &seed].map(Clone::clone))?;
            }
            row.push(t0.elapsed().as_secs_f64() * 1e3 / reps as f64);
        }
        println!(
            "{:>8} {:>14.3} {:>14.3} {:>9.2}x",
            l,
            row[0],
            row[1],
            row[0] / row[1]
        );
    }
    println!("\nexact grows ~quadratically; PRF ~linearly (crossover where m < L)");
    Ok(())
}
