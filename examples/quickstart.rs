//! Quickstart: train a tiny DARKFormer for a handful of steps.
//!
//! ```bash
//! make artifacts            # once: AOT-lower the JAX/Pallas programs
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole public API surface in ~40 lines: prepare data
//! (synthetic corpus + BPE), load the AOT artifacts via PJRT, run a short
//! training loop, evaluate, checkpoint.

use anyhow::Result;
use darkformer::config::ExperimentConfig;
use darkformer::coordinator::{Trainer, Workbench};

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        model_config: "tiny".into(),
        variant: "darkformer".into(),
        steps: 20,
        base_lr: 3e-3,
        corpus_docs: 400,
        out_dir: "runs/quickstart".into(),
        eval_every: 10,
        ..Default::default()
    };

    let wb = Workbench::prepare(
        &cfg.artifacts_dir,
        &cfg.model_config,
        cfg.corpus_docs,
        cfg.seed,
        &cfg.out_dir.join("_cache"),
    )?;
    println!(
        "corpus: {} tokens, vocab {} (BPE)",
        wb.dataset.n_tokens(),
        wb.bpe.vocab_size()
    );

    let trainer = Trainer::new(cfg, &wb)?;
    println!("platform: {}", trainer.platform());
    let report = trainer.run()?;
    println!(
        "\ndone: loss {:.4} -> (tail acc {:.4}), {:.1} ms/step",
        report.final_loss, report.tail_acc, report.mean_step_ms
    );
    println!("checkpoint at {}", report.checkpoint_path.display());
    Ok(())
}
