//! Streaming serving demo: N simulated user streams through the
//! `rfa::serve` stack — session pool with a deliberately small memory
//! budget (so LRU eviction-to-snapshot and fault-in actually exercise),
//! session-batched scheduler, online bank resampling, resumable state —
//! ending with the full observability surface: a Prometheus metric dump
//! (tick-latency histogram, per-head kernel-quality gauges) and the
//! structured event log.
//!
//! This is the serving entry point of the pure-Rust stack: the chunked
//! engine demo (`examples/chunked_attention.rs`) shows the raw forward;
//! this shows the multi-tenant layer the roadmap builds on.
//!
//! Run: `cargo run --release --example serve_demo`.

use std::time::Instant;

use darkformer::linalg::Matrix;
use darkformer::obs::ObsConfig;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::serve::{
    BatchScheduler, FsStore, Precision, ResampleConfig, ServeConfig,
    SessionPool, StepRequest,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn main() {
    let (d, dv, m, n_heads, chunk) = (16usize, 16usize, 32usize, 4usize, 32usize);
    let (n_sessions, rounds, seg) = (6usize, 8usize, 128usize);
    let snapshot_dir = std::env::temp_dir()
        .join(format!("serve_demo_{}", std::process::id()));
    // Epoch length 64 < seg: every segment crosses resample boundaries,
    // so the kernel-quality telemetry has real epochs to report.
    let resample = Some(ResampleConfig::every(64));

    // Budget ≈ 2 sessions: with 6 streams the pool must keep evicting
    // and faulting back in — outputs are unaffected (snapshots are
    // exact-bits), only wall clock pays.
    let probe = {
        let cfg = ServeConfig {
            est: PrfEstimator::new(d, m, Sampling::Isotropic),
            n_heads,
            dv,
            precision: Precision::F32,
            chunk,
            threads: 0,
            memory_budget: 0,
            snapshot_dir: snapshot_dir.clone(),
            resample: resample.clone(),
        };
        let mut pool =
            SessionPool::with_obs(cfg, Box::new(FsStore), ObsConfig::off());
        let id = pool.create_session(0).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };
    let budget = 2 * probe + probe / 2;

    let cfg = ServeConfig {
        est: PrfEstimator::new(d, m, Sampling::Isotropic),
        n_heads,
        dv,
        precision: Precision::F32,
        chunk,
        threads: 0,
        memory_budget: budget,
        snapshot_dir,
        resample,
    };
    println!(
        "serve demo: {n_sessions} streams × {rounds} rounds × {seg} \
         positions, {n_heads} heads, budget {budget} B (≈2 sessions of \
         {probe} B)\n"
    );

    // Full observability: histograms + gauges + the structured event
    // ring (identical outputs either way — obs is write-only).
    let mut pool =
        SessionPool::with_obs(cfg, Box::new(FsStore), ObsConfig::full());
    let ids: Vec<u64> = (0..n_sessions)
        .map(|s| pool.create_session(1000 + s as u64).unwrap())
        .collect();
    let mut sched = BatchScheduler::new(pool);

    let mut rng = Pcg64::seed(2026);
    let mut checksum = 0.0f64;
    let mut served_rows = 0usize;
    let t0 = Instant::now();
    for round in 0..rounds {
        // Uneven arrival: each round, a rotating subset of users sends a
        // segment — ticks keep changing which sessions are resident.
        for (s, id) in ids.iter().enumerate() {
            if (s + round) % 3 == 0 {
                continue; // this user idles this round
            }
            let q = rows(seg, d, 0.1, &mut rng);
            let k = rows(seg, d, 0.1, &mut rng);
            let v = Matrix::from_rows(&rows(seg, dv, 0.5, &mut rng));
            sched
                .submit(StepRequest::broadcast(*id, n_heads, q, k, v))
                .unwrap();
        }
        for resp in sched.run_until_idle().into_result().unwrap() {
            for out in &resp.outputs {
                checksum += out.to_f64().data().iter().sum::<f64>();
                served_rows += out.rows();
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = sched.pool().stats();
    let positions = served_rows / n_heads;

    println!(
        "served {positions} positions across {n_sessions} sessions in \
         {secs:.2}s — {:.0} positions/s ({} head-rows/s)",
        positions as f64 / secs,
        (served_rows as f64 / secs) as u64,
    );
    println!(
        "pool: {} resident / {} evicted at end, {} evictions, {} \
         restores (budget-driven churn)",
        sched.pool().resident_count(),
        sched.pool().evicted_count(),
        stats.evictions,
        stats.restores,
    );
    println!("output checksum: {checksum:.4} (finite => normalized)");
    assert!(
        stats.evictions > 0 && stats.restores > 0,
        "the demo budget should force eviction/restore churn"
    );
    assert!(checksum.is_finite());

    // --- the observability surface ----------------------------------
    let obs = sched.obs().clone();
    let events = obs.drain_events();
    println!("\n=== event log ({} events) ===", events.len());
    for event in events.iter().take(12) {
        println!("  {event}");
    }
    if events.len() > 12 {
        println!("  … {} more", events.len() - 12);
    }

    let dump = obs.prometheus_text();
    println!("\n=== prometheus metrics ===\n{dump}");

    // The dump must carry real signal: ticked latency buckets, per-head
    // ESS gauges, and at least one resample epoch in the event log.
    assert!(
        obs.tick_ms.count() > 0 && dump.contains("rfa_tick_ms_bucket"),
        "tick-latency histogram should have recorded ticks"
    );
    assert!(
        dump.contains("rfa_head_ess{"),
        "per-head ESS gauges should be registered"
    );
    assert!(
        events.iter().any(|e| matches!(
            e.kind,
            darkformer::obs::EventKind::ResampleEpoch { .. }
        )),
        "resampling every 64 positions should emit epoch events"
    );
    println!(
        "ess_mean={:.2} (isotropic epoch-0 banks read m={m}; data-aware \
         epochs reweight)",
        obs.ess_mean()
    );
}
