//! Streaming serving demo: N simulated user streams through the
//! `rfa::serve` stack — session pool with a deliberately small memory
//! budget (so LRU eviction-to-snapshot and fault-in actually exercise),
//! session-batched scheduler, resumable state.
//!
//! This is the serving entry point of the pure-Rust stack: the chunked
//! engine demo (`examples/chunked_attention.rs`) shows the raw forward;
//! this shows the multi-tenant layer the roadmap builds on.
//!
//! Run: `cargo run --release --example serve_demo`.

use std::time::Instant;

use darkformer::linalg::Matrix;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::serve::{
    BatchScheduler, Precision, ServeConfig, SessionPool, StepRequest,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn main() {
    let (d, dv, m, n_heads, chunk) = (16usize, 16usize, 32usize, 4usize, 32usize);
    let (n_sessions, rounds, seg) = (6usize, 8usize, 128usize);
    let snapshot_dir = std::env::temp_dir()
        .join(format!("serve_demo_{}", std::process::id()));

    // Budget ≈ 2 sessions: with 6 streams the pool must keep evicting
    // and faulting back in — outputs are unaffected (snapshots are
    // exact-bits), only wall clock pays.
    let probe = {
        let cfg = ServeConfig {
            est: PrfEstimator::new(d, m, Sampling::Isotropic),
            n_heads,
            dv,
            precision: Precision::F32,
            chunk,
            threads: 0,
            memory_budget: 0,
            snapshot_dir: snapshot_dir.clone(),
            resample: None,
        };
        let mut pool = SessionPool::new(cfg);
        let id = pool.create_session(0).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };
    let budget = 2 * probe + probe / 2;

    let cfg = ServeConfig {
        est: PrfEstimator::new(d, m, Sampling::Isotropic),
        n_heads,
        dv,
        precision: Precision::F32,
        chunk,
        threads: 0,
        memory_budget: budget,
        snapshot_dir,
        resample: None,
    };
    println!(
        "serve demo: {n_sessions} streams × {rounds} rounds × {seg} \
         positions, {n_heads} heads, budget {budget} B (≈2 sessions of \
         {probe} B)\n"
    );

    let mut pool = SessionPool::new(cfg);
    let ids: Vec<u64> = (0..n_sessions)
        .map(|s| pool.create_session(1000 + s as u64).unwrap())
        .collect();
    let mut sched = BatchScheduler::new(pool);

    let mut rng = Pcg64::seed(2026);
    let mut checksum = 0.0f64;
    let mut served_rows = 0usize;
    let t0 = Instant::now();
    for round in 0..rounds {
        // Uneven arrival: each round, a rotating subset of users sends a
        // segment — ticks keep changing which sessions are resident.
        for (s, id) in ids.iter().enumerate() {
            if (s + round) % 3 == 0 {
                continue; // this user idles this round
            }
            let q = rows(seg, d, 0.1, &mut rng);
            let k = rows(seg, d, 0.1, &mut rng);
            let v = Matrix::from_rows(&rows(seg, dv, 0.5, &mut rng));
            sched
                .submit(StepRequest::broadcast(*id, n_heads, q, k, v))
                .unwrap();
        }
        for resp in sched.run_until_idle().into_result().unwrap() {
            for out in &resp.outputs {
                checksum += out.to_f64().data().iter().sum::<f64>();
                served_rows += out.rows();
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = sched.pool().stats();
    let positions = served_rows / n_heads;

    println!(
        "served {positions} positions across {n_sessions} sessions in \
         {secs:.2}s — {:.0} positions/s ({} head-rows/s)",
        positions as f64 / secs,
        (served_rows as f64 / secs) as u64,
    );
    println!(
        "pool: {} resident / {} evicted at end, {} evictions, {} \
         restores (budget-driven churn)",
        sched.pool().resident_count(),
        sched.pool().evicted_count(),
        stats.evictions,
        stats.restores,
    );
    println!("output checksum: {checksum:.4} (finite => normalized)");
    assert!(
        stats.evictions > 0 && stats.restores > 0,
        "the demo budget should force eviction/restore churn"
    );
    assert!(checksum.is_finite());
}
