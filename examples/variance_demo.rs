//! Theorem 3.2 demo, pure Rust: the optimal importance-sampling proposal
//! strictly reduces PRF Monte-Carlo variance under anisotropic inputs,
//! and matches the closed form `Sigma* = (I + 2L)(I - 2L)^{-1}`.
//!
//! ```bash
//! cargo run --release --example variance_demo
//! ```

use anyhow::Result;
use darkformer::linalg::Matrix;
use darkformer::rfa::batch;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::proposal::{anisotropy_index, optimal_eigenvalue};
use darkformer::rfa::{optimal_proposal, PrfEstimator};
use darkformer::rng::Pcg64;

fn main() -> Result<()> {
    let d = 8;
    let m = 16;
    let mut rng = Pcg64::seed(7);

    println!("Theorem 3.2(1): Sigma* isotropic iff Lambda isotropic");
    let iso_lambda = Matrix::identity(d).scale(0.2);
    let sigma_iso = optimal_proposal(&iso_lambda).unwrap();
    println!(
        "  Lambda = 0.2 I  ->  Sigma* diag ~ {:.4} (closed form {:.4}), anisotropy {:.3}",
        sigma_iso[(0, 0)],
        optimal_eigenvalue(0.2),
        anisotropy_index(&sigma_iso)
    );

    println!("\nTheorem 3.2(2): V(psi*) < V(p_I), growing with anisotropy");
    println!(
        "{:>6} {:>12} {:>14} {:>14} {:>9}",
        "eps", "aniso(Σ*)", "V(p_I)", "V(ψ*)", "ratio"
    );
    for eps in [0.0, 0.3, 0.6, 0.9] {
        let lambda = anisotropic_covariance(d, 0.2, eps, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let sigma_star = optimal_proposal(&lambda).unwrap();
        let aniso = anisotropy_index(&sigma_star);
        let psi = MultivariateGaussian::new(sigma_star).unwrap();

        let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
        let opt = PrfEstimator::new(d, m, Sampling::Proposal(psi));
        // Shared-pair batched engine: same (q, k) draws for both
        // estimators, shared draw banks, all cores.
        let (v_iso, v_opt) = batch::paired_expected_mc_variance_batched(
            &iso, &opt, &dist, 60, 2000, &mut rng,
        );
        println!(
            "{:>6.2} {:>12.3} {:>14.6e} {:>14.6e} {:>9.3}",
            eps,
            aniso,
            v_iso,
            v_opt,
            v_iso / v_opt
        );
    }
    println!("\n(ratio > 1 everywhere except eps = 0, where Sigma* ∝ I)");
    Ok(())
}
