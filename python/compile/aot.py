"""AOT lowering: jax -> HLO *text* artifacts + JSON manifest (compile path).

This is the only place Python touches the system; `make artifacts` runs it
once and the Rust coordinator is self-contained afterwards.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Per (config, variant) we emit:
    init.hlo.txt            (seed u32)                       -> params
    train_step.hlo.txt      (params, m, v, tokens, seed, lr, clip, step)
                            -> (params, m, v, loss, acc, gnorm)
    train_step_qkv.hlo.txt  same, gradient-masked to q/k/v + M (Fig. 4)
    eval_step.hlo.txt       (params, tokens, seed)           -> (loss, acc)
    manifest.json           canonical flat-parameter order + arg layout

plus one meta.json per config. Parameters flatten in sorted-name order
(dict flattening order in jax), which the manifest records explicitly so
the Rust runtime never guesses.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, QKV_VARIANTS, VARIANTS, get_config
from .model import param_spec
from .train import make_eval_step, make_init, make_train_step


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract_params(cfg, variant):
    spec = param_spec(cfg, variant)
    return {
        name: jax.ShapeDtypeStruct(shape, jnp.float32)
        for name, shape in spec.items()
    }


def _scalar(dtype):
    return jax.ShapeDtypeStruct((), dtype)


def lower_variant(cfg, variant, out_dir):
    """Lower all step functions for one (config, variant) pair."""
    os.makedirs(out_dir, exist_ok=True)
    params = _abstract_params(cfg, variant)
    tokens = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len + 1), jnp.int32)
    seed = _scalar(jnp.uint32)
    lr = _scalar(jnp.float32)
    clip = _scalar(jnp.float32)
    step = _scalar(jnp.int32)

    def wrap_seed(fn):
        # Lower with a raw uint32 seed; the PRNG key is built inside so the
        # host only ever ships one scalar.
        return fn

    emitted = {}

    init = make_init(cfg, variant)
    lowered = jax.jit(
        lambda s: init(jax.random.PRNGKey(s)), keep_unused=True
    ).lower(seed)
    emitted["init"] = to_hlo_text(lowered)

    def _step(qkv_only):
        inner = make_train_step(cfg, variant, qkv_only=qkv_only)

        def step_fn(p, m, v, tok, s, lr_, clip_, st):
            return inner(p, m, v, tok, jax.random.PRNGKey(s), lr_, clip_, st)

        return step_fn

    lowered = jax.jit(_step(False), keep_unused=True).lower(
        params, params, params, tokens, seed, lr, clip, step
    )
    emitted["train_step"] = to_hlo_text(lowered)

    if variant in QKV_VARIANTS:
        lowered = jax.jit(_step(True), keep_unused=True).lower(
            params, params, params, tokens, seed, lr, clip, step
        )
        emitted["train_step_qkv"] = to_hlo_text(lowered)

    ev = make_eval_step(cfg, variant)
    lowered = jax.jit(
        lambda p, tok, s: ev(p, tok, jax.random.PRNGKey(s)),
        keep_unused=True,
    ).lower(params, tokens, seed)
    emitted["eval_step"] = to_hlo_text(lowered)

    for name, text in emitted.items():
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)

    spec = param_spec(cfg, variant)
    manifest = {
        "variant": variant,
        "config": cfg.name,
        "params": [
            {"name": n, "shape": list(spec[n]), "dtype": "f32"}
            for n in sorted(spec)
        ],
        "programs": sorted(emitted),
        "train_step": {
            "inputs": "params, opt_m, opt_v (each in manifest param order), "
                      "tokens i32[batch, seq_len+1], seed u32, lr f32, "
                      "clip f32 (<=0 disables), step i32",
            "outputs": "params, opt_m, opt_v (same order), loss f32, "
                       "acc f32, grad_norm f32",
        },
        "eval_step": {
            "inputs": "params, tokens, seed",
            "outputs": "loss f32, acc f32",
        },
        "init": {"inputs": "seed u32", "outputs": "params"},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return emitted


def emit_config(cfg, variants, root):
    cfg_dir = os.path.join(root, cfg.name)
    os.makedirs(cfg_dir, exist_ok=True)
    with open(os.path.join(cfg_dir, "meta.json"), "w") as f:
        json.dump({**cfg.as_dict(), "variants": list(variants)}, f, indent=1)
    for variant in variants:
        out_dir = os.path.join(cfg_dir, variant)
        emitted = lower_variant(cfg, variant, out_dir)
        sizes = {k: len(v) for k, v in emitted.items()}
        print(f"[aot] {cfg.name}/{variant}: {sizes}")


def emit_scaling_probes(root, seq_lens, n_heads=4, head_dim=32, m_features=32):
    """Fig. 1 probes: attention-only programs at several sequence lengths.

    Each probe takes (q, k, v) of shape (1, h, L, dh) plus a seed and
    returns the attention output, for both the exact O(L^2 d) softmax path
    and the O(L m d) PRF linear path. The Rust fig1 harness times these to
    regenerate the paper's complexity figure.
    """
    from .kernels import prf
    from .kernels import ref as kref
    from .kernels.linear_attention import causal_linear_attention

    out_dir = os.path.join(root, "scaling")
    os.makedirs(out_dir, exist_ok=True)

    def exact_fn(q, k, v, seed):
        del seed
        return (kref.causal_softmax_attention_ref(q, k, v),)

    def performer_fn(q, k, v, seed):
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (n_heads, m_features, head_dim), jnp.float32)
        phi_q = prf.prf_features(q, w[None], is_query=True)
        phi_k = prf.prf_features(k, w[None], is_query=False)
        # The O(L m d) chunked path — NOT the O(L^2) oracle — so the probe
        # actually measures the complexity the paper's Fig. 1 plots.
        return (causal_linear_attention(phi_q, phi_k, v, 64),)

    emitted = {}
    for L in seq_lens:
        qkv = jax.ShapeDtypeStruct((1, n_heads, L, head_dim), jnp.float32)
        seed = _scalar(jnp.uint32)
        for name, fn in [("exact", exact_fn), ("performer", performer_fn)]:
            lowered = jax.jit(fn, keep_unused=True).lower(qkv, qkv, qkv, seed)
            text = to_hlo_text(lowered)
            fname = f"attn_{name}_L{L}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            emitted[fname] = len(text)
    meta = {
        "seq_lens": list(seq_lens),
        "n_heads": n_heads,
        "head_dim": head_dim,
        "m_features": m_features,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] scaling probes: {emitted}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root")
    ap.add_argument(
        "--configs", nargs="*", default=["tiny", "small"],
        choices=sorted(CONFIGS),
    )
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    ap.add_argument(
        "--scaling-seq-lens", nargs="*", type=int,
        default=[64, 128, 256, 512, 1024],
        help="Fig. 1 probe sequence lengths (empty disables)",
    )
    args = ap.parse_args()
    for name in args.configs:
        emit_config(get_config(name), args.variants, args.out)
    if args.scaling_seq_lens:
        emit_scaling_probes(args.out, args.scaling_seq_lens)
    # Stamp file lets `make artifacts` skip cleanly when inputs unchanged.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
