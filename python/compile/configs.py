"""Model/experiment configurations for the DARKFormer reproduction.

Each config fully determines the lowered artifact shapes, so the Rust
coordinator can treat artifacts as opaque given the emitted ``meta.json``.
"""

from dataclasses import dataclass, asdict, replace

VARIANTS = ("exact", "performer", "darkformer", "lfk", "random", "constant")

# Variants that participate in the qkv-only partial-finetuning experiment
# (Fig. 4 of the paper).
QKV_VARIANTS = ("exact", "performer", "darkformer")


@dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of the Gemma-style decoder used in all experiments.

    Attributes:
        vocab_size: BPE vocabulary size (must match the Rust tokenizer).
        d_model: residual stream width.
        n_layers: number of decoder blocks.
        n_heads: attention heads per block.
        head_dim: per-head dimension (d_model = n_heads * head_dim).
        d_ff: GeGLU hidden width.
        seq_len: training sequence length (tokens per row, excluding target
            shift; the Rust batcher feeds ``seq_len + 1`` token rows).
        batch_size: rows per train step.
        m_features: PRF feature budget m (number of random projections).
        r_proj: rank r of the learned re-embedding M (DARKFormer). We use
            r = head_dim so Sigma = M^T M can be full rank.
        rope_base: RoPE theta base.
        weight_decay: AdamW decoupled weight decay.
        adam_b1 / adam_b2 / adam_eps: AdamW moments.
    """

    name: str = "tiny"
    vocab_size: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 32
    d_ff: int = 128
    seq_len: int = 32
    batch_size: int = 2
    m_features: int = 16
    r_proj: int = 32
    rope_base: float = 10000.0
    weight_decay: float = 0.01
    adam_b1: float = 0.9
    adam_b2: float = 0.98
    adam_eps: float = 1e-9
    use_pallas: bool = True

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim, (
            f"d_model={self.d_model} != n_heads*head_dim="
            f"{self.n_heads * self.head_dim}"
        )
        assert self.r_proj <= self.head_dim

    def as_dict(self):
        return asdict(self)


#: Smoke-test scale: used by pytest and the Rust integration tests.
TINY = ModelConfig()

#: Experiment scale: all figure harnesses (Figs. 2-5) run at this size.
#: Chosen so a CPU-PJRT train step lands in the ~0.1-1s range, making a
#: few-hundred-step curve tractable while keeping enough capacity for the
#: variant ordering (exact > darkformer > performer > baselines) to emerge.
SMALL = ModelConfig(
    name="small",
    vocab_size=1024,
    d_model=128,
    n_layers=2,
    n_heads=4,
    head_dim=32,
    d_ff=512,
    seq_len=128,
    batch_size=8,
    m_features=32,
    r_proj=32,
)

#: Constrained-feature-budget variant of SMALL (m = head_dim / 4): the
#: regime the paper targets — the PRF approximation error dominates, so
#: sampling geometry matters most. Used by the sharpened Fig. 2/4 runs.
SMALL_M8 = ModelConfig(
    name="small_m8",
    vocab_size=1024,
    d_model=128,
    n_layers=2,
    n_heads=4,
    head_dim=32,
    d_ff=512,
    seq_len=128,
    batch_size=8,
    m_features=8,
    r_proj=32,
)

#: ~100M-parameter configuration mirroring the paper's Gemma setting in
#: structure (not size). Provided for completeness; the end-to-end driver
#: defaults to SMALL because CPU-PJRT throughput makes 100M-scale training
#: impractical in this testbed (see DESIGN.md section 2).
GEMMA100M = ModelConfig(
    name="gemma100m",
    vocab_size=32768,
    d_model=768,
    n_layers=12,
    n_heads=12,
    head_dim=64,
    d_ff=3072,
    seq_len=512,
    batch_size=8,
    m_features=128,
    r_proj=64,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, SMALL_M8, GEMMA100M)}


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = CONFIGS[name]
    return replace(cfg, **overrides) if overrides else cfg
