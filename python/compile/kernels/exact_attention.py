"""Tiled causal softmax attention (exact baseline) as a Pallas kernel.

Flash-attention-style streaming softmax: one program per (batch * head)
slice, an outer loop over query chunks and an inner loop over the key
chunks visible to that query chunk, carrying the running row-max,
denominator and output accumulator. Memory per program is O(C^2 + C d)
instead of O(L^2) — the standard IO-aware schedule of Dao et al.,
re-expressed as a Pallas grid + fori_loop for TPU (DESIGN.md section 6).

Forward = Pallas, backward = autodiff of the jnp oracle (ref.py) via
``jax.custom_vjp``, same contract as linear_attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_CHUNK = 32
NEG_INF = -1e30


def _causal_softmax_kernel(q_ref, k_ref, v_ref, out_ref, *, chunk):
    q = q_ref[0]  # (L, d)
    k = k_ref[0]
    v = v_ref[0]
    L, d = q.shape
    n_chunks = L // chunk

    # Strictly-lower+diag mask for the diagonal (i == j) block.
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diag_mask = row >= col

    def outer(i, _):
        qi = jax.lax.dynamic_slice(q, (i * chunk, 0), (chunk, d))

        def inner(j, carry):
            m_run, den, acc = carry
            kj = jax.lax.dynamic_slice(k, (j * chunk, 0), (chunk, d))
            vj = jax.lax.dynamic_slice(v, (j * chunk, 0), (chunk, d))
            s = qi @ kj.T  # (C, C)
            s = jnp.where((j == i) & ~diag_mask, NEG_INF, s)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            scale = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[:, None])
            den = den * scale + jnp.sum(p, axis=-1)
            acc = acc * scale[:, None] + p @ vj
            return (m_new, den, acc)

        m0 = jnp.full((chunk,), NEG_INF, dtype=q.dtype)
        den0 = jnp.zeros((chunk,), dtype=q.dtype)
        acc0 = jnp.zeros((chunk, d), dtype=q.dtype)
        m_run, den, acc = jax.lax.fori_loop(0, i + 1, inner, (m0, den0, acc0))
        out_ref[0, pl.ds(i * chunk, chunk), :] = acc / den[:, None]
        return 0

    jax.lax.fori_loop(0, n_chunks, outer, 0)


def _pallas_forward(q, k, v, chunk):
    batch_shape = q.shape[:-2]
    L, d = q.shape[-2:]
    bh = 1
    for s in batch_shape:
        bh *= s
    if L % chunk != 0:
        raise ValueError(f"sequence length {L} not divisible by chunk {chunk}")

    kernel = functools.partial(_causal_softmax_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, d), v.dtype),
        interpret=True,
    )(q.reshape(bh, L, d), k.reshape(bh, L, d), v.reshape(bh, L, d))
    return out.reshape(*batch_shape, L, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def causal_softmax_attention(q, k, v, chunk=DEFAULT_CHUNK):
    """Exact causal attention: Pallas tiled forward, oracle backward."""
    return _pallas_forward(q, k, v, chunk)


def _fwd(q, k, v, chunk):
    return _pallas_forward(q, k, v, chunk), (q, k, v)


def _bwd(chunk, residuals, g):
    q, k, v = residuals
    _, vjp = jax.vjp(ref.causal_softmax_attention_ref, q, k, v)
    return vjp(g)


causal_softmax_attention.defvjp(_fwd, _bwd)
