"""Chunked causal linear attention — the paper's compute hot-spot as a
Pallas kernel.

The random-feature attention path (Performer / DARKFormer) computes

    out_i = sum_{j<=i} (phi_q_i . phi_k_j) v_j / sum_{j<=i} phi_q_i . phi_k_j

in O(L m d) by carrying the running moment matrix ``S = sum phi_k v^T``
(m x d) and normalizer ``z = sum phi_k`` (m) across sequence chunks:
each chunk combines an intra-chunk masked quadratic term (C x C — small)
with an inter-chunk linear term against (S, z).

Hardware adaptation (see DESIGN.md section 6): the CUDA formulation of this
schedule assigns one threadblock per query block with the running state in
shared memory. On TPU the natural mapping is a Pallas grid over (batch x
head) programs with the chunk loop inside the kernel and (S, z) living in
VMEM registers/scratch; the three inner products per chunk —
phi_q_c @ phi_k_c^T (C x m)(m x C), A @ v_c (C x C)(C x d) and
phi_k_c^T @ v_c (m x C)(C x d) — are all MXU-shaped matmuls.

The kernel is lowered with ``interpret=True`` (the CPU PJRT client cannot
execute Mosaic custom-calls); correctness is pinned to the pure-jnp oracle
in ref.py, which also provides the backward rule via ``jax.custom_vjp``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_CHUNK = 32


def _causal_linear_attention_kernel(phi_q_ref, phi_k_ref, v_ref, out_ref, *, chunk):
    """Pallas kernel body: one program per (batch * head) slice.

    Refs are (1, L, m/d) blocks; the leading 1 is the grid-mapped axis.
    """
    phi_q = phi_q_ref[0]  # (L, m)
    phi_k = phi_k_ref[0]  # (L, m)
    v = v_ref[0]  # (L, d)
    L, m = phi_q.shape
    d = v.shape[-1]
    n_chunks = L // chunk

    # Lower-triangular mask for the intra-chunk quadratic term.
    tri = jnp.tril(jnp.ones((chunk, chunk), dtype=phi_q.dtype))

    def body(c, carry):
        s, z = carry  # s: (m, d) running sum phi_k v^T ; z: (m,) running sum phi_k
        start = c * chunk
        pq = jax.lax.dynamic_slice(phi_q, (start, 0), (chunk, m))
        pk = jax.lax.dynamic_slice(phi_k, (start, 0), (chunk, m))
        vc = jax.lax.dynamic_slice(v, (start, 0), (chunk, d))

        # Intra-chunk: masked (C x C) kernel block.
        a = (pq @ pk.T) * tri
        num = a @ vc + pq @ s
        den = jnp.sum(a, axis=-1) + pq @ z
        out_c = num / (den + ref.EPS)[:, None]

        out_ref[0, pl.ds(start, chunk), :] = out_c

        # Inter-chunk state update (the TPU analogue of the CUDA
        # shared-memory accumulator).
        s = s + pk.T @ vc
        z = z + jnp.sum(pk, axis=0)
        return (s, z)

    s0 = jnp.zeros((m, d), dtype=phi_q.dtype)
    z0 = jnp.zeros((m,), dtype=phi_q.dtype)
    jax.lax.fori_loop(0, n_chunks, body, (s0, z0))


def _pallas_forward(phi_q, phi_k, v, chunk):
    """Run the chunked kernel over (..., L, m/d) inputs."""
    batch_shape = phi_q.shape[:-2]
    L, m = phi_q.shape[-2:]
    d = v.shape[-1]
    bh = 1
    for s in batch_shape:
        bh *= s
    pq = phi_q.reshape(bh, L, m)
    pk = phi_k.reshape(bh, L, m)
    vv = v.reshape(bh, L, d)

    if L % chunk != 0:
        raise ValueError(f"sequence length {L} not divisible by chunk {chunk}")

    kernel = functools.partial(_causal_linear_attention_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, L, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, d), v.dtype),
        interpret=True,
    )(pq, pk, vv)
    return out.reshape(*batch_shape, L, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def causal_linear_attention(phi_q, phi_k, v, chunk=DEFAULT_CHUNK):
    """Causal linear attention with a Pallas forward and oracle backward.

    Numerically identical (to float tolerance) to
    ``ref.causal_linear_attention_ref``; the backward pass differentiates
    the oracle, so gradients are consistent with the forward values.
    """
    return _pallas_forward(phi_q, phi_k, v, chunk)


def _fwd(phi_q, phi_k, v, chunk):
    return _pallas_forward(phi_q, phi_k, v, chunk), (phi_q, phi_k, v)


def _bwd(chunk, residuals, g):
    phi_q, phi_k, v = residuals
    _, vjp = jax.vjp(ref.causal_linear_attention_ref, phi_q, phi_k, v)
    return vjp(g)


causal_linear_attention.defvjp(_fwd, _bwd)
