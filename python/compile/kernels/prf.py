"""Positive-random-feature maps: isotropic (Performer), data-aware
(DARKFormer) and learned (LFK).

The central implementation identity (paper App. B, derivation of Eq. 3):
with Sigma = M^T M and omega~ = M^T w, w ~ N(0, I_r),

    phi_Sigma(x, omega~) = exp(omega~^T x - 1/2 x^T Sigma x)
                         = exp(w^T (M x) - 1/2 ||M x||^2)
                         = phi_plus(M x, w)

so DARKFormer's data-aware PRF is exactly the isotropic PRF applied to the
re-embedded inputs M q, M k — differentiable through M. This module
implements the stabilized phi_plus and the re-embedding; the model picks
M trainable (darkformer), M = I frozen (performer), or replaces w with a
trainable omega (lfk).

Stabilization: queries subtract a per-token max over the feature axis —
a per-row multiplicative constant that depends only on that query, so it
is *causal* and cancels exactly in the attention normalizer. Keys must
NOT subtract a data-dependent max: a max over positions would leak future
keys into past outputs (breaking causality) and a per-key max would bias
the kernel. Instead key logits get a fixed overflow clamp that is inert
in normal operation (logit = omega.x - |x|^2/2 <= |omega|^2/2, small for
RMSNorm-scale inputs) and merely guards exp() in pathological regimes.
"""

import jax
import jax.numpy as jnp

#: Key-logit overflow guard: exp(KEY_LOGIT_CAP) and its squares must stay
#: comfortably inside f32 range. exp(30) ~ 1e13.
KEY_LOGIT_CAP = 30.0


def prf_features(x, omega, is_query):
    """Stabilized positive random features, m^{-1/2} exp(omega x - |x|^2/2 - c).

    Args:
        x: (..., L, d) inputs with attention scaling absorbed.
        omega: (..., m, d) projection vectors (broadcast against x's batch
            dims; typically (h, m, d) against (b, h, L, d)).
        is_query: queries subtract a per-token max (causal, cancels in the
            normalizer); keys are clamped at ``KEY_LOGIT_CAP`` only.

    Returns:
        (..., L, m) strictly positive features.
    """
    m = omega.shape[-2]
    proj = jnp.einsum("...ld,...md->...lm", x, omega)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    logits = proj - sq
    if is_query:
        stab = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        logits = logits - stab
    else:
        logits = jnp.minimum(logits, KEY_LOGIT_CAP)
    return jnp.exp(logits) / jnp.sqrt(m)


def reembed(x, m_proj):
    """Apply the learned re-embedding x -> M x per head.

    Args:
        x: (b, h, L, d) queries or keys.
        m_proj: (h, r, d) per-head re-embedding matrices.

    Returns:
        (b, h, L, r) re-embedded inputs.
    """
    return jnp.einsum("bhld,hrd->bhlr", x, m_proj)


def draw_noise(key, n_layers, n_heads, m, r, dtype=jnp.float32):
    """Standard Gaussian projection noise w ~ N(0, I_r), fresh per step.

    Shape (n_layers, n_heads, m, r): independent projections per layer and
    head. The host (Rust coordinator) supplies only the PRNG key; the draw
    itself lowers into the train-step HLO so resampling costs no extra
    host round-trip.
    """
    return jax.random.normal(key, (n_layers, n_heads, m, r), dtype=dtype)
