"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness signal: each Pallas kernel in this package is
tested (pytest + hypothesis) against the oracle here with
``assert_allclose``. They are also the backward rule: the Pallas forward
kernels install a ``jax.custom_vjp`` whose backward pass differentiates
these references (see linear_attention.py / exact_attention.py), so a
train step that runs the Pallas forward produces gradients consistent
with the oracle.

All oracles materialize the full L x L interaction matrix — O(L^2) time
and memory — which is exactly the cost the paper's random-feature path
avoids.
"""

import jax.numpy as jnp

EPS = 1e-6


def prf_features_ref(x, omega, stabilizer=None):
    """Positive random features phi+ of Choromanski et al. (Eq. 1).

    phi(x)_j = m^{-1/2} * exp(omega_j^T x - ||x||^2 / 2 - stabilizer)

    Args:
        x: (..., L, d) inputs (queries or keys, scaling already absorbed).
        omega: (m, d) projection vectors.
        stabilizer: optional broadcastable log-space shift. The attention
            normalization cancels any per-query constant; per-key constants
            must be shared across keys (a global max) to stay exact.

    Returns:
        (..., L, m) non-negative features.
    """
    m = omega.shape[0]
    proj = jnp.einsum("...ld,md->...lm", x, omega)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    logits = proj - sq
    if stabilizer is not None:
        logits = logits - stabilizer
    return jnp.exp(logits) / jnp.sqrt(m)


def softmax_kernel_ref(q, k):
    """Exact (unnormalized) softmax kernel exp(q_i . k_j), (..., L, L)."""
    return jnp.exp(jnp.einsum("...id,...jd->...ij", q, k))


def causal_linear_attention_ref(phi_q, phi_k, v):
    """Naive causal linear attention via the explicit L x L kernel matrix.

    out_i = sum_{j<=i} (phi_q_i . phi_k_j) v_j / (sum_{j<=i} phi_q_i . phi_k_j)

    Args:
        phi_q, phi_k: (..., L, m) feature maps.
        v: (..., L, d) values.

    Returns:
        (..., L, d) attention output.
    """
    L = phi_q.shape[-2]
    a = jnp.einsum("...im,...jm->...ij", phi_q, phi_k)
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    a = jnp.where(mask, a, 0.0)
    num = jnp.einsum("...ij,...jd->...id", a, v)
    den = jnp.sum(a, axis=-1, keepdims=True)
    return num / (den + EPS)


def causal_softmax_attention_ref(q, k, v):
    """Exact causal softmax attention (scaling absorbed into q)."""
    L = q.shape[-2]
    scores = jnp.einsum("...id,...jd->...ij", q, k)
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("...ij,...jd->...id", w, v)
