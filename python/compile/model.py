"""Gemma-style decoder-only transformer with pluggable attention (L2).

Structure mirrors the paper's Gemma testbed: RMSNorm pre-norms, rotary
position embeddings, multi-head attention, GeGLU MLP, tied input/output
embeddings. The attention mechanism is selected per DESIGN.md:

    exact       causal softmax (Pallas tiled kernel)
    performer   isotropic PRF linear attention  (Choromanski et al. 2021)
    darkformer  data-aware PRF linear attention on re-embedded M q, M k
                with trainable per-head M  (this paper)
    lfk         learned feature kernel: trainable projections omega
    random      rank-free random attention weights (paper baseline)
    constant    uniform causal attention (paper baseline)

Parameters live in a *flat* ``dict[str, Array]``; sorted key order is the
canonical flattening used by the AOT manifest and the Rust runtime.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, VARIANTS
from .kernels import prf
from .kernels import ref as kref
from .kernels.exact_attention import causal_softmax_attention
from .kernels.linear_attention import causal_linear_attention

RMS_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig, variant: str):
    """Flat name -> shape spec for a variant. Sorted names define the
    canonical argument order everywhere (manifest, checkpoints, runtime)."""
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    d, h, dh, ff, r, m = (
        cfg.d_model,
        cfg.n_heads,
        cfg.head_dim,
        cfg.d_ff,
        cfg.r_proj,
        cfg.m_features,
    )
    spec = {"emb": (cfg.vocab_size, d), "final_norm": (d,)}
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        spec[p + "ln1"] = (d,)
        spec[p + "ln2"] = (d,)
        spec[p + "attn.wq"] = (d, h * dh)
        spec[p + "attn.wk"] = (d, h * dh)
        spec[p + "attn.wv"] = (d, h * dh)
        spec[p + "attn.wo"] = (h * dh, d)
        if variant == "darkformer":
            spec[p + "attn.m_proj"] = (h, r, dh)
        if variant == "lfk":
            spec[p + "attn.omega"] = (h, m, dh)
        spec[p + "mlp.wg"] = (d, ff)
        spec[p + "mlp.wu"] = (d, ff)
        spec[p + "mlp.wd"] = (ff, d)
    return spec


def init_params(key, cfg: ModelConfig, variant: str):
    """Initialize the flat parameter dict.

    Linear weights are LeCun-normal; norms start at 1; DARKFormer's M
    starts at (truncated) identity so it is exactly a Performer at step 0
    and *learns* to depart toward the whitening geometry; LFK's omega
    starts as a fixed Gaussian draw (a frozen-at-init Performer).
    """
    spec = param_spec(cfg, variant)
    params = {}
    names = sorted(spec)
    keys = jax.random.split(key, len(names))
    for name, k in zip(names, keys):
        shape = spec[name]
        if name.endswith(("ln1", "ln2")) or name == "final_norm":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("m_proj"):
            eye = jnp.eye(cfg.head_dim, dtype=jnp.float32)[: cfg.r_proj]
            params[name] = jnp.broadcast_to(eye, shape).copy()
        elif name.endswith("omega"):
            params[name] = jax.random.normal(k, shape, jnp.float32)
        elif name == "emb":
            params[name] = jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
                float(cfg.d_model)
            )
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            params[name] = jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
                float(fan_in)
            )
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, gain):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * gain


def rope(x, base):
    """Rotary position embedding over the last axis of (b, h, L, dh)."""
    L, dh = x.shape[-2], x.shape[-1]
    half = dh // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = jnp.arange(L, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _split_heads(x, h, dh):
    b, L, _ = x.shape
    return x.reshape(b, L, h, dh).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, L, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, L, h * dh)


# ---------------------------------------------------------------------------
# Attention variants
# ---------------------------------------------------------------------------


def _linear_attention(phi_q, phi_k, v, cfg: ModelConfig):
    if cfg.use_pallas:
        chunk = min(32, cfg.seq_len)
        return causal_linear_attention(phi_q, phi_k, v, chunk)
    return kref.causal_linear_attention_ref(phi_q, phi_k, v)


def attention(q, k, v, *, variant, cfg: ModelConfig, params, prefix, key):
    """Dispatch one layer's attention. q, k, v: (b, h, L, dh)."""
    scale = cfg.head_dim ** -0.25  # split the 1/sqrt(dh) between q and k
    qs, ks = q * scale, k * scale

    if variant == "exact":
        if cfg.use_pallas:
            chunk = min(32, cfg.seq_len)
            return causal_softmax_attention(qs, ks, v, chunk)
        return kref.causal_softmax_attention_ref(qs, ks, v)

    if variant in ("performer", "darkformer"):
        # Fresh isotropic base noise every step; DARKFormer re-embeds the
        # inputs through its learned M, realizing omega~ ~ N(0, M^T M)
        # (paper Eq. 3 via the identity phi_Sigma(x) = phi+(Mx)).
        w = jax.random.normal(
            key, (cfg.n_heads, cfg.m_features, cfg.r_proj), jnp.float32
        )
        if variant == "darkformer":
            m_proj = params[prefix + "attn.m_proj"]  # (h, r, dh)
            qs = prf.reembed(qs, m_proj)
            ks = prf.reembed(ks, m_proj)
        phi_q = prf.prf_features(qs, w[None], is_query=True)
        phi_k = prf.prf_features(ks, w[None], is_query=False)
        return _linear_attention(phi_q, phi_k, v, cfg)

    if variant == "lfk":
        omega = params[prefix + "attn.omega"]  # (h, m, dh) trainable
        phi_q = prf.prf_features(qs, omega[None], is_query=True)
        phi_k = prf.prf_features(ks, omega[None], is_query=False)
        return _linear_attention(phi_q, phi_k, v, cfg)

    if variant == "random":
        b, h, L, _ = q.shape
        scores = jax.random.normal(key, (h, L, L), jnp.float32)
        mask = jnp.tril(jnp.ones((L, L), dtype=bool))
        scores = jnp.where(mask, scores, -jnp.inf)
        wgt = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("hij,bhjd->bhid", wgt, v)

    if variant == "constant":
        L = v.shape[-2]
        csum = jnp.cumsum(v, axis=-2)
        counts = jnp.arange(1, L + 1, dtype=v.dtype)[:, None]
        return csum / counts

    raise ValueError(f"unknown variant {variant!r}")


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward(params, tokens, key, *, cfg: ModelConfig, variant: str):
    """Next-token logits.

    Args:
        params: flat dict (see param_spec).
        tokens: (b, T) int32 input token ids.
        key: PRNG key driving PRF resampling / random baseline.

    Returns:
        (b, T, vocab) float32 logits.
    """
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["emb"][tokens] * jnp.sqrt(float(cfg.d_model))
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        lkey = jax.random.fold_in(key, i)
        y = rms_norm(x, params[p + "ln1"])
        q = _split_heads(y @ params[p + "attn.wq"], h, dh)
        k = _split_heads(y @ params[p + "attn.wk"], h, dh)
        v = _split_heads(y @ params[p + "attn.wv"], h, dh)
        q = rope(q, cfg.rope_base)
        k = rope(k, cfg.rope_base)
        o = attention(
            q, k, v, variant=variant, cfg=cfg, params=params, prefix=p, key=lkey
        )
        x = x + _merge_heads(o) @ params[p + "attn.wo"]
        y = rms_norm(x, params[p + "ln2"])
        g = jax.nn.gelu(y @ params[p + "mlp.wg"])
        x = x + (g * (y @ params[p + "mlp.wu"])) @ params[p + "mlp.wd"]
    x = rms_norm(x, params["final_norm"])
    return x @ params["emb"].T
