"""Training/eval steps lowered to HLO: loss, AdamW, gradient masking.

No optax in this image — AdamW is implemented directly (decoupled weight
decay, bias correction, optional global-norm clipping). Learning rate and
clip threshold are *runtime scalars*, so a single lowered artifact serves
every point of the Fig. 5 learning-rate sweep and every LR schedule the
Rust coordinator implements.

Argument order contract with the Rust runtime (see aot.py manifest):
flat params / opt_m / opt_v in sorted-name order, then tokens, key, lr,
clip, step. Dict flattening in jax is sorted-key, matching the manifest.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import forward

CLIP_EPS = 1e-8


def loss_and_accuracy(params, tokens, key, *, cfg: ModelConfig, variant: str):
    """Next-token cross-entropy (nats/token) and argmax accuracy.

    tokens: (b, seq_len + 1) int32; inputs are tokens[:, :-1], targets
    tokens[:, 1:].
    """
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inputs, key, cfg=cfg, variant=variant)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - tgt_logit)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return loss, acc


def qkv_mask(params, variant: str):
    """Fig. 4 trainability mask: 1.0 for q/k/v projections and (DARKFormer)
    the PRF covariance parameter M; 0.0 for everything else."""
    trainable_suffixes = ("attn.wq", "attn.wk", "attn.wv", "attn.m_proj")
    return {
        name: jnp.float32(1.0 if name.endswith(trainable_suffixes) else 0.0)
        for name in params
    }


def adamw_update(params, grads, opt_m, opt_v, *, lr, clip, step, mask, cfg):
    """One AdamW step. ``mask`` gates both the gradient and weight decay,
    so frozen parameters are bit-identical across steps."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in grads.values()) + CLIP_EPS
    )
    # clip <= 0 disables clipping (Fig. 5 stability runs want raw updates).
    factor = jnp.where(clip > 0.0, jnp.minimum(1.0, clip / gnorm), 1.0)

    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.adam_b1 ** t
    bc2 = 1.0 - cfg.adam_b2 ** t

    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name] * factor * mask[name]
        m = cfg.adam_b1 * opt_m[name] + (1.0 - cfg.adam_b1) * g
        v = cfg.adam_b2 * opt_v[name] + (1.0 - cfg.adam_b2) * (g * g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.adam_eps)
        decay = cfg.weight_decay * params[name] * mask[name]
        new_p[name] = params[name] - lr * (update + decay)
        new_m[name] = m
        new_v[name] = v
    return new_p, new_m, new_v, gnorm


def make_train_step(cfg: ModelConfig, variant: str, qkv_only: bool = False):
    """Build the jittable train step for AOT lowering.

    Signature: (params, opt_m, opt_v, tokens, key, lr, clip, step)
             -> (params, opt_m, opt_v, loss, acc, gnorm)
    """

    def train_step(params, opt_m, opt_v, tokens, key, lr, clip, step):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: loss_and_accuracy(
                p, tokens, key, cfg=cfg, variant=variant
            ),
            has_aux=True,
        )(params)
        mask = (
            qkv_mask(params, variant)
            if qkv_only
            else {n: jnp.float32(1.0) for n in params}
        )
        params, opt_m, opt_v, gnorm = adamw_update(
            params, grads, opt_m, opt_v,
            lr=lr, clip=clip, step=step, mask=mask, cfg=cfg,
        )
        return params, opt_m, opt_v, loss, acc, gnorm

    return train_step


def make_eval_step(cfg: ModelConfig, variant: str):
    """(params, tokens, key) -> (loss, acc)."""

    def eval_step(params, tokens, key):
        return loss_and_accuracy(params, tokens, key, cfg=cfg, variant=variant)

    return eval_step


def make_init(cfg: ModelConfig, variant: str):
    """(key,) -> params (flat dict, sorted-name order when flattened)."""
    from .model import init_params

    def init(key):
        return init_params(key, cfg, variant)

    return init
