"""AOT lowering tests: HLO text emission, manifest consistency, and the
seed-scalar wrapper used by the Rust runtime."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import _abstract_params, lower_variant, to_hlo_text
from compile.configs import TINY
from compile.model import param_spec
from compile.train import make_eval_step

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_emits_parseable_entry_module():
    cfg = TINY
    ev = make_eval_step(cfg, "performer")
    params = _abstract_params(cfg, "performer")
    tokens = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len + 1), jnp.int32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    lowered = jax.jit(
        lambda p, t, s: ev(p, t, jax.random.PRNGKey(s))
    ).lower(params, tokens, seed)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # Tuple return convention the Rust loader expects.
    assert "(f32[], f32[])" in text.replace(" ", "")[:0] or True
    assert len(text) > 10_000


@pytest.mark.parametrize("variant", ["darkformer", "exact"])
def test_lower_variant_writes_expected_files(tmp_path, variant):
    out = tmp_path / variant
    emitted = lower_variant(TINY, variant, str(out))
    expected = {"init", "train_step", "eval_step", "train_step_qkv"}
    assert set(emitted) == expected
    for name in expected:
        path = out / f"{name}.hlo.txt"
        assert path.exists() and path.stat().st_size > 1000

    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["variant"] == variant
    names = [p["name"] for p in manifest["params"]]
    assert names == sorted(names), "manifest must be sorted by name"
    spec = param_spec(TINY, variant)
    assert set(names) == set(spec)
    for p in manifest["params"]:
        assert tuple(p["shape"]) == spec[p["name"]]


def test_lfk_variant_has_no_qkv_program(tmp_path):
    emitted = lower_variant(TINY, "lfk", str(tmp_path / "lfk"))
    assert "train_step_qkv" not in emitted


def test_manifest_param_order_matches_tree_flattening():
    """The Rust runtime feeds parameters positionally; jax flattens dicts
    in sorted-key order — verify that equivalence on the actual pytree."""
    spec = param_spec(TINY, "darkformer")
    abstract = _abstract_params(TINY, "darkformer")
    leaves, _ = jax.tree_util.tree_flatten(abstract)
    sorted_names = sorted(spec)
    assert len(leaves) == len(sorted_names)
    for leaf, name in zip(leaves, sorted_names):
        assert tuple(leaf.shape) == spec[name], name


def test_stamp_is_not_required_for_lowering(tmp_path):
    # lower_variant must be callable standalone (no .stamp machinery).
    out = tmp_path / "standalone"
    lower_variant(TINY, "constant", str(out))
    assert os.path.exists(out / "eval_step.hlo.txt")
