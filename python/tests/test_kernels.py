"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes; fixed-seed numpy draws give the values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.exact_attention import causal_softmax_attention
from compile.kernels.linear_attention import causal_linear_attention

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


# ---------------------------------------------------------------------
# Chunked causal linear attention
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    lc=st.sampled_from([(32, 32), (64, 32), (64, 16), (128, 32)]),
    m=st.sampled_from([8, 16, 33]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_linear_attention_matches_ref(b, h, lc, m, d, seed):
    L, chunk = lc
    phi_q = jnp.abs(rand((b, h, L, m), seed)) + 1e-3
    phi_k = jnp.abs(rand((b, h, L, m), seed + 1)) + 1e-3
    v = rand((b, h, L, d), seed + 2)
    out = causal_linear_attention(phi_q, phi_k, v, chunk)
    expected = ref.causal_linear_attention_ref(phi_q, phi_k, v)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-5)


def test_linear_attention_rejects_bad_chunk():
    x = jnp.ones((1, 1, 30, 4))
    with pytest.raises(ValueError, match="not divisible"):
        causal_linear_attention(x, x, jnp.ones((1, 1, 30, 4)), 16)


def test_linear_attention_first_token_is_v0():
    # Causality base case: output at position 0 equals v_0 exactly
    # (single key in the prefix, normalization cancels).
    phi_q = jnp.abs(rand((1, 1, 32, 8), 3)) + 1e-3
    phi_k = jnp.abs(rand((1, 1, 32, 8), 4)) + 1e-3
    v = rand((1, 1, 32, 4), 5)
    out = causal_linear_attention(phi_q, phi_k, v, 16)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=2e-4, atol=1e-5)


def test_linear_attention_is_causal():
    # Perturbing a future key/value must not change earlier outputs.
    phi_q = jnp.abs(rand((1, 1, 64, 8), 7)) + 1e-3
    phi_k = jnp.abs(rand((1, 1, 64, 8), 8)) + 1e-3
    v = rand((1, 1, 64, 4), 9)
    base = causal_linear_attention(phi_q, phi_k, v, 16)
    v2 = v.at[0, 0, 40].set(100.0)
    pk2 = phi_k.at[0, 0, 40].set(5.0)
    out2 = causal_linear_attention(phi_q, pk2, v2, 16)
    np.testing.assert_allclose(base[0, 0, :40], out2[0, 0, :40], rtol=1e-5)
    assert not np.allclose(base[0, 0, 40:], out2[0, 0, 40:])


def test_linear_attention_gradients_match_ref():
    phi_q = jnp.abs(rand((1, 2, 32, 8), 11)) + 1e-3
    phi_k = jnp.abs(rand((1, 2, 32, 8), 12)) + 1e-3
    v = rand((1, 2, 32, 8), 13)

    def loss_pallas(pq, pk, vv):
        return jnp.sum(causal_linear_attention(pq, pk, vv, 16) ** 2)

    def loss_ref(pq, pk, vv):
        return jnp.sum(ref.causal_linear_attention_ref(pq, pk, vv) ** 2)

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(phi_q, phi_k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(phi_q, phi_k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-5)


# ---------------------------------------------------------------------
# Tiled causal softmax attention
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 4),
    lc=st.sampled_from([(32, 32), (64, 32), (64, 16), (128, 32)]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_exact_attention_matches_ref(b, h, lc, d, seed):
    L, chunk = lc
    q = rand((b, h, L, d), seed)
    k = rand((b, h, L, d), seed + 1)
    v = rand((b, h, L, d), seed + 2)
    out = causal_softmax_attention(q, k, v, chunk)
    expected = ref.causal_softmax_attention_ref(q, k, v)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-5)


def test_exact_attention_handles_large_scores():
    # Streaming-softmax stability: logits ~ +-40 must not overflow.
    q = rand((1, 1, 64, 16), 21, scale=5.0)
    k = rand((1, 1, 64, 16), 22, scale=5.0)
    v = rand((1, 1, 64, 16), 23)
    out = causal_softmax_attention(q, k, v, 16)
    assert bool(jnp.all(jnp.isfinite(out)))
    expected = ref.causal_softmax_attention_ref(q, k, v)
    np.testing.assert_allclose(out, expected, rtol=1e-3, atol=1e-4)


def test_exact_attention_is_causal():
    q = rand((1, 1, 64, 8), 31)
    k = rand((1, 1, 64, 8), 32)
    v = rand((1, 1, 64, 8), 33)
    base = causal_softmax_attention(q, k, v, 16)
    v2 = v.at[0, 0, 50].set(9.0)
    out2 = causal_softmax_attention(q, k, v2, 16)
    np.testing.assert_allclose(base[0, 0, :50], out2[0, 0, :50], rtol=1e-5)


def test_exact_attention_gradients_match_ref():
    q = rand((1, 1, 32, 8), 41)
    k = rand((1, 1, 32, 8), 42)
    v = rand((1, 1, 32, 8), 43)

    def loss_pallas(a, b, c):
        return jnp.sum(causal_softmax_attention(a, b, c, 16) ** 3)

    def loss_ref(a, b, c):
        return jnp.sum(ref.causal_softmax_attention_ref(a, b, c) ** 3)

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-5)


def test_uniform_values_passthrough():
    # With all values equal, attention output equals that value everywhere
    # regardless of the weights — sanity for both kernels.
    q = rand((1, 1, 32, 8), 51)
    k = rand((1, 1, 32, 8), 52)
    v = jnp.ones((1, 1, 32, 8), jnp.float32) * 2.5
    out = causal_softmax_attention(q, k, v, 16)
    np.testing.assert_allclose(out, 2.5, rtol=1e-5)
    phi = jnp.abs(q) + 1e-3
    out2 = causal_linear_attention(phi, phi, v, 16)
    np.testing.assert_allclose(out2, 2.5, rtol=1e-4)
