"""L2 model tests: shapes, causality, variant behaviour, init geometry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY, VARIANTS, get_config
from compile.model import forward, init_params, param_spec

jax.config.update("jax_platform_name", "cpu")

CFG = TINY
KEY = jax.random.PRNGKey(0)


def tokens(b, t, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, CFG.vocab_size)


@pytest.mark.parametrize("variant", VARIANTS)
def test_forward_shapes(variant):
    params = init_params(KEY, CFG, variant)
    tok = tokens(2, CFG.seq_len)
    logits = forward(params, tok, KEY, cfg=CFG, variant=variant)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("variant", VARIANTS)
def test_causality(variant):
    """Changing a future token must not change earlier logits."""
    params = init_params(KEY, CFG, variant)
    tok = tokens(1, CFG.seq_len, seed=3)
    cut = CFG.seq_len // 2
    logits1 = forward(params, tok, KEY, cfg=CFG, variant=variant)
    tok2 = tok.at[0, cut:].set((tok[0, cut:] + 1) % CFG.vocab_size)
    logits2 = forward(params, tok2, KEY, cfg=CFG, variant=variant)
    np.testing.assert_allclose(
        logits1[0, : cut - 1], logits2[0, : cut - 1], rtol=2e-3, atol=2e-4
    )


def test_param_spec_variant_extras():
    base = set(param_spec(CFG, "exact"))
    dark = set(param_spec(CFG, "darkformer"))
    lfk = set(param_spec(CFG, "lfk"))
    extra_dark = dark - base
    extra_lfk = lfk - base
    assert all(n.endswith("m_proj") for n in extra_dark)
    assert len(extra_dark) == CFG.n_layers
    assert all(n.endswith("omega") for n in extra_lfk)


def test_param_spec_rejects_unknown_variant():
    with pytest.raises(ValueError):
        param_spec(CFG, "bogus")


def test_darkformer_m_initialized_to_identity():
    params = init_params(KEY, CFG, "darkformer")
    m = params["layer00.attn.m_proj"]
    eye = jnp.eye(CFG.head_dim)[: CFG.r_proj]
    for h in range(CFG.n_heads):
        np.testing.assert_array_equal(m[h], eye)


def test_darkformer_at_identity_matches_performer():
    """With M = I (its init), DARKFormer must compute exactly what
    Performer computes under the same key: it *is* a Performer at step 0."""
    p_dark = init_params(KEY, CFG, "darkformer")
    p_perf = {k: v for k, v in p_dark.items() if not k.endswith("m_proj")}
    tok = tokens(1, CFG.seq_len, seed=5)
    out_dark = forward(p_dark, tok, KEY, cfg=CFG, variant="darkformer")
    out_perf = forward(p_perf, tok, KEY, cfg=CFG, variant="performer")
    np.testing.assert_allclose(out_dark, out_perf, rtol=1e-4, atol=1e-5)


def test_performer_approximates_exact_attention():
    """With a large feature budget the PRF logits should correlate tightly
    with exact-softmax logits (same weights)."""
    big = get_config("tiny", m_features=512)
    params = init_params(KEY, big, "exact")
    tok = tokens(1, big.seq_len, seed=7)
    exact = forward(params, tok, KEY, cfg=big, variant="exact")
    perf = forward(params, tok, KEY, cfg=big, variant="performer")
    corr = np.corrcoef(np.ravel(exact), np.ravel(perf))[0, 1]
    assert corr > 0.9, f"corr={corr}"
    err = float(jnp.mean((exact - perf) ** 2) / jnp.mean(exact**2))
    assert err < 0.25, f"relative mse={err}"


def test_prf_variants_use_fresh_noise_per_key():
    params = init_params(KEY, CFG, "performer")
    tok = tokens(1, CFG.seq_len, seed=9)
    out1 = forward(params, tok, jax.random.PRNGKey(1), cfg=CFG, variant="performer")
    out2 = forward(params, tok, jax.random.PRNGKey(2), cfg=CFG, variant="performer")
    assert not np.allclose(out1, out2), "different keys must resample features"


def test_constant_variant_ignores_queries():
    params = init_params(KEY, CFG, "constant")
    tok = tokens(1, CFG.seq_len, seed=11)
    out1 = forward(params, tok, KEY, cfg=CFG, variant="constant")
    p2 = dict(params)
    p2["layer00.attn.wq"] = params["layer00.attn.wq"] * 3.0
    out2 = forward(p2, tok, KEY, cfg=CFG, variant="constant")
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_use_pallas_false_matches_pallas_path():
    ref_cfg = get_config("tiny", use_pallas=False)
    params = init_params(KEY, CFG, "exact")
    tok = tokens(1, CFG.seq_len, seed=13)
    out_pallas = forward(params, tok, KEY, cfg=CFG, variant="exact")
    out_ref = forward(params, tok, KEY, cfg=ref_cfg, variant="exact")
    np.testing.assert_allclose(out_pallas, out_ref, rtol=2e-4, atol=2e-5)
