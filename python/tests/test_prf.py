"""PRF feature-map properties: unbiasedness, the DARKFormer re-embedding
identity (paper Eq. 3 via App. B), and stabilizer exactness."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import prf, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


def test_prf_is_unbiased_for_softmax_kernel():
    # E_omega[phi(q) . phi(k)] = exp(q . k): check with a large draw.
    d, m = 4, 200_000
    q = rand((1, d), 1)
    k = rand((1, d), 2)
    omega = rand((m, d), 3, scale=1.0)
    phi_q = ref.prf_features_ref(q, omega)
    phi_k = ref.prf_features_ref(k, omega)
    est = float((phi_q @ phi_k.T)[0, 0])
    exact = float(jnp.exp(q @ k.T)[0, 0])
    assert abs(est - exact) / exact < 0.02, (est, exact)


def test_darkformer_identity_phi_sigma_equals_phi_of_mx():
    """phi_Sigma(x, M^T w) == phi+(Mx, w): the implementation identity that
    lets DARKFormer reuse the standard PRF pipeline (App. B)."""
    d, r, m = 6, 6, 32
    x = rand((5, d), 11)
    m_mat = rand((r, d), 12)
    w = rand((m, r), 13, scale=1.0)

    # Left side: features of x with omega~ = M^T w and Mahalanobis h.
    omega_tilde = w @ m_mat  # (m, d)
    sigma = m_mat.T @ m_mat
    proj = x @ omega_tilde.T
    quad = 0.5 * jnp.einsum("ld,de,le->l", x, sigma, x)[:, None]
    lhs = jnp.exp(proj - quad) / jnp.sqrt(m)

    # Right side: standard PRF of the re-embedded inputs.
    rhs = ref.prf_features_ref(x @ m_mat.T, w)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)


def test_data_aware_estimator_unbiased_for_sigma_kernel():
    d, m = 4, 200_000
    q = rand((1, d), 21)
    k = rand((1, d), 22)
    m_mat = 0.3 * rand((d, d), 23) + 0.8 * jnp.eye(d)
    sigma = m_mat.T @ m_mat
    w = rand((m, d), 24, scale=1.0)
    phi_q = ref.prf_features_ref(q @ m_mat.T, w)
    phi_k = ref.prf_features_ref(k @ m_mat.T, w)
    est = float((phi_q @ phi_k.T)[0, 0])
    exact = float(jnp.exp(q @ sigma @ k.T)[0, 0])
    assert abs(est - exact) / exact < 0.03, (est, exact)


@settings(max_examples=15, deadline=None)
@given(
    L=st.integers(2, 16),
    d=st.sampled_from([4, 8, 16]),
    m=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_stabilizers_cancel_in_attention(L, d, m, seed):
    """Normalized attention weights computed from stabilized features must
    equal weights from unstabilized features: per-query shifts cancel in
    the normalizer and the global key shift is key-uniform."""
    q = rand((1, 1, L, d), seed)
    k = rand((1, 1, L, d), seed + 1)
    omega = rand((1, 1, m, d), seed + 2, scale=1.0)

    phi_q_s = prf.prf_features(q, omega, is_query=True)
    phi_k_s = prf.prf_features(k, omega, is_query=False)
    phi_q_u = ref.prf_features_ref(q, omega[0, 0])
    phi_k_u = ref.prf_features_ref(k, omega[0, 0])

    def attn_weights(pq, pk):
        a = jnp.einsum("...im,...jm->...ij", pq, pk)
        mask = jnp.tril(jnp.ones((L, L), dtype=bool))
        a = jnp.where(mask, a, 0.0)
        return a / (jnp.sum(a, axis=-1, keepdims=True) + 1e-30)

    np.testing.assert_allclose(
        attn_weights(phi_q_s, phi_k_s),
        attn_weights(phi_q_u, phi_k_u),
        rtol=2e-3,
        atol=1e-5,
    )


def test_prf_features_positive_and_finite_under_extreme_inputs():
    x = rand((1, 1, 8, 16), 31, scale=8.0)  # big norms would overflow naive exp
    omega = rand((1, 1, 64, 16), 32, scale=1.0)
    feats = prf.prf_features(x, omega, is_query=True)
    assert bool(jnp.all(jnp.isfinite(feats)))
    assert bool(jnp.all(feats >= 0))


def test_reembed_shapes_and_identity():
    x = rand((2, 3, 5, 8), 41)
    eye = jnp.broadcast_to(jnp.eye(8), (3, 8, 8))
    np.testing.assert_allclose(prf.reembed(x, eye), x, rtol=1e-6)
    m_rect = rand((3, 4, 8), 42)
    assert prf.reembed(x, m_rect).shape == (2, 3, 5, 4)


def test_draw_noise_is_key_deterministic():
    k = jax.random.PRNGKey(0)
    a = prf.draw_noise(k, 2, 3, 4, 5)
    b = prf.draw_noise(k, 2, 3, 4, 5)
    assert a.shape == (2, 3, 4, 5)
    np.testing.assert_array_equal(a, b)
    c = prf.draw_noise(jax.random.PRNGKey(1), 2, 3, 4, 5)
    assert not np.allclose(a, c)
