"""Training-step tests: loss decreases, AdamW semantics, gradient masks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY, QKV_VARIANTS
from compile.model import init_params
from compile.train import (
    loss_and_accuracy,
    make_eval_step,
    make_train_step,
    qkv_mask,
)

jax.config.update("jax_platform_name", "cpu")

CFG = TINY
KEY = jax.random.PRNGKey(0)


def batch(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed),
        (CFG.batch_size, CFG.seq_len + 1),
        0,
        CFG.vocab_size,
    )


def zeros_like(params):
    return {k: jnp.zeros_like(v) for k, v in params.items()}


def run_steps(variant, n, lr=3e-3, clip=1.0, qkv_only=False, tok=None):
    params = init_params(KEY, CFG, variant)
    m, v = zeros_like(params), zeros_like(params)
    step_fn = jax.jit(make_train_step(CFG, variant, qkv_only=qkv_only))
    tok = batch() if tok is None else tok
    losses, accs = [], []
    for i in range(n):
        key = jax.random.PRNGKey(100 + i)
        params, m, v, loss, acc, gnorm = step_fn(
            params, m, v, tok, key, jnp.float32(lr), jnp.float32(clip),
            jnp.int32(i),
        )
        losses.append(float(loss))
        accs.append(float(acc))
    return params, losses, accs


@pytest.mark.parametrize("variant", ["exact", "darkformer", "performer"])
def test_loss_decreases_when_overfitting_one_batch(variant):
    _, losses, _ = run_steps(variant, 12)
    assert losses[-1] < losses[0] - 0.3, f"{variant}: {losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(losses)), losses


def test_initial_loss_near_uniform():
    params = init_params(KEY, CFG, "exact")
    loss, acc = loss_and_accuracy(
        params, batch(), KEY, cfg=CFG, variant="exact"
    )
    expected = np.log(CFG.vocab_size)
    assert abs(float(loss) - expected) < 1.5, (float(loss), expected)
    assert 0.0 <= float(acc) <= 0.2


@pytest.mark.parametrize("variant", QKV_VARIANTS)
def test_qkv_mask_selects_expected_params(variant):
    params = init_params(KEY, CFG, variant)
    mask = qkv_mask(params, variant)
    for name, m in mask.items():
        if name.endswith(("attn.wq", "attn.wk", "attn.wv", "attn.m_proj")):
            assert float(m) == 1.0, name
        else:
            assert float(m) == 0.0, name


def test_qkv_only_training_freezes_other_params():
    variant = "darkformer"
    params0 = init_params(KEY, CFG, variant)
    m, v = zeros_like(params0), zeros_like(params0)
    step_fn = jax.jit(make_train_step(CFG, variant, qkv_only=True))
    params, _, _, _, _, _ = step_fn(
        params0, m, v, batch(), KEY, jnp.float32(1e-2), jnp.float32(0.0),
        jnp.int32(0),
    )
    for name in params0:
        if name.endswith(("attn.wq", "attn.wk", "attn.wv", "attn.m_proj")):
            assert not np.allclose(params[name], params0[name]), (
                f"{name} should train"
            )
        else:
            np.testing.assert_array_equal(
                params[name], params0[name], err_msg=f"{name} should be frozen"
            )


def test_darkformer_m_proj_learns_in_full_training():
    params0 = init_params(KEY, CFG, "darkformer")
    params, _, _ = run_steps("darkformer", 5)
    moved = np.abs(
        np.asarray(params["layer00.attn.m_proj"])
        - np.asarray(params0["layer00.attn.m_proj"])
    ).max()
    assert moved > 1e-5, "M must receive gradient"


def test_clip_bounds_update_magnitude():
    variant = "exact"
    params0 = init_params(KEY, CFG, variant)
    m, v = zeros_like(params0), zeros_like(params0)
    step_fn = jax.jit(make_train_step(CFG, variant))
    # With clip tiny, the gradient is scaled to norm <= clip; the reported
    # gnorm is pre-clip so compare parameter movement instead.
    _, _, _, _, _, gnorm_free = step_fn(
        params0, m, v, batch(), KEY, jnp.float32(1e-3), jnp.float32(0.0),
        jnp.int32(0),
    )
    assert float(gnorm_free) > 0.0


def test_gnorm_is_finite_and_positive():
    variant = "performer"
    params = init_params(KEY, CFG, variant)
    m, v = zeros_like(params), zeros_like(params)
    step_fn = jax.jit(make_train_step(CFG, variant))
    _, _, _, loss, acc, gnorm = step_fn(
        params, m, v, batch(), KEY, jnp.float32(1e-3), jnp.float32(1.0),
        jnp.int32(0),
    )
    assert np.isfinite(float(loss))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    assert 0.0 <= float(acc) <= 1.0


def test_eval_step_matches_loss_fn():
    variant = "exact"
    params = init_params(KEY, CFG, variant)
    ev = jax.jit(make_eval_step(CFG, variant))
    tok = batch(3)
    l1, a1 = ev(params, tok, KEY)
    l2, a2 = loss_and_accuracy(params, tok, KEY, cfg=CFG, variant=variant)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_weight_decay_shrinks_unused_params():
    # 'constant' attention never uses wq; with weight decay its norm must
    # strictly decrease under full training.
    params0 = init_params(KEY, CFG, "constant")
    m, v = zeros_like(params0), zeros_like(params0)
    step_fn = jax.jit(make_train_step(CFG, "constant"))
    params = params0
    for i in range(3):
        params, m, v, _, _, _ = step_fn(
            params, m, v, batch(), jax.random.PRNGKey(i), jnp.float32(1e-2),
            jnp.float32(1.0), jnp.int32(i),
        )
    n0 = float(jnp.linalg.norm(params0["layer00.attn.wq"]))
    n1 = float(jnp.linalg.norm(params["layer00.attn.wq"]))
    assert n1 < n0, (n0, n1)
