//! Micro-benchmark harness (no criterion in the offline environment).
//!
//! `cargo bench` targets use [`bench`] directly: warmup, fixed-count
//! timing, robust summary (mean / min / p50). Deliberately simple — the
//! paper-level benchmarks (Figs. 1-5) are end-to-end harnesses under
//! `coordinator::experiments`; these benches cover hot-path latency and
//! substrate throughput.
//!
//! Bench targets that should leave a machine-readable trail collect their
//! results in a [`BenchSuite`] and call [`BenchSuite::write`], which emits
//! `BENCH_<suite>.json` (override the directory with `BENCH_OUT_DIR`).
//! The JSON carries every `BenchResult` (name, iters, mean/min/p50 ms)
//! plus free-form scalar metrics (speedups, variances, scaling
//! exponents), so the perf trajectory is diffable across PRs.

use std::path::PathBuf;
use std::time::Instant;

use crate::ser::{Json, JsonObj};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  mean {:>10.4} ms  min {:>10.4} ms  p50 {:>10.4} ms",
            self.name, self.iters, self.mean_ms, self.min_ms, self.p50_ms
        );
    }

    /// JSON record: `{"name", "iters", "mean_ms", "min_ms", "p50_ms"}`.
    pub fn to_json(&self) -> Json {
        let mut obj = JsonObj::new();
        obj.insert("name", Json::Str(self.name.clone()));
        obj.insert("iters", Json::Num(self.iters as f64));
        obj.insert("mean_ms", Json::Num(self.mean_ms));
        obj.insert("min_ms", Json::Num(self.min_ms));
        obj.insert("p50_ms", Json::Num(self.p50_ms));
        Json::Obj(obj)
    }
}

/// Collects [`BenchResult`]s and scalar metrics for one bench target and
/// persists them as `BENCH_<suite>.json`.
pub struct BenchSuite {
    suite: String,
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
    str_metrics: Vec<(String, String)>,
}

impl BenchSuite {
    pub fn new(suite: impl Into<String>) -> Self {
        Self {
            suite: suite.into(),
            results: Vec::new(),
            metrics: Vec::new(),
            str_metrics: Vec::new(),
        }
    }

    /// Run [`bench`] and record the result; returns the mean ms.
    pub fn bench<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: F,
    ) -> f64 {
        let r = bench(name, warmup, iters, f);
        let mean = r.mean_ms;
        self.results.push(r);
        mean
    }

    /// Record an externally produced result.
    pub fn record(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Record a free-form scalar metric (speedup, variance, exponent...).
    pub fn metric(&mut self, key: impl Into<String>, value: f64) {
        self.metrics.push((key.into(), value));
    }

    /// Record a free-form string metric (e.g. the active SIMD ISA).
    /// Serialized into the same `metrics` object; `bench_diff` skips
    /// non-numeric values, so string metrics annotate without diffing.
    pub fn metric_str(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.str_metrics.push((key.into(), value.into()));
    }

    /// Serialize the whole suite.
    pub fn to_json(&self) -> Json {
        let mut obj = JsonObj::new();
        obj.insert("suite", Json::Str(self.suite.clone()));
        obj.insert(
            "results",
            Json::Arr(self.results.iter().map(BenchResult::to_json).collect()),
        );
        let mut metrics = JsonObj::new();
        for (k, v) in &self.metrics {
            metrics.insert(k.clone(), Json::Num(*v));
        }
        for (k, v) in &self.str_metrics {
            metrics.insert(k.clone(), Json::Str(v.clone()));
        }
        obj.insert("metrics", Json::Obj(metrics));
        Json::Obj(obj)
    }

    /// Write `BENCH_<suite>.json` into `BENCH_OUT_DIR` (default: the
    /// current directory). Returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_OUT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        self.write_to(&dir)
    }

    /// Write `BENCH_<suite>.json` into an explicit directory.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, self.to_json().to_string_compact())?;
        println!("bench json: {}", path.display());
        Ok(path)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: samples.iter().sum::<f64>() / iters as f64,
        min_ms: sorted[0],
        p50_ms: sorted[iters / 2],
    };
    result.print();
    result
}

/// Convenience: bench returning throughput items/sec given items/iter.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    f: F,
) -> f64 {
    let r = bench(name, warmup, iters, f);
    let per_sec = items_per_iter / (r.mean_ms / 1e3);
    println!("{:<44} {:>18.0} items/s", format!("{} (throughput)", r.name), per_sec);
    per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
        assert!(r.min_ms <= r.p50_ms + 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_iters_panics() {
        bench("bad", 0, 0, || {});
    }

    #[test]
    fn suite_json_round_trips() {
        let mut suite = BenchSuite::new("unit");
        suite.record(BenchResult {
            name: "case".into(),
            iters: 3,
            mean_ms: 1.5,
            min_ms: 1.0,
            p50_ms: 1.25,
        });
        suite.metric("speedup", 6.5);
        suite.metric_str("active_isa", "avx2");
        let text = suite.to_json().to_string_compact();
        let back = crate::ser::parse(&text).expect("valid json");
        assert_eq!(back.field("suite").unwrap().as_str(), Some("unit"));
        let results = back.field("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].field("name").unwrap().as_str(), Some("case"));
        assert_eq!(results[0].field("iters").unwrap().as_usize(), Some(3));
        let metrics = back.field("metrics").unwrap();
        assert_eq!(metrics.field("speedup").unwrap().as_f64(), Some(6.5));
        assert_eq!(metrics.field("active_isa").unwrap().as_str(), Some("avx2"));
    }

    #[test]
    fn suite_writes_json_file() {
        // Per-process dir: concurrent test runs must not race on one file.
        let dir = std::env::temp_dir()
            .join(format!("dkf_bench_suite_{}", std::process::id()));
        let mut suite = BenchSuite::new("writer_test");
        suite.metric("x", 1.0);
        let path = suite.write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_writer_test.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::ser::parse(&text).is_ok());
    }
}
