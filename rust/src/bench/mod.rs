//! Micro-benchmark harness (no criterion in the offline environment).
//!
//! `cargo bench` targets use [`Bencher`] directly: warmup, fixed-count
//! timing, robust summary (mean / min / p50). Deliberately simple — the
//! paper-level benchmarks (Figs. 1-5) are end-to-end harnesses under
//! `coordinator::experiments`; these benches cover hot-path latency and
//! substrate throughput.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  mean {:>10.4} ms  min {:>10.4} ms  p50 {:>10.4} ms",
            self.name, self.iters, self.mean_ms, self.min_ms, self.p50_ms
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: samples.iter().sum::<f64>() / iters as f64,
        min_ms: sorted[0],
        p50_ms: sorted[iters / 2],
    };
    result.print();
    result
}

/// Convenience: bench returning throughput items/sec given items/iter.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    f: F,
) -> f64 {
    let r = bench(name, warmup, iters, f);
    let per_sec = items_per_iter / (r.mean_ms / 1e3);
    println!("{:<44} {:>18.0} items/s", format!("{} (throughput)", r.name), per_sec);
    per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
        assert!(r.min_ms <= r.p50_ms + 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_iters_panics() {
        bench("bad", 0, 0, || {});
    }
}
