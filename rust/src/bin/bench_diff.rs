//! Compare current `BENCH_<suite>.json` files against a committed
//! baseline and print per-metric / per-case deltas, so perf regressions
//! are visible in review.
//!
//! Usage:
//!
//! ```text
//! bench_diff [--baseline DIR] [--current DIR] [--fail-over PCT]
//! ```
//!
//! Defaults: baseline `benches/baseline`, current `$BENCH_OUT_DIR` (the
//! same env var the bench targets write through) falling back to `.`.
//! For every
//! `BENCH_*.json` in the baseline dir the tool prints the change in each
//! timing case's `mean_ms` (positive = slower than baseline), the change
//! in each scalar metric, and a per-suite `summary: n better / n worse /
//! n missing` line so CI logs are scannable at a glance. With
//! `--fail-over PCT` the exit code is 1 if any timing case regressed by
//! more than PCT percent — usable as a CI gate.
//!
//! Regenerate the baseline on a machine with a Rust toolchain via
//! `make bench-baseline` (runs the offline benches with
//! `BENCH_OUT_DIR=benches/baseline`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use darkformer::ser::{parse, Json};

struct Suite {
    /// case name -> mean_ms
    cases: BTreeMap<String, f64>,
    /// metric key -> value
    metrics: BTreeMap<String, f64>,
}

fn load_suite(path: &Path) -> Result<Suite, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let json = parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut cases = BTreeMap::new();
    if let Some(results) = json.field("results").and_then(Json::as_arr) {
        for r in results {
            if let (Some(name), Some(mean)) = (
                r.field("name").and_then(Json::as_str),
                r.field("mean_ms").and_then(Json::as_f64),
            ) {
                cases.insert(name.to_string(), mean);
            }
        }
    }
    let mut metrics = BTreeMap::new();
    if let Some(obj) = json.field("metrics").and_then(Json::as_obj) {
        for (k, v) in obj.iter() {
            if let Some(x) = v.as_f64() {
                metrics.insert(k.clone(), x);
            }
        }
    }
    Ok(Suite { cases, metrics })
}

fn pct_change(base: f64, cur: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    (cur - base) / base * 100.0
}

fn baseline_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| {
                        n.starts_with("BENCH_") && n.ends_with(".json")
                    })
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    files.sort();
    files
}

fn main() -> ExitCode {
    let mut baseline_dir = PathBuf::from("benches/baseline");
    // Match BenchSuite::write: benches land in BENCH_OUT_DIR when set.
    let mut current_dir = std::env::var("BENCH_OUT_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."));
    let mut fail_over: Option<f64> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        let mut take = |what: &str| {
            args.next().unwrap_or_else(|| {
                eprintln!("{what} needs a value");
                std::process::exit(2);
            })
        };
        match arg.as_str() {
            "--baseline" => baseline_dir = PathBuf::from(take("--baseline")),
            "--current" => current_dir = PathBuf::from(take("--current")),
            "--fail-over" => {
                fail_over = Some(take("--fail-over").parse().unwrap_or_else(
                    |_| {
                        eprintln!("--fail-over needs a number (percent)");
                        std::process::exit(2);
                    },
                ))
            }
            other => {
                eprintln!(
                    "unknown arg {other}\nusage: bench_diff [--baseline DIR] \
                     [--current DIR] [--fail-over PCT]"
                );
                return ExitCode::from(2);
            }
        }
    }

    let files = baseline_files(&baseline_dir);
    // Suites present in the current run but absent from the baseline
    // (e.g. a freshly added bench target like BENCH_serving.json) would
    // otherwise be invisible here — call them out so the next
    // `make bench-baseline` run knows to pick them up.
    let baselined: Vec<String> = files
        .iter()
        .filter_map(|p| p.file_name()?.to_str().map(String::from))
        .collect();
    for cur_only in baseline_files(&current_dir) {
        let name = cur_only.file_name().unwrap().to_str().unwrap();
        if !baselined.iter().any(|b| b == name) {
            println!(
                "== {name} == new suite (no baseline — add it via \
                 `make bench-baseline`)\n"
            );
        }
    }
    if files.is_empty() {
        println!(
            "no BENCH_*.json baseline found under {} — generate one with \
             `make bench-baseline` and commit it.",
            baseline_dir.display()
        );
        return ExitCode::SUCCESS;
    }

    let mut worst_regression: Option<(String, f64)> = None;
    for base_path in files {
        let name = base_path.file_name().unwrap().to_str().unwrap();
        let cur_path = current_dir.join(name);
        println!("== {name} ==");
        let base = match load_suite(&base_path) {
            Ok(s) => s,
            Err(e) => {
                println!("  unreadable baseline: {e}");
                continue;
            }
        };
        let cur = match load_suite(&cur_path) {
            Ok(s) => s,
            Err(_) => {
                println!(
                    "  no current {name} (run `make bench` first) — skipped"
                );
                continue;
            }
        };
        let (mut better, mut worse, mut missing) = (0usize, 0usize, 0usize);
        for (case, &base_ms) in &base.cases {
            match cur.cases.get(case) {
                Some(&cur_ms) => {
                    let pct = pct_change(base_ms, cur_ms);
                    println!(
                        "  {case:<44} {base_ms:>10.4} -> {cur_ms:>10.4} ms  \
                         {pct:>+7.1}%"
                    );
                    if cur_ms < base_ms {
                        better += 1;
                    } else if cur_ms > base_ms {
                        worse += 1;
                    }
                    let is_worse = match &worst_regression {
                        Some((_, worst)) => pct > *worst,
                        None => true,
                    };
                    if is_worse {
                        worst_regression = Some((case.clone(), pct));
                    }
                }
                None => {
                    missing += 1;
                    println!("  {case:<44} missing from current run");
                }
            }
        }
        for case in cur.cases.keys() {
            if !base.cases.contains_key(case) {
                println!("  {case:<44} new (no baseline)");
            }
        }
        for (key, &base_v) in &base.metrics {
            match cur.metrics.get(key) {
                Some(&cur_v) => println!(
                    "  metric {key:<37} {base_v:>10.4} -> {cur_v:>10.4}  \
                     {:>+7.1}%",
                    pct_change(base_v, cur_v)
                ),
                None => println!("  metric {key:<37} missing from current"),
            }
        }
        for key in cur.metrics.keys() {
            if !base.metrics.contains_key(key) {
                println!(
                    "  metric {key:<37} new: {:.4}",
                    cur.metrics[key]
                );
            }
        }
        // One scannable line per suite for CI logs: timing cases only
        // (equal-time cases count as neither better nor worse).
        println!(
            "  summary: {better} better / {worse} worse / {missing} missing"
        );
        println!();
    }

    if let (Some(limit), Some((case, pct))) = (fail_over, &worst_regression) {
        if *pct > limit {
            eprintln!(
                "FAIL: {case} regressed {pct:+.1}% (> {limit}% allowed)"
            );
            return ExitCode::FAILURE;
        }
    }
    if let Some((case, pct)) = worst_regression {
        println!("worst timing delta: {case} {pct:+.1}%");
    }
    ExitCode::SUCCESS
}
