//! Binary tensor-store checkpoint format.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "DKFT" | u32 version | u32 tensor count
//! per tensor: u32 name_len | name bytes | u8 dtype | u8 rank
//!             | u64 dims[rank] | raw data bytes
//! trailer: u32 crc32 over everything after the magic
//! ```
//!
//! Used for model parameters and optimizer state between pretraining and
//! the finetuning experiments (the "pretrained weights" of the paper's
//! resource-constrained setting), by the coordinator's periodic
//! checkpoint cadence, and by [`crate::rfa::serve`]'s resumable session
//! snapshots — which is why the store carries an F64 dtype (bitwise f64
//! round-trips) and typed `require_*` reads that turn a missing, renamed
//! or reshaped tensor into a descriptive error instead of a panic.
//!
//! Writes are crash-safe: [`Checkpoint::save`] serializes with
//! [`Checkpoint::to_bytes`] and lands the file via [`atomic_write`]
//! (staging file + fsync + rename), so no crash or full-disk
//! interleaving ever leaves a torn file at the final path.

mod store;

pub use store::{atomic_write, staging_path, Checkpoint, DType, Tensor};
