//! Tensor store implementation. See format doc in `mod.rs`.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"DKFT";
const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    /// Added for the `rfa::serve` session snapshots, whose resumability
    /// contract is *bitwise* f64 round-trips; files without F64 tensors
    /// are unchanged, so the format version stays at 1.
    F64,
}

impl DType {
    fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
            DType::F64 => 3,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            3 => DType::F64,
            t => bail!("unknown dtype tag {t}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 => 8,
        }
    }
}

/// A named tensor: shape + raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::F32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::I32, shape, data }
    }

    pub fn from_u32(shape: Vec<usize>, values: &[u32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::U32, shape, data }
    }

    /// f64 tensor — the little-endian bytes preserve every bit, so an
    /// f64 value round-trips exactly (the property session snapshots
    /// rely on).
    pub fn from_f64(shape: Vec<usize>, values: &[f64]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::F64, shape, data }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u32(&self) -> Result<Vec<u32>> {
        if self.dtype != DType::U32 {
            bail!("tensor is {:?}, not U32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_f64(&self) -> Result<Vec<f64>> {
        if self.dtype != DType::F64 {
            bail!("tensor is {:?}, not F64", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ])
            })
            .collect())
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Default, Clone)]
pub struct Checkpoint {
    tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, tensor: Tensor) {
        self.tensors.insert(name.into(), tensor);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// Fetch a tensor by name, with a descriptive error (instead of a
    /// panic or a bare `None`) when it is absent.
    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| {
            format!(
                "checkpoint has no tensor named {name:?} ({} tensors: {})",
                self.tensors.len(),
                self.tensors
                    .keys()
                    .take(8)
                    .map(String::as_str)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// Fetch a tensor by name and validate dtype and shape — the typed
    /// read the `rfa::serve` snapshot path restores through, so a renamed
    /// or reshaped tensor surfaces as a readable error, never a panic or
    /// a silently misinterpreted buffer.
    pub fn require_typed(
        &self,
        name: &str,
        dtype: DType,
        shape: &[usize],
    ) -> Result<&Tensor> {
        let t = self.require(name)?;
        if t.dtype != dtype {
            bail!(
                "tensor {name:?} is {:?}, expected {dtype:?}",
                t.dtype
            );
        }
        if t.shape != shape {
            bail!(
                "tensor {name:?} has shape {:?}, expected {shape:?}",
                t.shape
            );
        }
        Ok(t)
    }

    /// Typed f64 read: [`Checkpoint::require_typed`] + decode.
    pub fn require_f64(&self, name: &str, shape: &[usize]) -> Result<Vec<f64>> {
        self.require_typed(name, DType::F64, shape)?.as_f64()
    }

    /// Typed u32 read: [`Checkpoint::require_typed`] + decode.
    pub fn require_u32(&self, name: &str, shape: &[usize]) -> Result<Vec<u32>> {
        self.require_typed(name, DType::U32, shape)?.as_u32()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Serialize to the DKFT wire format (magic..crc) without touching
    /// the filesystem. [`Checkpoint::save`] writes exactly these bytes;
    /// the `rfa::serve` snapshot store hands them to pluggable backends.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        let mut w = Crc32Writer::new(&mut buf);
        w.inner.write_all(MAGIC)?;
        w.write_u32(VERSION)?;
        w.write_u32(self.tensors.len() as u32)?;
        for (name, t) in &self.tensors {
            w.write_u32(name.len() as u32)?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype.tag(), t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_u64(d as u64)?;
            }
            let expected = t.element_count() * t.dtype.size_bytes();
            if t.data.len() != expected {
                bail!(
                    "tensor {name}: data {} bytes != shape implies {expected}",
                    t.data.len()
                );
            }
            w.write_all(&t.data)?;
        }
        let crc = w.crc();
        w.inner.write_all(&crc.to_le_bytes())?;
        Ok(buf)
    }

    /// Crash-safe save: serialize, then [`atomic_write`]. No crash or
    /// full-disk interleaving can leave a torn file at `path` — either
    /// the old contents survive or the new contents are complete.
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        atomic_write(path, &bytes)
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Parse the DKFT wire format. Every length and offset is bounds-
    /// and overflow-checked, so a truncated or bit-flipped file (even
    /// one whose CRC was re-fixed) yields a descriptive error — never a
    /// panic or a wrapped-arithmetic misread.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 16 || &buf[..4] != MAGIC {
            bail!("not a DKFT checkpoint");
        }
        let body = &buf[4..buf.len() - 4];
        let stored_crc = u32::from_le_bytes(
            buf[buf.len() - 4..].try_into().unwrap(),
        );
        if crc32(body) != stored_crc {
            bail!("checkpoint CRC mismatch");
        }
        let mut pos = 0usize;
        let read_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > body.len() {
                bail!("truncated checkpoint");
            }
            let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into()?);
            *pos += 4;
            Ok(v)
        };
        let version = read_u32(&mut pos)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut pos)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(&mut pos)? as usize;
            let header_end = pos
                .checked_add(name_len)
                .and_then(|p| p.checked_add(2))
                .filter(|&p| p <= body.len());
            let Some(header_end) = header_end else {
                bail!("truncated tensor header");
            };
            let name =
                String::from_utf8(body[pos..pos + name_len].to_vec())?;
            pos += name_len;
            let dtype = DType::from_tag(body[pos])?;
            let rank = body[pos + 1] as usize;
            pos = header_end;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                if pos + 8 > body.len() {
                    bail!("truncated shape");
                }
                shape.push(u64::from_le_bytes(
                    body[pos..pos + 8].try_into()?,
                ) as usize);
                pos += 8;
            }
            let n_bytes = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .and_then(|n| n.checked_mul(dtype.size_bytes()));
            let Some(n_bytes) = n_bytes else {
                bail!("tensor {name}: shape {shape:?} overflows");
            };
            let data_end =
                pos.checked_add(n_bytes).filter(|&p| p <= body.len());
            let Some(data_end) = data_end else {
                bail!("truncated tensor data for {name}");
            };
            let data = body[pos..data_end].to_vec();
            pos = data_end;
            tensors.insert(name, Tensor { dtype, shape, data });
        }
        Ok(Self { tensors })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::from_bytes(&buf)
            .with_context(|| format!("loading {}", path.display()))
    }
}

// --- durable whole-file writes -----------------------------------------

/// Where [`atomic_write`] stages its temporary copy: `<path>.tmp` in the
/// same directory (rename must not cross a filesystem). A crash can leave
/// this file behind; the final path is never exposed to partial writes.
pub fn staging_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    path.with_file_name(format!("{name}.tmp"))
}

/// Crash-safe whole-file write: write to [`staging_path`], `sync_all`,
/// atomically rename over `path`, then best-effort fsync the parent
/// directory so the rename itself is durable. On any failure the
/// destination is untouched (old contents, if any, remain loadable) and
/// the staging file is cleaned up best-effort.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = staging_path(path);
    let staged = (|| -> io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    })();
    if let Err(e) = staged {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(parent) = path.parent() {
        if let Ok(dir) = std::fs::File::open(parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(())
}

// --- CRC32 (IEEE, reflected) -------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Writer wrapper that maintains a running CRC over written bytes
/// (excluding the magic, matching the load path).
struct Crc32Writer<W: Write> {
    inner: W,
    table: [u32; 256],
    state: u32,
    past_magic: bool,
}

impl<W: Write> Crc32Writer<W> {
    fn new(inner: W) -> Self {
        Self {
            inner,
            table: crc32_table(),
            state: 0xffff_ffff,
            past_magic: false,
        }
    }

    fn write_all(&mut self, data: &[u8]) -> Result<()> {
        if self.past_magic {
            for &b in data {
                self.state = self.table[((self.state ^ b as u32) & 0xff) as usize]
                    ^ (self.state >> 8);
            }
        }
        self.inner.write_all(data)?;
        Ok(())
    }

    fn write_u32(&mut self, v: u32) -> Result<()> {
        self.past_magic = true;
        self.write_all(&v.to_le_bytes())
    }

    fn write_u64(&mut self, v: u64) -> Result<()> {
        self.write_all(&v.to_le_bytes())
    }

    fn crc(&self) -> u32 {
        self.state ^ 0xffff_ffff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dkf_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trips_tensors() {
        let mut ck = Checkpoint::new();
        ck.insert("emb", Tensor::from_f32(vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]));
        ck.insert("steps", Tensor::from_i32(vec![2], &[7, -9]));
        let path = tmp("roundtrip.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(
            loaded.get("emb").unwrap().as_f32().unwrap(),
            vec![1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]
        );
        assert_eq!(loaded.get("steps").unwrap().as_i32().unwrap(), vec![7, -9]);
        assert_eq!(loaded.get("emb").unwrap().shape, vec![2, 3]);
    }

    #[test]
    fn names_are_sorted() {
        let mut ck = Checkpoint::new();
        ck.insert("z", Tensor::from_f32(vec![1], &[1.0]));
        ck.insert("a", Tensor::from_f32(vec![1], &[2.0]));
        let names: Vec<_> = ck.names().cloned().collect();
        assert_eq!(names, vec!["a", "z"]);
    }

    #[test]
    fn detects_corruption() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4], &[1.0, 2.0, 3.0, 4.0]));
        let path = tmp("corrupt.dkft");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("notckpt.dkft");
        std::fs::write(&path, b"XXXXrest-of-file-content").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![64], &[0.5; 64]));
        let path = tmp("trunc.dkft");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn empty_checkpoint_round_trips() {
        let ck = Checkpoint::new();
        let path = tmp("empty.dkft");
        ck.save(&path).unwrap();
        assert!(Checkpoint::load(&path).unwrap().is_empty());
    }

    #[test]
    fn f64_round_trip_is_bitwise() {
        // The serve snapshot contract: every f64 bit pattern survives,
        // including denormals, negative zero and extreme exponents.
        let vals = [
            1.0f64,
            -0.0,
            f64::MIN_POSITIVE,
            5e-324, // smallest denormal
            1e300,
            -1.2345678901234567,
        ];
        let mut ck = Checkpoint::new();
        ck.insert("s", Tensor::from_f64(vec![2, 3], &vals));
        ck.insert("pos", Tensor::from_u32(vec![2], &[0xdead_beef, 7]));
        let path = tmp("f64bits.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let back = loaded.require_f64("s", &[2, 3]).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed bits");
        }
        assert_eq!(
            loaded.require_u32("pos", &[2]).unwrap(),
            vec![0xdead_beef, 7]
        );
    }

    #[test]
    fn require_reports_missing_and_mismatched() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4], &[1.0, 2.0, 3.0, 4.0]));
        // Missing name: descriptive error, not a panic.
        let err = ck.require("nope").unwrap_err();
        assert!(format!("{err}").contains("nope"), "got: {err}");
        // Wrong dtype.
        let err =
            ck.require_typed("w", DType::F64, &[4]).unwrap_err();
        assert!(format!("{err}").contains("F32"), "got: {err}");
        assert!(format!("{err}").contains("F64"), "got: {err}");
        // Wrong shape.
        let err = ck.require_typed("w", DType::F32, &[2, 2]).unwrap_err();
        assert!(format!("{err}").contains("[2, 2]"), "got: {err}");
    }

    #[test]
    fn corrupted_crc_is_a_described_error() {
        let mut ck = Checkpoint::new();
        ck.insert("s", Tensor::from_f64(vec![3], &[1.0, 2.0, 3.0]));
        let path = tmp("crc_err.dkft");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "got: {err}");
    }

    /// Recompute and patch the trailing CRC so corruption tests exercise
    /// the *parser* (bounds/overflow checks), not just the CRC gate.
    fn refix_crc(bytes: &mut [u8]) {
        let n = bytes.len();
        let crc = crc32(&bytes[4..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
    }

    fn two_tensor_bytes() -> Vec<u8> {
        let mut ck = Checkpoint::new();
        ck.insert("s", Tensor::from_f64(vec![2], &[1.5, -2.5]));
        ck.insert("w", Tensor::from_f32(vec![3], &[1.0, 2.0, 3.0]));
        ck.to_bytes().unwrap()
    }

    #[test]
    fn truncation_at_every_byte_is_an_error_not_a_panic() {
        // Covers every section boundary (magic, header, each tensor
        // header/shape/data, CRC) by truncating at *every* prefix length.
        let bytes = two_tensor_bytes();
        for k in 0..bytes.len() {
            let err = Checkpoint::from_bytes(&bytes[..k]);
            assert!(err.is_err(), "prefix of {k} bytes parsed as valid");
        }
        assert!(Checkpoint::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn every_single_byte_flip_never_panics() {
        // Without re-fixing the CRC: any flip is caught by the CRC gate
        // (or the magic check) and reported, never a panic.
        let bytes = two_tensor_bytes();
        for i in 0..bytes.len() {
            let mut b = bytes.clone();
            b[i] ^= 0xff;
            assert!(
                Checkpoint::from_bytes(&b).is_err(),
                "flip at byte {i} parsed as valid"
            );
        }
    }

    #[test]
    fn crc_refixed_region_corruption_is_described() {
        // Even when the CRC is made consistent again, structural fields
        // must be rejected with a descriptive error. Offsets for a file
        // holding ("s", F64 [2]) then ("w", F32 [3]):
        //   4 version | 8 count | 12 name_len | 16 name "s" | 17 dtype
        //   18 rank | 19..27 dim | 27..43 data | ...
        let bytes = two_tensor_bytes();
        let check = |mutate: fn(&mut Vec<u8>), needle: &str| {
            let mut b = bytes.clone();
            mutate(&mut b);
            refix_crc(&mut b);
            let err = Checkpoint::from_bytes(&b).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "wanted {needle:?}, got: {msg}");
        };
        // Unsupported version.
        check(|b| b[4] = 0x7f, "unsupported checkpoint version");
        // Tensor count far beyond the payload.
        check(|b| b[8..12].copy_from_slice(&u32::MAX.to_le_bytes()), "truncated");
        // name_len pointing past EOF (checked add, no wraparound).
        check(
            |b| b[12..16].copy_from_slice(&u32::MAX.to_le_bytes()),
            "truncated tensor header",
        );
        // Unknown dtype tag.
        check(|b| b[17] = 0xee, "unknown dtype tag");
        // A dim of u64::MAX: the element-count product must be
        // overflow-checked, not wrapped into a tiny bogus size.
        check(
            |b| b[19..27].copy_from_slice(&u64::MAX.to_le_bytes()),
            "overflows",
        );
        // Huge-but-not-overflowing dim: plain truncation error.
        check(
            |b| b[19..27].copy_from_slice(&(1u64 << 40).to_le_bytes()),
            "truncated tensor data",
        );
    }

    #[test]
    fn crash_between_staging_and_rename_keeps_old_snapshot() {
        // Simulate dying after the tmp write but before the rename: the
        // staging file holds half of v2, while v1 sits at the final
        // path. v1 must still load; completing the write must win.
        let path = tmp("crash_consistency.dkft");
        let mut v1 = Checkpoint::new();
        v1.insert("s", Tensor::from_f64(vec![2], &[1.0, 2.0]));
        v1.save(&path).unwrap();
        let mut v2 = Checkpoint::new();
        v2.insert("s", Tensor::from_f64(vec![2], &[9.0, 8.0]));
        let v2_bytes = v2.to_bytes().unwrap();
        let staging = staging_path(&path);
        std::fs::write(&staging, &v2_bytes[..v2_bytes.len() / 2]).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.require_f64("s", &[2]).unwrap(), vec![1.0, 2.0]);
        // Re-running the atomic write replaces the torn staging file and
        // lands v2; no .tmp residue remains.
        atomic_write(&path, &v2_bytes).unwrap();
        assert!(!staging.exists(), "staging file left behind");
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.require_f64("s", &[2]).unwrap(), vec![9.0, 8.0]);
    }

    #[test]
    fn save_is_atomic_over_existing_file() {
        // Overwriting an existing snapshot goes through rename, so a
        // reader can never observe a mix of old and new bytes; after the
        // save only the new contents exist and no staging file remains.
        let path = tmp("atomic_overwrite.dkft");
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![1], &[1.0]));
        ck.save(&path).unwrap();
        ck.insert("w2", Tensor::from_f32(vec![1], &[2.0]));
        ck.save(&path).unwrap();
        assert!(!staging_path(&path).exists());
        assert_eq!(Checkpoint::load(&path).unwrap().len(), 2);
    }

    #[test]
    fn scalar_tensor_rank_zero() {
        let mut ck = Checkpoint::new();
        ck.insert("lr", Tensor::from_f32(vec![], &[0.001]));
        let path = tmp("scalar.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let t = loaded.get("lr").unwrap();
        assert!(t.shape.is_empty());
        assert_eq!(t.as_f32().unwrap(), vec![0.001]);
    }
}
