//! Tensor store implementation. See format doc in `mod.rs`.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"DKFT";
const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    /// Added for the `rfa::serve` session snapshots, whose resumability
    /// contract is *bitwise* f64 round-trips; files without F64 tensors
    /// are unchanged, so the format version stays at 1.
    F64,
}

impl DType {
    fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
            DType::F64 => 3,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            3 => DType::F64,
            t => bail!("unknown dtype tag {t}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 => 8,
        }
    }
}

/// A named tensor: shape + raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::F32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::I32, shape, data }
    }

    pub fn from_u32(shape: Vec<usize>, values: &[u32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::U32, shape, data }
    }

    /// f64 tensor — the little-endian bytes preserve every bit, so an
    /// f64 value round-trips exactly (the property session snapshots
    /// rely on).
    pub fn from_f64(shape: Vec<usize>, values: &[f64]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self { dtype: DType::F64, shape, data }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u32(&self) -> Result<Vec<u32>> {
        if self.dtype != DType::U32 {
            bail!("tensor is {:?}, not U32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_f64(&self) -> Result<Vec<f64>> {
        if self.dtype != DType::F64 {
            bail!("tensor is {:?}, not F64", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ])
            })
            .collect())
    }
}

/// An ordered collection of named tensors.
#[derive(Debug, Default, Clone)]
pub struct Checkpoint {
    tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, tensor: Tensor) {
        self.tensors.insert(name.into(), tensor);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// Fetch a tensor by name, with a descriptive error (instead of a
    /// panic or a bare `None`) when it is absent.
    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| {
            format!(
                "checkpoint has no tensor named {name:?} ({} tensors: {})",
                self.tensors.len(),
                self.tensors
                    .keys()
                    .take(8)
                    .map(String::as_str)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// Fetch a tensor by name and validate dtype and shape — the typed
    /// read the `rfa::serve` snapshot path restores through, so a renamed
    /// or reshaped tensor surfaces as a readable error, never a panic or
    /// a silently misinterpreted buffer.
    pub fn require_typed(
        &self,
        name: &str,
        dtype: DType,
        shape: &[usize],
    ) -> Result<&Tensor> {
        let t = self.require(name)?;
        if t.dtype != dtype {
            bail!(
                "tensor {name:?} is {:?}, expected {dtype:?}",
                t.dtype
            );
        }
        if t.shape != shape {
            bail!(
                "tensor {name:?} has shape {:?}, expected {shape:?}",
                t.shape
            );
        }
        Ok(t)
    }

    /// Typed f64 read: [`Checkpoint::require_typed`] + decode.
    pub fn require_f64(&self, name: &str, shape: &[usize]) -> Result<Vec<f64>> {
        self.require_typed(name, DType::F64, shape)?.as_f64()
    }

    /// Typed u32 read: [`Checkpoint::require_typed`] + decode.
    pub fn require_u32(&self, name: &str, shape: &[usize]) -> Result<Vec<u32>> {
        self.require_typed(name, DType::U32, shape)?.as_u32()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = Crc32Writer::new(BufWriter::new(file));
        w.inner.write_all(MAGIC)?;
        w.write_u32(VERSION)?;
        w.write_u32(self.tensors.len() as u32)?;
        for (name, t) in &self.tensors {
            w.write_u32(name.len() as u32)?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype.tag(), t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_u64(d as u64)?;
            }
            let expected = t.element_count() * t.dtype.size_bytes();
            if t.data.len() != expected {
                bail!(
                    "tensor {name}: data {} bytes != shape implies {expected}",
                    t.data.len()
                );
            }
            w.write_all(&t.data)?;
        }
        let crc = w.crc();
        w.inner.write_all(&crc.to_le_bytes())?;
        w.inner.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(file);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        if buf.len() < 16 || &buf[..4] != MAGIC {
            bail!("not a DKFT checkpoint: {}", path.display());
        }
        let body = &buf[4..buf.len() - 4];
        let stored_crc = u32::from_le_bytes(
            buf[buf.len() - 4..].try_into().unwrap(),
        );
        if crc32(body) != stored_crc {
            bail!("checkpoint CRC mismatch: {}", path.display());
        }
        let mut pos = 0usize;
        let read_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > body.len() {
                bail!("truncated checkpoint");
            }
            let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into()?);
            *pos += 4;
            Ok(v)
        };
        let version = read_u32(&mut pos)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut pos)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(&mut pos)? as usize;
            if pos + name_len + 2 > body.len() {
                bail!("truncated tensor header");
            }
            let name =
                String::from_utf8(body[pos..pos + name_len].to_vec())?;
            pos += name_len;
            let dtype = DType::from_tag(body[pos])?;
            let rank = body[pos + 1] as usize;
            pos += 2;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                if pos + 8 > body.len() {
                    bail!("truncated shape");
                }
                shape.push(u64::from_le_bytes(
                    body[pos..pos + 8].try_into()?,
                ) as usize);
                pos += 8;
            }
            let n_bytes =
                shape.iter().product::<usize>() * dtype.size_bytes();
            if pos + n_bytes > body.len() {
                bail!("truncated tensor data for {name}");
            }
            let data = body[pos..pos + n_bytes].to_vec();
            pos += n_bytes;
            tensors.insert(name, Tensor { dtype, shape, data });
        }
        Ok(Self { tensors })
    }
}

// --- CRC32 (IEEE, reflected) -------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Writer wrapper that maintains a running CRC over written bytes
/// (excluding the magic, matching the load path).
struct Crc32Writer<W: Write> {
    inner: W,
    table: [u32; 256],
    state: u32,
    past_magic: bool,
}

impl<W: Write> Crc32Writer<W> {
    fn new(inner: W) -> Self {
        Self {
            inner,
            table: crc32_table(),
            state: 0xffff_ffff,
            past_magic: false,
        }
    }

    fn write_all(&mut self, data: &[u8]) -> Result<()> {
        if self.past_magic {
            for &b in data {
                self.state = self.table[((self.state ^ b as u32) & 0xff) as usize]
                    ^ (self.state >> 8);
            }
        }
        self.inner.write_all(data)?;
        Ok(())
    }

    fn write_u32(&mut self, v: u32) -> Result<()> {
        self.past_magic = true;
        self.write_all(&v.to_le_bytes())
    }

    fn write_u64(&mut self, v: u64) -> Result<()> {
        self.write_all(&v.to_le_bytes())
    }

    fn crc(&self) -> u32 {
        self.state ^ 0xffff_ffff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dkf_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trips_tensors() {
        let mut ck = Checkpoint::new();
        ck.insert("emb", Tensor::from_f32(vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]));
        ck.insert("steps", Tensor::from_i32(vec![2], &[7, -9]));
        let path = tmp("roundtrip.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(
            loaded.get("emb").unwrap().as_f32().unwrap(),
            vec![1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]
        );
        assert_eq!(loaded.get("steps").unwrap().as_i32().unwrap(), vec![7, -9]);
        assert_eq!(loaded.get("emb").unwrap().shape, vec![2, 3]);
    }

    #[test]
    fn names_are_sorted() {
        let mut ck = Checkpoint::new();
        ck.insert("z", Tensor::from_f32(vec![1], &[1.0]));
        ck.insert("a", Tensor::from_f32(vec![1], &[2.0]));
        let names: Vec<_> = ck.names().cloned().collect();
        assert_eq!(names, vec!["a", "z"]);
    }

    #[test]
    fn detects_corruption() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4], &[1.0, 2.0, 3.0, 4.0]));
        let path = tmp("corrupt.dkft");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("notckpt.dkft");
        std::fs::write(&path, b"XXXXrest-of-file-content").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![64], &[0.5; 64]));
        let path = tmp("trunc.dkft");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn empty_checkpoint_round_trips() {
        let ck = Checkpoint::new();
        let path = tmp("empty.dkft");
        ck.save(&path).unwrap();
        assert!(Checkpoint::load(&path).unwrap().is_empty());
    }

    #[test]
    fn f64_round_trip_is_bitwise() {
        // The serve snapshot contract: every f64 bit pattern survives,
        // including denormals, negative zero and extreme exponents.
        let vals = [
            1.0f64,
            -0.0,
            f64::MIN_POSITIVE,
            5e-324, // smallest denormal
            1e300,
            -1.2345678901234567,
        ];
        let mut ck = Checkpoint::new();
        ck.insert("s", Tensor::from_f64(vec![2, 3], &vals));
        ck.insert("pos", Tensor::from_u32(vec![2], &[0xdead_beef, 7]));
        let path = tmp("f64bits.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let back = loaded.require_f64("s", &[2, 3]).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed bits");
        }
        assert_eq!(
            loaded.require_u32("pos", &[2]).unwrap(),
            vec![0xdead_beef, 7]
        );
    }

    #[test]
    fn require_reports_missing_and_mismatched() {
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(vec![4], &[1.0, 2.0, 3.0, 4.0]));
        // Missing name: descriptive error, not a panic.
        let err = ck.require("nope").unwrap_err();
        assert!(format!("{err}").contains("nope"), "got: {err}");
        // Wrong dtype.
        let err =
            ck.require_typed("w", DType::F64, &[4]).unwrap_err();
        assert!(format!("{err}").contains("F32"), "got: {err}");
        assert!(format!("{err}").contains("F64"), "got: {err}");
        // Wrong shape.
        let err = ck.require_typed("w", DType::F32, &[2, 2]).unwrap_err();
        assert!(format!("{err}").contains("[2, 2]"), "got: {err}");
    }

    #[test]
    fn corrupted_crc_is_a_described_error() {
        let mut ck = Checkpoint::new();
        ck.insert("s", Tensor::from_f64(vec![3], &[1.0, 2.0, 3.0]));
        let path = tmp("crc_err.dkft");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "got: {err}");
    }

    #[test]
    fn scalar_tensor_rank_zero() {
        let mut ck = Checkpoint::new();
        ck.insert("lr", Tensor::from_f32(vec![], &[0.001]));
        let path = tmp("scalar.dkft");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let t = loaded.get("lr").unwrap();
        assert!(t.shape.is_empty());
        assert_eq!(t.as_f32().unwrap(), vec![0.001]);
    }
}
