//! Command-line parsing substrate (no clap in the offline environment).
//!
//! Grammar: `darkformer <command> [<subcommand>] [--flag value]...
//! [--switch]`. Flags may appear in any order; `--flag=value` is also
//! accepted. Unknown flags are an error (catches typos in experiment
//! sweeps).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: positionals + flag map.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    allowed: Vec<String>,
}

impl Args {
    /// Parse from raw args (without argv[0]). `allowed` lists valid flag
    /// names (without `--`); switches are flags that take no value and
    /// must be listed with a `!` prefix, e.g. `"!verbose"`.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        allowed: &[&str],
    ) -> Result<Self> {
        let mut args = Args {
            allowed: allowed.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let switch_names: Vec<&str> = allowed
            .iter()
            .filter_map(|s| s.strip_prefix('!'))
            .collect();
        let flag_names: Vec<&str> = allowed
            .iter()
            .filter(|s| !s.starts_with('!'))
            .copied()
            .collect();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                let (name, inline_value) = match flag.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (flag.to_string(), None),
                };
                if switch_names.contains(&name.as_str()) {
                    if inline_value.is_some() {
                        bail!("switch --{name} takes no value");
                    }
                    args.switches.push(name);
                } else if flag_names.contains(&name.as_str()) {
                    let value = match inline_value {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| {
                                anyhow::anyhow!("--{name} needs a value")
                            })?,
                    };
                    args.flags.insert(name, value);
                } else {
                    bail!("unknown flag --{name}");
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad float {v:?}")),
        }
    }

    /// Comma-separated float list.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("--{name}: bad float {p:?}")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated string list.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], allowed: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), allowed)
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(
            &["exp", "fig2", "--steps", "100", "--lr=0.5"],
            &["steps", "lr"],
        )
        .unwrap();
        assert_eq!(a.positionals, vec!["exp", "fig2"]);
        assert_eq!(a.u64_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(parse(&["--bogus", "1"], &["steps"]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&["--steps"], &["steps"]).is_err());
    }

    #[test]
    fn switches_take_no_value() {
        let a = parse(&["run", "--verbose"], &["!verbose"]).unwrap();
        assert!(a.has_switch("verbose"));
        assert!(parse(&["--verbose=yes"], &["!verbose"]).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--lrs", "0.1, 0.2,0.3"], &["lrs"]).unwrap();
        assert_eq!(
            a.f64_list_or("lrs", &[]).unwrap(),
            vec![0.1, 0.2, 0.3]
        );
        let b = parse(&[], &["lrs"]).unwrap();
        assert_eq!(b.f64_list_or("lrs", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &["steps"]).unwrap();
        assert_eq!(a.u64_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--steps", "abc"], &["steps"]).unwrap();
        assert!(a.u64_or("steps", 0).is_err());
    }
}
