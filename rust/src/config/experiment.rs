//! Typed experiment configuration: everything a training run needs,
//! loadable from TOML with CLI-friendly defaults.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::toml::{parse_toml, TomlDoc};

/// Learning-rate schedule shapes supported by the coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// Linear warmup then cosine decay to `final_frac * base_lr`.
    WarmupCosine { warmup_steps: u64, final_frac: f64 },
    /// Linear warmup then linear decay to `final_frac * base_lr`.
    WarmupLinear { warmup_steps: u64, final_frac: f64 },
}

impl LrSchedule {
    /// LR multiplier at `step` of `total` steps (both 0-based / exclusive).
    pub fn multiplier(&self, step: u64, total: u64) -> f64 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::WarmupCosine { warmup_steps, final_frac } => {
                if step < warmup_steps {
                    (step + 1) as f64 / warmup_steps.max(1) as f64
                } else {
                    let t = (step - warmup_steps) as f64
                        / (total.saturating_sub(warmup_steps)).max(1) as f64;
                    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                    final_frac + (1.0 - final_frac) * cos
                }
            }
            LrSchedule::WarmupLinear { warmup_steps, final_frac } => {
                if step < warmup_steps {
                    (step + 1) as f64 / warmup_steps.max(1) as f64
                } else {
                    let t = (step - warmup_steps) as f64
                        / (total.saturating_sub(warmup_steps)).max(1) as f64;
                    final_frac + (1.0 - final_frac) * (1.0 - t)
                }
            }
        }
    }
}

/// Which parameters train (Fig. 2/3 vs Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMode {
    /// All parameters (uses `train_step.hlo.txt`).
    Full,
    /// q/k/v projections + DARKFormer's M only (`train_step_qkv.hlo.txt`).
    QkvOnly,
}

impl TrainMode {
    pub fn program_name(&self) -> &'static str {
        match self {
            TrainMode::Full => "train_step",
            TrainMode::QkvOnly => "train_step_qkv",
        }
    }
}

/// Full description of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Root of the AOT artifacts (contains `<config>/<variant>/...`).
    pub artifacts_dir: PathBuf,
    /// Model size config name (must match an artifacts subdirectory).
    pub model_config: String,
    /// Attention variant.
    pub variant: String,
    pub mode: TrainMode,
    pub steps: u64,
    pub base_lr: f64,
    pub schedule: LrSchedule,
    /// Global-norm clip; <= 0 disables.
    pub clip: f64,
    pub seed: u64,
    /// Evaluate on the validation split every `eval_every` steps (0 = off).
    pub eval_every: u64,
    /// Checkpoint every `checkpoint_every` steps (0 = only at the end).
    pub checkpoint_every: u64,
    /// Start from this checkpoint instead of `init` (finetuning).
    pub init_checkpoint: Option<PathBuf>,
    /// Output directory for metrics + checkpoints.
    pub out_dir: PathBuf,
    /// Corpus synthesis: number of documents.
    pub corpus_docs: usize,
    /// Loader prefetch depth (bounded-channel backpressure).
    pub prefetch_depth: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            model_config: "tiny".into(),
            variant: "darkformer".into(),
            mode: TrainMode::Full,
            steps: 50,
            base_lr: 1e-3,
            schedule: LrSchedule::Constant,
            clip: 1.0,
            seed: 42,
            eval_every: 0,
            checkpoint_every: 0,
            init_checkpoint: None,
            out_dir: PathBuf::from("runs/default"),
            corpus_docs: 2000,
            prefetch_depth: 4,
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML text. Unknown keys are ignored (forward compat);
    /// structural errors and bad enum values are hard errors.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parse_toml(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_doc(&doc)
    }

    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = ExperimentConfig::default();
        let mode = match doc.str_or("train", "mode", "full") {
            "full" => TrainMode::Full,
            "qkv" | "qkv_only" => TrainMode::QkvOnly,
            other => bail!("unknown train mode {other:?}"),
        };
        let schedule = match doc.str_or("train", "schedule", "constant") {
            "constant" => LrSchedule::Constant,
            "warmup_cosine" => LrSchedule::WarmupCosine {
                warmup_steps: doc.i64_or("train", "warmup_steps", 20) as u64,
                final_frac: doc.f64_or("train", "final_frac", 0.1),
            },
            "warmup_linear" => LrSchedule::WarmupLinear {
                warmup_steps: doc.i64_or("train", "warmup_steps", 20) as u64,
                final_frac: doc.f64_or("train", "final_frac", 0.1),
            },
            other => bail!("unknown schedule {other:?}"),
        };
        let init_checkpoint = doc
            .get("train", "init_checkpoint")
            .and_then(|v| v.as_str())
            .map(PathBuf::from);
        Ok(Self {
            artifacts_dir: PathBuf::from(doc.str_or(
                "",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
            model_config: doc.str_or("", "model_config", &d.model_config).into(),
            variant: doc.str_or("", "variant", &d.variant).into(),
            mode,
            steps: doc.i64_or("train", "steps", d.steps as i64) as u64,
            base_lr: doc.f64_or("train", "base_lr", d.base_lr),
            schedule,
            clip: doc.f64_or("train", "clip", d.clip),
            seed: doc.i64_or("", "seed", d.seed as i64) as u64,
            eval_every: doc.i64_or("train", "eval_every", 0) as u64,
            checkpoint_every: doc.i64_or("train", "checkpoint_every", 0) as u64,
            init_checkpoint,
            out_dir: PathBuf::from(doc.str_or(
                "",
                "out_dir",
                d.out_dir.to_str().unwrap(),
            )),
            corpus_docs: doc.i64_or("data", "corpus_docs", d.corpus_docs as i64)
                as usize,
            prefetch_depth: doc.i64_or("data", "prefetch_depth", 4) as usize,
        })
    }

    /// LR at a given step under this config's schedule.
    pub fn lr_at(&self, step: u64) -> f64 {
        self.base_lr * self.schedule.multiplier(step, self.steps)
    }

    pub fn variant_dir(&self) -> PathBuf {
        self.artifacts_dir.join(&self.model_config).join(&self.variant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_toml() {
        let cfg = ExperimentConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.variant, "darkformer");
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.mode, TrainMode::Full);
    }

    #[test]
    fn full_document_parses() {
        let text = r#"
model_config = "small"
variant = "performer"
seed = 7
out_dir = "runs/x"

[train]
steps = 300
base_lr = 5e-4
schedule = "warmup_cosine"
warmup_steps = 30
final_frac = 0.05
clip = 0.0
mode = "qkv"
eval_every = 50

[data]
corpus_docs = 5000
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.model_config, "small");
        assert_eq!(cfg.variant, "performer");
        assert_eq!(cfg.steps, 300);
        assert_eq!(cfg.mode, TrainMode::QkvOnly);
        assert_eq!(cfg.clip, 0.0);
        assert_eq!(cfg.corpus_docs, 5000);
        match cfg.schedule {
            LrSchedule::WarmupCosine { warmup_steps, final_frac } => {
                assert_eq!(warmup_steps, 30);
                assert!((final_frac - 0.05).abs() < 1e-12);
            }
            _ => panic!("wrong schedule"),
        }
    }

    #[test]
    fn rejects_unknown_mode() {
        assert!(
            ExperimentConfig::from_toml_str("[train]\nmode = \"bogus\"\n")
                .is_err()
        );
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { warmup_steps: 10, final_frac: 0.1 };
        // Ramps up during warmup.
        assert!(s.multiplier(0, 100) < s.multiplier(5, 100));
        assert!((s.multiplier(9, 100) - 1.0).abs() < 1e-9);
        // Decays after warmup.
        assert!(s.multiplier(50, 100) > s.multiplier(99, 100));
        // Ends near final_frac.
        assert!((s.multiplier(100, 100) - 0.1).abs() < 0.02);
    }

    #[test]
    fn schedule_monotonic_decay_after_warmup() {
        for sched in [
            LrSchedule::WarmupCosine { warmup_steps: 5, final_frac: 0.0 },
            LrSchedule::WarmupLinear { warmup_steps: 5, final_frac: 0.0 },
        ] {
            let mut prev = f64::INFINITY;
            for step in 5..200 {
                let m = sched.multiplier(step, 200);
                assert!(m <= prev + 1e-12, "not monotone at {step}");
                prev = m;
            }
        }
    }

    #[test]
    fn lr_at_composes_base_and_schedule() {
        let cfg = ExperimentConfig {
            base_lr: 2.0,
            schedule: LrSchedule::Constant,
            ..Default::default()
        };
        assert_eq!(cfg.lr_at(17), 2.0);
    }
}
