//! Configuration substrate: a TOML-subset parser and the typed experiment
//! configuration consumed by the coordinator and CLI.
//!
//! The environment has no serde/toml crates, so [`toml`] implements the
//! subset the project needs: `[section]` headers, string / integer /
//! float / bool scalars, homogeneous arrays, `#` comments.

mod experiment;
mod toml;

pub use experiment::{ExperimentConfig, LrSchedule, TrainMode};
pub use toml::{parse_toml, TomlDoc, TomlValue};
