//! TOML-subset parser (sections, scalars, arrays, comments).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: `section -> key -> value`. Top-level keys live in the
/// `""` section.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse_toml(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();
    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return Err(TomlError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                });
            };
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(TomlError {
                line: lineno,
                msg: format!("expected key = value, got {line:?}"),
            });
        };
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(TomlError { line: lineno, msg: "empty key".into() });
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        doc.sections.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<TomlValue, TomlError> {
    let err = |msg: &str| TomlError { line, msg: msg.to_string() };
    let text = text.trim();
    if text.is_empty() {
        return Err(err("missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return Err(err("unterminated string"));
        };
        // Basic escapes.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err(err("bad escape in string")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            return Err(err("unterminated array"));
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(&format!("cannot parse value {text:?}")))
}

/// Split top-level array items, respecting quoted strings (nested arrays
/// are not needed by this project's configs).
fn split_array_items(inner: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&inner[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_document() {
        let doc = parse_toml("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Str("hi".into())));
        assert_eq!(doc.get("", "d"), Some(&TomlValue::Bool(true)));
    }

    #[test]
    fn parses_sections() {
        let doc = parse_toml("[train]\nsteps = 100\n[eval]\nsteps = 10\n").unwrap();
        assert_eq!(doc.i64_or("train", "steps", 0), 100);
        assert_eq!(doc.i64_or("eval", "steps", 0), 10);
    }

    #[test]
    fn parses_arrays() {
        let doc = parse_toml("lrs = [0.1, 0.2, 0.3]\nnames = [\"a\", \"b\"]\n").unwrap();
        let lrs: Vec<f64> = doc
            .get("", "lrs")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(lrs, vec![0.1, 0.2, 0.3]);
        let names: Vec<&str> = doc
            .get("", "names")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn strips_comments_but_not_in_strings() {
        let doc =
            parse_toml("a = 1 # trailing\ns = \"has # inside\"\n").unwrap();
        assert_eq!(doc.i64_or("", "a", 0), 1);
        assert_eq!(doc.str_or("", "s", ""), "has # inside");
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = parse_toml("[x]\n").unwrap();
        assert_eq!(doc.f64_or("x", "missing", 1.25), 1.25);
        assert_eq!(doc.bool_or("y", "missing", true), true);
    }

    #[test]
    fn scientific_notation_floats() {
        let doc = parse_toml("lr = 3e-4\nbig = 1.5E6\n").unwrap();
        assert_eq!(doc.f64_or("", "lr", 0.0), 3e-4);
        assert_eq!(doc.f64_or("", "big", 0.0), 1.5e6);
    }

    #[test]
    fn error_reports_line_number() {
        let err = parse_toml("good = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn string_escapes() {
        let doc = parse_toml(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a\nb\t\"c\"");
    }
}
