//! Figure/table harnesses: each function regenerates one piece of the
//! paper's evaluation (DESIGN.md section 3 maps ids to paper figures).
//!
//! All harnesses write machine-readable CSV under the experiment output
//! root and print the headline comparison to stderr, so `darkformer exp
//! figN` is the full regeneration command for figure N.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use crate::config::{ExperimentConfig, LrSchedule, TrainMode};
use crate::linalg::Matrix;
#[cfg(feature = "pjrt")]
use crate::metrics::MetricLogger;
use crate::rfa::{
    self, estimators::Sampling, gaussian::anisotropic_covariance,
    gaussian::MultivariateGaussian, variance, PrfEstimator,
};
use crate::rng::Pcg64;

#[cfg(feature = "pjrt")]
use super::trainer::{TrainReport, Trainer};
#[cfg(feature = "pjrt")]
use super::workbench::Workbench;

/// Shared harness context.
pub struct ExpContext {
    pub artifacts_dir: PathBuf,
    pub model_config: String,
    pub out_root: PathBuf,
    pub seed: u64,
    pub corpus_docs: usize,
}

#[cfg(feature = "pjrt")]
impl ExpContext {
    fn workbench(&self) -> Result<Workbench> {
        Workbench::prepare(
            &self.artifacts_dir,
            &self.model_config,
            self.corpus_docs,
            self.seed,
            &self.out_root.join("_cache"),
        )
    }

    fn base_cfg(&self, variant: &str, out: &Path) -> ExperimentConfig {
        ExperimentConfig {
            artifacts_dir: self.artifacts_dir.clone(),
            model_config: self.model_config.clone(),
            variant: variant.to_string(),
            out_dir: out.to_path_buf(),
            seed: self.seed,
            corpus_docs: self.corpus_docs,
            ..Default::default()
        }
    }
}

#[cfg(feature = "pjrt")]
fn run_one(cfg: ExperimentConfig, wb: &Workbench) -> Result<TrainReport> {
    let trainer = Trainer::new(cfg.clone(), wb)?;
    eprintln!(
        "[exp] {} {} mode={:?} steps={} lr={}",
        cfg.model_config, cfg.variant, cfg.mode, cfg.steps, cfg.base_lr
    );
    trainer.run()
}

/// Merge per-variant metrics.jsonl files into one long-format CSV:
/// `step,variant,loss,acc,lr,grad_norm,wall_ms`.
#[cfg(feature = "pjrt")]
fn merge_curves(runs: &[(String, PathBuf)], out_csv: &Path) -> Result<()> {
    let mut csv = String::from("step,variant,loss,acc,lr,grad_norm,wall_ms\n");
    for (variant, metrics_path) in runs {
        for r in MetricLogger::read_all(metrics_path)? {
            writeln!(
                csv,
                "{},{},{},{},{},{},{}",
                r.step, variant, r.loss, r.acc, r.lr, r.grad_norm, r.wall_ms
            )?;
        }
    }
    std::fs::create_dir_all(out_csv.parent().context("csv parent")?)?;
    std::fs::write(out_csv, csv)?;
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_report_table(title: &str, reports: &[TrainReport]) {
    eprintln!("\n=== {title} ===");
    eprintln!(
        "{:<12} {:>8} {:>10} {:>10} {:>10} {:>8} {:>10}",
        "variant", "steps", "loss", "acc", "tail_acc", "spikes", "ms/step"
    );
    for r in reports {
        eprintln!(
            "{:<12} {:>8} {:>10.4} {:>10.4} {:>10.4} {:>8} {:>10.1}",
            r.variant,
            r.steps,
            r.final_loss,
            r.final_acc,
            r.tail_acc,
            r.spike_events,
            r.mean_step_ms
        );
    }
}

// ---------------------------------------------------------------------
// Fig. 2 — pretraining + finetuning accuracy across all six variants
// ---------------------------------------------------------------------

pub const FIG2_VARIANTS: &[&str] =
    &["exact", "darkformer", "performer", "lfk", "random", "constant"];

/// Pretrain each variant from scratch; curves to `fig2/pretrain.csv`.
#[cfg(feature = "pjrt")]
pub fn fig2_pretrain(
    ctx: &ExpContext,
    variants: &[&str],
    steps: u64,
    base_lr: f64,
) -> Result<Vec<TrainReport>> {
    let wb = ctx.workbench()?;
    let root = ctx.out_root.join("fig2/pretrain");
    let mut reports = Vec::new();
    let mut runs = Vec::new();
    for &variant in variants {
        let mut cfg = ctx.base_cfg(variant, &root.join(variant));
        cfg.steps = steps;
        cfg.base_lr = base_lr;
        cfg.schedule = LrSchedule::WarmupCosine {
            warmup_steps: (steps / 10).max(5),
            final_frac: 0.1,
        };
        let report = run_one(cfg, &wb)?;
        runs.push((variant.to_string(), report.metrics_path.clone()));
        reports.push(report);
    }
    merge_curves(&runs, &root.join("../pretrain.csv"))?;
    print_report_table("Fig 2 (pretraining)", &reports);
    Ok(reports)
}

/// Ensure a pretrained exact-softmax checkpoint exists (the stand-in for
/// the paper's pretrained Gemma weights); returns its path.
#[cfg(feature = "pjrt")]
pub fn ensure_pretrained(
    ctx: &ExpContext,
    steps: u64,
    base_lr: f64,
) -> Result<PathBuf> {
    let dir = ctx.out_root.join("pretrained_exact");
    let ckpt = dir.join("final.dkft");
    if ckpt.exists() {
        return Ok(ckpt);
    }
    let wb = ctx.workbench()?;
    let mut cfg = ctx.base_cfg("exact", &dir);
    cfg.steps = steps;
    cfg.base_lr = base_lr;
    cfg.schedule = LrSchedule::WarmupCosine {
        warmup_steps: (steps / 10).max(5),
        final_frac: 0.1,
    };
    run_one(cfg, &wb)?;
    Ok(ckpt)
}

/// Finetune every variant from the shared exact-pretrained checkpoint.
#[cfg(feature = "pjrt")]
pub fn fig2_finetune(
    ctx: &ExpContext,
    variants: &[&str],
    pretrain_steps: u64,
    steps: u64,
    base_lr: f64,
) -> Result<Vec<TrainReport>> {
    let ckpt = ensure_pretrained(ctx, pretrain_steps, 3e-3)?;
    let wb = ctx.workbench()?;
    let root = ctx.out_root.join("fig2/finetune");
    let mut reports = Vec::new();
    let mut runs = Vec::new();
    for &variant in variants {
        let mut cfg = ctx.base_cfg(variant, &root.join(variant));
        cfg.steps = steps;
        cfg.base_lr = base_lr;
        cfg.init_checkpoint = Some(ckpt.clone());
        let report = run_one(cfg, &wb)?;
        runs.push((variant.to_string(), report.metrics_path.clone()));
        reports.push(report);
    }
    merge_curves(&runs, &root.join("../finetune.csv"))?;
    print_report_table("Fig 2 (finetuning)", &reports);
    Ok(reports)
}

// ---------------------------------------------------------------------
// Fig. 3 — extended finetuning (Performer slowly closes the gap)
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub fn fig3_long_finetune(
    ctx: &ExpContext,
    pretrain_steps: u64,
    steps: u64,
    base_lr: f64,
) -> Result<Vec<TrainReport>> {
    let ckpt = ensure_pretrained(ctx, pretrain_steps, 3e-3)?;
    let wb = ctx.workbench()?;
    let root = ctx.out_root.join("fig3");
    let mut reports = Vec::new();
    let mut runs = Vec::new();
    for variant in ["exact", "darkformer", "performer"] {
        let mut cfg = ctx.base_cfg(variant, &root.join(variant));
        cfg.steps = steps;
        cfg.base_lr = base_lr;
        cfg.init_checkpoint = Some(ckpt.clone());
        let report = run_one(cfg, &wb)?;
        runs.push((variant.to_string(), report.metrics_path.clone()));
        reports.push(report);
    }
    merge_curves(&runs, &root.join("curves.csv"))?;
    print_report_table("Fig 3 (long finetune)", &reports);
    Ok(reports)
}

// ---------------------------------------------------------------------
// Fig. 4 — qkv-only partial finetuning
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub fn fig4_qkv_finetune(
    ctx: &ExpContext,
    pretrain_steps: u64,
    steps: u64,
    base_lr: f64,
) -> Result<Vec<TrainReport>> {
    let ckpt = ensure_pretrained(ctx, pretrain_steps, 3e-3)?;
    let wb = ctx.workbench()?;
    let root = ctx.out_root.join("fig4");
    let mut reports = Vec::new();
    let mut runs = Vec::new();
    for variant in ["exact", "darkformer", "performer"] {
        let mut cfg = ctx.base_cfg(variant, &root.join(variant));
        cfg.steps = steps;
        cfg.base_lr = base_lr;
        cfg.mode = TrainMode::QkvOnly;
        cfg.init_checkpoint = Some(ckpt.clone());
        let report = run_one(cfg, &wb)?;
        runs.push((variant.to_string(), report.metrics_path.clone()));
        reports.push(report);
    }
    merge_curves(&runs, &root.join("curves.csv"))?;
    print_report_table("Fig 4 (qkv-only finetune)", &reports);
    Ok(reports)
}

// ---------------------------------------------------------------------
// Fig. 5 — learning-rate sweep stability
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub fn fig5_lr_sweep(
    ctx: &ExpContext,
    pretrain_steps: u64,
    steps: u64,
    lrs: &[f64],
) -> Result<()> {
    let ckpt = ensure_pretrained(ctx, pretrain_steps, 3e-3)?;
    let wb = ctx.workbench()?;
    let root = ctx.out_root.join("fig5");
    let mut summary = String::from(
        "variant,lr,spike_events,spike_fraction,final_loss,final_acc\n",
    );
    let mut runs = Vec::new();
    for variant in ["darkformer", "performer"] {
        for (i, &lr) in lrs.iter().enumerate() {
            let mut cfg = ctx
                .base_cfg(variant, &root.join(format!("{variant}_lr{i}")));
            cfg.steps = steps;
            cfg.base_lr = lr;
            cfg.clip = 0.0; // Stability probes want raw updates.
            cfg.init_checkpoint = Some(ckpt.clone());
            let report = run_one(cfg, &wb)?;
            writeln!(
                summary,
                "{variant},{lr},{},{},{},{}",
                report.spike_events,
                report.spike_fraction,
                report.final_loss,
                report.final_acc
            )?;
            runs.push((
                format!("{variant}@{lr}"),
                report.metrics_path.clone(),
            ));
        }
    }
    std::fs::create_dir_all(&root)?;
    std::fs::write(root.join("stability.csv"), &summary)?;
    merge_curves(&runs, &root.join("curves.csv"))?;
    eprintln!("\n=== Fig 5 (LR sweep stability) ===\n{summary}");
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 1 — attention complexity scaling (exact O(L^2 d) vs PRF O(L m d))
// ---------------------------------------------------------------------

/// Time the attention-only probe artifacts across sequence lengths.
/// Writes `fig1/scaling.csv` with per-L mean wall time for both paths.
#[cfg(feature = "pjrt")]
pub fn fig1_scaling(
    ctx: &ExpContext,
    seq_lens: &[usize],
    reps: usize,
) -> Result<()> {
    use crate::runtime::Runtime;
    use std::time::Instant;

    let dir = ctx.artifacts_dir.join("scaling");
    anyhow::ensure!(
        dir.exists(),
        "no scaling probes at {} — run `make artifacts`",
        dir.display()
    );
    let meta = crate::ser::parse(&std::fs::read_to_string(
        dir.join("meta.json"),
    )?)
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let h = meta.field("n_heads").and_then(|v| v.as_usize()).unwrap_or(4);
    let dh = meta.field("head_dim").and_then(|v| v.as_usize()).unwrap_or(32);

    let runtime = Runtime::cpu()?;
    let mut rng = Pcg64::seed(ctx.seed);
    let mut csv = String::from("seq_len,variant,mean_ms,min_ms\n");
    eprintln!("\n=== Fig 1: attention wall-clock vs sequence length ===");
    eprintln!("{:>8} {:>12} {:>12} {:>12}", "L", "exact ms", "prf ms", "speedup");
    for &l in seq_lens {
        let mut times = Vec::new();
        for variant in ["exact", "performer"] {
            let path = dir.join(format!("attn_{variant}_L{l}.hlo.txt"));
            if !path.exists() {
                eprintln!("  (skipping L={l}: {} missing)", path.display());
                continue;
            }
            let program = runtime.load_program(&path)?;
            let n = h * l * dh;
            let mk = |rng: &mut Pcg64| {
                let data: Vec<f32> =
                    (0..n).map(|_| rng.next_f32() - 0.5).collect();
                xla::Literal::vec1(&data)
                    .reshape(&[1, h as i64, l as i64, dh as i64])
                    .map_err(|e| anyhow::anyhow!("{e:?}"))
            };
            let q = mk(&mut rng)?;
            let k = mk(&mut rng)?;
            let v = mk(&mut rng)?;
            let seed = xla::Literal::scalar(7u32);
            // Warmup.
            program.run(&[&q, &k, &v, &seed].map(|x| x.clone()))?;
            let mut mean = 0.0;
            let mut min = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                program.run(&[&q, &k, &v, &seed].map(|x| x.clone()))?;
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                mean += ms;
                min = min.min(ms);
            }
            mean /= reps as f64;
            writeln!(csv, "{l},{variant},{mean},{min}")?;
            times.push(mean);
        }
        if times.len() == 2 {
            eprintln!(
                "{:>8} {:>12.3} {:>12.3} {:>12.2}x",
                l,
                times[0],
                times[1],
                times[0] / times[1]
            );
        }
    }
    let out = ctx.out_root.join("fig1");
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("scaling.csv"), &csv)?;
    Ok(())
}

// ---------------------------------------------------------------------
// Theory tables — Theorem 3.2 variance + approximation error (pure Rust)
// ---------------------------------------------------------------------

/// Expected MC variance: isotropic vs optimal proposal, sweeping
/// anisotropy. Validates Theorem 3.2's strict ordering and its growth
/// with anisotropy.
pub fn variance_table(
    out_root: &Path,
    d: usize,
    m: usize,
    eps_grid: &[f64],
    seed: u64,
) -> Result<String> {
    let mut rng = Pcg64::seed(seed);
    let mut csv = String::from(
        "eps,anisotropy_index,var_isotropic,var_optimal,reduction_factor\n",
    );
    eprintln!("\n=== Theorem 3.2: expected MC variance (d={d}, m={m}) ===");
    eprintln!(
        "{:>6} {:>12} {:>14} {:>14} {:>10}",
        "eps", "aniso(Σ*)", "V(p_I)", "V(ψ*)", "V_I/V_ψ*"
    );
    for &eps in eps_grid {
        let lambda = anisotropic_covariance(d, 0.2, eps, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let sigma_star =
            rfa::optimal_proposal(&lambda).context("lambda too large")?;
        let aniso = rfa::proposal::anisotropy_index(&sigma_star);
        let psi = MultivariateGaussian::new(sigma_star).unwrap();

        let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
        let opt = PrfEstimator::new(d, m, Sampling::Proposal(psi));
        // Paired draws: the same (q, k) set for both estimators, so the
        // heavy-tailed across-pair variation cancels in the ratio.
        let (v_iso, v_opt) = variance::paired_expected_mc_variance(
            &iso, &opt, &dist, 200, 3000, &mut rng,
        );
        let factor = v_iso / v_opt;
        writeln!(csv, "{eps},{aniso},{v_iso},{v_opt},{factor}")?;
        eprintln!(
            "{:>6.2} {:>12.3} {:>14.6e} {:>14.6e} {:>10.3}",
            eps, aniso, v_iso, v_opt, factor
        );
    }
    std::fs::create_dir_all(out_root)?;
    std::fs::write(out_root.join("variance.csv"), &csv)?;
    Ok(csv)
}

/// Relative kernel-approximation error vs feature budget for the SAME
/// softmax-kernel target: isotropic sampling (Performer) vs the
/// data-aligned optimal proposal of Theorem 3.2 (the importance-sampled
/// estimator DARKFormer realizes implicitly, Prop. 4.1) — the §3-§4
/// "improves approximation under limited budgets" claim.
pub fn approx_table(
    out_root: &Path,
    d: usize,
    m_grid: &[usize],
    eps: f64,
    seed: u64,
) -> Result<String> {
    let mut rng = Pcg64::seed(seed);
    let lambda = anisotropic_covariance(d, 0.2, eps, &mut rng);
    let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    // The data-aligned sampling geometry for this input distribution.
    let sigma_star = rfa::optimal_proposal(&lambda).context("invalid")?;

    let mut csv = String::from("m,rel_mse_isotropic,rel_mse_aligned,ratio\n");
    eprintln!("\n=== Kernel approximation error (d={d}, eps={eps}) ===");
    eprintln!(
        "{:>6} {:>18} {:>18} {:>8}",
        "m", "relMSE isotropic", "relMSE aligned", "ratio"
    );
    for &m in m_grid {
        let iso = PrfEstimator::new(d, m, Sampling::Isotropic);
        let aligned = PrfEstimator::new(
            d,
            m,
            Sampling::Proposal(
                MultivariateGaussian::new(sigma_star.clone()).unwrap(),
            ),
        );
        let e_iso = variance::relative_mse(&iso, &dist, 80, 50, &mut rng);
        let e_ali = variance::relative_mse(&aligned, &dist, 80, 50, &mut rng);
        writeln!(csv, "{m},{e_iso},{e_ali},{}", e_iso / e_ali)?;
        eprintln!(
            "{:>6} {:>18.6e} {:>18.6e} {:>8.2}",
            m,
            e_iso,
            e_ali,
            e_iso / e_ali
        );
    }
    std::fs::create_dir_all(out_root)?;
    std::fs::write(out_root.join("approx.csv"), &csv)?;
    Ok(csv)
}

// ---------------------------------------------------------------------
// Extension: learned-geometry probe (Sigma = M^T M from a checkpoint)
// ---------------------------------------------------------------------

/// Analyze the learned PRF covariance in a DARKFormer checkpoint: per
/// (layer, head), the eigen-spread of `Sigma = M^T M`, its anisotropy
/// index, and Frobenius distance from identity — direct evidence that
/// finetuning moved the sampling geometry away from the isotropic
/// Performer point (M = I at init).
pub fn sigma_report(ckpt_path: &Path, out_csv: Option<&Path>) -> Result<String> {
    use crate::checkpoint::Checkpoint;

    let ck = Checkpoint::load(ckpt_path)?;
    let mut csv = String::from(
        "param,head,sigma_min_eig,sigma_max_eig,anisotropy,dist_from_identity\n",
    );
    eprintln!("\n=== learned Sigma = M^T M geometry: {} ===", ckpt_path.display());
    eprintln!(
        "{:<24} {:>4} {:>12} {:>12} {:>10} {:>10}",
        "param", "head", "min eig", "max eig", "aniso", "|Σ−I|_F"
    );
    let mut found = false;
    let names: Vec<String> = ck.names().cloned().collect();
    for name in names {
        // Model parameters only — not the AdamW moment mirrors
        // (opt_m/..., opt_v/...) that checkpoints also carry.
        if !name.ends_with("attn.m_proj") || name.starts_with("opt_") {
            continue;
        }
        found = true;
        let t = ck.get(&name).unwrap();
        anyhow::ensure!(t.shape.len() == 3, "m_proj must be (h, r, dh)");
        let (h, r, dh) = (t.shape[0], t.shape[1], t.shape[2]);
        let vals = t.as_f32()?;
        for head in 0..h {
            // M is (r, dh); Sigma = M^T M is (dh, dh).
            let mut m = Matrix::zeros(r, dh);
            for i in 0..r {
                for j in 0..dh {
                    m[(i, j)] = vals[head * r * dh + i * dh + j] as f64;
                }
            }
            let sigma = m.transpose().matmul(&m);
            let (eigs, _) = sigma.jacobi_eigen();
            let max = eigs[0];
            let min = *eigs.last().unwrap();
            let dist = sigma.sub(&Matrix::identity(dh)).frobenius_norm();
            let aniso = if min > 1e-12 { max / min } else { f64::INFINITY };
            writeln!(csv, "{name},{head},{min},{max},{aniso},{dist}")?;
            eprintln!(
                "{:<24} {:>4} {:>12.5} {:>12.5} {:>10.3} {:>10.4}",
                name, head, min, max, aniso, dist
            );
        }
    }
    anyhow::ensure!(
        found,
        "{} has no attn.m_proj tensors (not a DARKFormer checkpoint?)",
        ckpt_path.display()
    );
    if let Some(path) = out_csv {
        std::fs::create_dir_all(path.parent().context("csv parent")?)?;
        std::fs::write(path, &csv)?;
    }
    Ok(csv)
}

/// Empirical check that `Sigma*` reduces to a scalar multiple of I under
/// isotropic inputs (Theorem 3.2 item 1) — printed with the variance
/// table for completeness.
pub fn sigma_star_isotropy_check(d: usize) -> (f64, f64) {
    let lambda = Matrix::identity(d).scale(0.2);
    let sigma = rfa::optimal_proposal(&lambda).unwrap();
    let expected = rfa::proposal::optimal_eigenvalue(0.2);
    let diag_err = (0..d)
        .map(|i| (sigma[(i, i)] - expected).abs())
        .fold(0.0, f64::max);
    let off_err = (0..d)
        .flat_map(|i| (0..d).map(move |j| (i, j)))
        .filter(|&(i, j)| i != j)
        .map(|(i, j)| sigma[(i, j)].abs())
        .fold(0.0, f64::max);
    (diag_err, off_err)
}
