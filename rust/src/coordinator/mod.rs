//! L3 coordinator: training orchestration over the PJRT runtime.
//!
//! * [`train_state`] — host-side mirror of the flattened parameter /
//!   optimizer-state vectors, checkpoint save/restore, init-from-artifact.
//! * [`trainer`] — the training loop: data prefetch, LR schedule, step
//!   execution, eval cadence, metric logging, spike detection.
//! * [`workbench`] — shared setup (corpus synthesis, BPE training,
//!   dataset assembly) with on-disk caching so experiment sweeps don't
//!   redo corpus work per run.
//! * [`experiments`] — the paper's figure harnesses (Figs. 1-5 plus the
//!   theory tables); each regenerates one table/figure as CSV.

pub mod experiments;
#[cfg(feature = "pjrt")]
pub mod train_state;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod workbench;

#[cfg(feature = "pjrt")]
pub use train_state::TrainState;
#[cfg(feature = "pjrt")]
pub use trainer::{HotState, TrainReport, Trainer};
pub use workbench::Workbench;
