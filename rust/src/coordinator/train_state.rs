//! Host-side training state: parameters + AdamW moments in the canonical
//! manifest order, with checkpoint persistence.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{Checkpoint, Tensor};
use crate::runtime::{literal_to_tensor, tensor_to_literal, Manifest, Program};

/// Flattened model + optimizer state. Tensors are host copies in the
/// manifest's canonical (sorted-name) order; every train step round-trips
/// them through the PJRT executable.
pub struct TrainState {
    pub manifest: Manifest,
    pub params: Vec<Tensor>,
    pub opt_m: Vec<Tensor>,
    pub opt_v: Vec<Tensor>,
    pub step: u64,
}

impl TrainState {
    /// Initialize from the `init` artifact (fresh pretraining state).
    pub fn init(manifest: Manifest, init_program: &Program, seed: u32) -> Result<Self> {
        let seed_lit = xla::Literal::scalar(seed);
        let outs = init_program.run(&[seed_lit])?;
        if outs.len() != manifest.n_params() {
            bail!(
                "init returned {} tensors, manifest expects {}",
                outs.len(),
                manifest.n_params()
            );
        }
        let params = outs
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Self::with_params(manifest, params)
    }

    /// Wrap existing parameters with zeroed optimizer moments.
    pub fn with_params(manifest: Manifest, params: Vec<Tensor>) -> Result<Self> {
        for (t, spec) in params.iter().zip(&manifest.params) {
            if t.shape != spec.shape {
                bail!(
                    "param {} shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        let zeros: Vec<Tensor> = params
            .iter()
            .map(|t| {
                Tensor::from_f32(t.shape.clone(), &vec![0.0; t.element_count()])
            })
            .collect();
        Ok(Self {
            manifest,
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            step: 0,
        })
    }

    /// Number of parameter leaves.
    pub fn n_params(&self) -> usize {
        self.manifest.n_params()
    }

    /// Arguments prefix for train_step: params, opt_m, opt_v as literals.
    pub fn state_literals(&self) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(3 * self.n_params());
        for t in self.params.iter().chain(&self.opt_m).chain(&self.opt_v) {
            out.push(tensor_to_literal(t)?);
        }
        Ok(out)
    }

    /// Parameter-only literals (eval_step prefix).
    pub fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params.iter().map(tensor_to_literal).collect()
    }

    /// Absorb the train-step outputs: `params, opt_m, opt_v` (then the
    /// caller reads the scalar tail). Advances the step counter.
    pub fn absorb(&mut self, outs: &[xla::Literal]) -> Result<()> {
        let n = self.n_params();
        if outs.len() < 3 * n {
            bail!("train step returned {} outputs, need >= {}", outs.len(), 3 * n);
        }
        for i in 0..n {
            self.params[i] = literal_to_tensor(&outs[i])?;
            self.opt_m[i] = literal_to_tensor(&outs[n + i])?;
            self.opt_v[i] = literal_to_tensor(&outs[2 * n + i])?;
        }
        self.step += 1;
        Ok(())
    }

    /// Save params + moments + step to a checkpoint file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut ck = Checkpoint::new();
        for (spec, t) in self.manifest.params.iter().zip(&self.params) {
            ck.insert(spec.name.clone(), t.clone());
        }
        for (spec, t) in self.manifest.params.iter().zip(&self.opt_m) {
            ck.insert(format!("opt_m/{}", spec.name), t.clone());
        }
        for (spec, t) in self.manifest.params.iter().zip(&self.opt_v) {
            ck.insert(format!("opt_v/{}", spec.name), t.clone());
        }
        ck.insert("__step__", Tensor::from_i32(vec![], &[self.step as i32]));
        ck.save(path)
    }

    /// Restore from a checkpoint.
    ///
    /// `strict_optimizer = false` tolerates a params-only checkpoint
    /// (finetuning resets moments) and *always* tolerates missing
    /// variant-specific parameters: finetuning a DARKFormer from an
    /// exact-softmax pretrain must synthesize `attn.m_proj` (identity) —
    /// handled by `fill_missing`.
    pub fn load(
        manifest: Manifest,
        path: &Path,
        init_fallback: &[Tensor],
        reset_optimizer: bool,
    ) -> Result<Self> {
        let ck = Checkpoint::load(path)?;
        let mut params = Vec::with_capacity(manifest.n_params());
        for (i, spec) in manifest.params.iter().enumerate() {
            match ck.get(&spec.name) {
                Some(t) => {
                    if t.shape != spec.shape {
                        bail!(
                            "checkpoint {}: shape {:?} != manifest {:?}",
                            spec.name,
                            t.shape,
                            spec.shape
                        );
                    }
                    params.push(t.clone());
                }
                None => {
                    // Variant-specific parameter absent from the source
                    // checkpoint (e.g. m_proj when finetuning from exact).
                    let fb = init_fallback
                        .get(i)
                        .with_context(|| format!("no fallback for {}", spec.name))?;
                    params.push(fb.clone());
                }
            }
        }
        let mut state = Self::with_params(manifest, params)?;
        if !reset_optimizer {
            for (i, spec) in state.manifest.params.iter().enumerate() {
                if let Some(t) = ck.get(&format!("opt_m/{}", spec.name)) {
                    state.opt_m[i] = t.clone();
                }
                if let Some(t) = ck.get(&format!("opt_v/{}", spec.name)) {
                    state.opt_v[i] = t.clone();
                }
            }
            if let Some(t) = ck.get("__step__") {
                state.step = t.as_i32()?[0] as u64;
            }
        }
        Ok(state)
    }

    /// Parameter tensor by name (for probes/tests).
    pub fn param(&self, name: &str) -> Option<&Tensor> {
        let i = self.manifest.param_index(name)?;
        self.params.get(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;
    use crate::ser::parse;

    fn manifest2() -> Manifest {
        let v = parse(
            r#"{"variant":"x","config":"t","params":[
                {"name":"a","shape":[2,2],"dtype":"f32"},
                {"name":"b","shape":[3],"dtype":"f32"}],
                "programs":[]}"#,
        )
        .unwrap();
        Manifest::from_json(&v).unwrap()
    }

    fn tensors2() -> Vec<Tensor> {
        vec![
            Tensor::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]),
            Tensor::from_f32(vec![3], &[5.0, 6.0, 7.0]),
        ]
    }

    #[test]
    fn with_params_validates_shapes() {
        let m = manifest2();
        let bad = vec![
            Tensor::from_f32(vec![2, 2], &[0.0; 4]),
            Tensor::from_f32(vec![4], &[0.0; 4]),
        ];
        assert!(TrainState::with_params(m, bad).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("dkf_state_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.dkft");
        let mut st = TrainState::with_params(manifest2(), tensors2()).unwrap();
        st.step = 17;
        st.opt_m[0] = Tensor::from_f32(vec![2, 2], &[0.1; 4]);
        st.save(&path).unwrap();

        let loaded =
            TrainState::load(manifest2(), &path, &tensors2(), false).unwrap();
        assert_eq!(loaded.step, 17);
        assert_eq!(loaded.params[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(loaded.opt_m[0].as_f32().unwrap(), vec![0.1; 4]);
    }

    #[test]
    fn load_with_reset_optimizer_zeroes_moments() {
        let dir = std::env::temp_dir().join("dkf_state_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state2.dkft");
        let mut st = TrainState::with_params(manifest2(), tensors2()).unwrap();
        st.opt_m[0] = Tensor::from_f32(vec![2, 2], &[9.0; 4]);
        st.step = 5;
        st.save(&path).unwrap();

        let loaded =
            TrainState::load(manifest2(), &path, &tensors2(), true).unwrap();
        assert_eq!(loaded.step, 0);
        assert_eq!(loaded.opt_m[0].as_f32().unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn load_fills_missing_params_from_fallback() {
        // Save a checkpoint that only has "a"; manifest also wants "b".
        let dir = std::env::temp_dir().join("dkf_state_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("partial.dkft");
        let mut ck = Checkpoint::new();
        ck.insert("a", Tensor::from_f32(vec![2, 2], &[8.0; 4]));
        ck.save(&path).unwrap();

        let fallback = tensors2();
        let loaded =
            TrainState::load(manifest2(), &path, &fallback, true).unwrap();
        assert_eq!(loaded.params[0].as_f32().unwrap(), vec![8.0; 4]);
        // "b" came from the fallback (the variant's init).
        assert_eq!(loaded.params[1].as_f32().unwrap(), vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn param_lookup_by_name() {
        let st = TrainState::with_params(manifest2(), tensors2()).unwrap();
        assert_eq!(st.param("b").unwrap().as_f32().unwrap(), vec![5.0, 6.0, 7.0]);
        assert!(st.param("zz").is_none());
    }

    // Silence unused import warning (ParamSpec used implicitly via manifest).
    #[allow(dead_code)]
    fn _touch(_p: ParamSpec) {}
}
