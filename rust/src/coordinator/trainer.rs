//! The training loop: artifacts + data -> metrics + checkpoints.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::data::BatchStream;
use crate::metrics::{MetricLogger, SpikeDetector, StepRecord, Summary};
use crate::runtime::{Manifest, Program, Runtime};

use super::train_state::TrainState;
use super::workbench::Workbench;

/// Outcome summary of one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub variant: String,
    pub steps: u64,
    pub final_loss: f64,
    pub final_acc: f64,
    /// Mean training accuracy over the last 10% of steps (curve tail).
    pub tail_acc: f64,
    pub eval_loss: Option<f64>,
    pub eval_acc: Option<f64>,
    pub spike_events: usize,
    pub spike_fraction: f64,
    pub mean_step_ms: f64,
    pub metrics_path: PathBuf,
    pub checkpoint_path: PathBuf,
}

/// Orchestrates one run: loads programs, owns the step loop.
pub struct Trainer<'wb> {
    cfg: ExperimentConfig,
    wb: &'wb Workbench,
    runtime: Runtime,
    train_program: Program,
    eval_program: Program,
    init_program: Program,
}

impl<'wb> Trainer<'wb> {
    pub fn new(cfg: ExperimentConfig, wb: &'wb Workbench) -> Result<Self> {
        let dir = cfg.variant_dir();
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            bail!(
                "no artifacts at {} — run `make artifacts`",
                dir.display()
            );
        }
        let runtime = Runtime::cpu()?;
        let program_file = format!("{}.hlo.txt", cfg.mode.program_name());
        let train_program = runtime
            .load_program(&dir.join(&program_file))
            .with_context(|| format!("loading {program_file}"))?;
        let eval_program = runtime.load_program(&dir.join("eval_step.hlo.txt"))?;
        let init_program = runtime.load_program(&dir.join("init.hlo.txt"))?;
        Ok(Self { cfg, wb, runtime, train_program, eval_program, init_program })
    }

    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.cfg.variant_dir().join("manifest.json"))
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Build the initial state: fresh init, or checkpoint restore
    /// (finetuning: optimizer moments reset, missing variant-specific
    /// params filled from this variant's init).
    pub fn initial_state(&self) -> Result<TrainState> {
        let manifest = self.manifest()?;
        let init_seed = self.cfg.seed as u32;
        match &self.cfg.init_checkpoint {
            None => TrainState::init(manifest, &self.init_program, init_seed),
            Some(path) => {
                let fresh = TrainState::init(
                    manifest.clone(),
                    &self.init_program,
                    init_seed,
                )?;
                TrainState::load(manifest, path, &fresh.params, true)
            }
        }
    }

    /// Run the configured number of steps. Returns the report; metrics go
    /// to `<out_dir>/metrics.jsonl`, the final state to
    /// `<out_dir>/final.dkft`.
    pub fn run(&self) -> Result<TrainReport> {
        let mut state = self.initial_state()?;
        self.run_from(&mut state)
    }

    pub fn run_from(&self, state: &mut TrainState) -> Result<TrainReport> {
        let cfg = &self.cfg;
        std::fs::create_dir_all(&cfg.out_dir)?;
        let metrics_path = cfg.out_dir.join("metrics.jsonl");
        let mut logger = MetricLogger::create(&metrics_path)?;
        let mut spikes = SpikeDetector::new(0.1, 0.5);
        let mut step_time = Summary::new();
        let mut tail = Summary::new();
        let tail_start = cfg.steps - (cfg.steps / 10).max(1);

        let mut batches = BatchStream::spawn(
            self.wb.dataset.clone(),
            self.wb.meta.batch_size,
            cfg.prefetch_depth,
            cfg.steps as usize,
            self.wb.batch_rng(cfg.seed),
        );

        let mut last = (f64::NAN, f64::NAN);
        let mut rng = crate::rng::Pcg64::seed_stream(cfg.seed, 0x5eed);
        // Hot-loop fast path (§Perf): keep the model/optimizer state as
        // PJRT literals between steps, converting to host tensors only at
        // checkpoint/eval boundaries. Saves two full state copies per step
        // versus round-tripping through `TrainState::absorb`.
        let mut hot = HotState::from_state(state)?;
        for step in 0..cfg.steps {
            let batch = batches
                .next()
                .context("batch stream ended early")?;
            let lr = cfg.lr_at(step) as f32;
            let noise_seed = rng.next_u32();
            let t0 = Instant::now();
            let (loss, acc, gnorm) =
                self.train_step_literals(&mut hot, &batch, noise_seed, lr)?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

            step_time.update(wall_ms);
            spikes.observe(loss);
            if step >= tail_start {
                tail.update(acc);
            }
            last = (loss, acc);
            logger.log(&StepRecord {
                step,
                loss,
                acc,
                lr: lr as f64,
                grad_norm: gnorm,
                wall_ms,
            })?;

            if cfg.checkpoint_every > 0
                && (step + 1) % cfg.checkpoint_every == 0
            {
                hot.sync_to_state(state)?;
                state.save(&cfg.out_dir.join(format!("step{:06}.dkft", step + 1)))?;
            }
            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                hot.sync_to_state(state)?;
                let (el, ea) = self.evaluate(state, 4)?;
                eprintln!(
                    "[{}] step {:>5} loss {:.4} acc {:.4} | eval loss {:.4} acc {:.4}",
                    cfg.variant, step + 1, loss, acc, el, ea
                );
            }
        }
        logger.flush()?;
        hot.sync_to_state(state)?;

        let checkpoint_path = cfg.out_dir.join("final.dkft");
        state.save(&checkpoint_path)?;

        let (eval_loss, eval_acc) = if cfg.eval_every > 0 {
            let (l, a) = self.evaluate(state, 8)?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        Ok(TrainReport {
            variant: cfg.variant.clone(),
            steps: cfg.steps,
            final_loss: last.0,
            final_acc: last.1,
            tail_acc: tail.mean(),
            eval_loss,
            eval_acc,
            spike_events: spikes.events(),
            spike_fraction: spikes.spike_fraction(),
            mean_step_ms: step_time.mean(),
            metrics_path,
            checkpoint_path,
        })
    }

    /// Literal-resident variant of [`Trainer::train_step`] — the hot-loop
    /// fast path. State stays as `xla::Literal`s between steps; the step
    /// counter lives in `hot.step`.
    pub fn train_step_literals(
        &self,
        hot: &mut HotState,
        batch: &[i32],
        noise_seed: u32,
        lr: f32,
    ) -> Result<(f64, f64, f64)> {
        let n = hot.n_params;
        let mut args = Vec::with_capacity(3 * n + 5);
        args.append(&mut hot.state); // moved into args; rebuilt from outs
        args.push(self.tokens_literal(batch)?);
        args.push(xla::Literal::scalar(noise_seed));
        args.push(xla::Literal::scalar(lr));
        args.push(xla::Literal::scalar(self.cfg.clip as f32));
        args.push(xla::Literal::scalar(hot.step as i32));
        let mut outs = self.train_program.run(&args)?;
        if outs.len() != 3 * n + 3 {
            bail!(
                "train step returned {} outputs, expected {}",
                outs.len(),
                3 * n + 3
            );
        }
        let gnorm = scalar_f64(&outs[3 * n + 2])?;
        let acc = scalar_f64(&outs[3 * n + 1])?;
        let loss = scalar_f64(&outs[3 * n])?;
        outs.truncate(3 * n);
        hot.state = outs;
        hot.step += 1;
        Ok((loss, acc, gnorm))
    }

    /// One optimizer step. `batch` is row-major `(batch, seq_len+1)` i32.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        batch: &[i32],
        noise_seed: u32,
        lr: f32,
    ) -> Result<(f64, f64, f64)> {
        let mut args = state.state_literals()?;
        args.push(self.tokens_literal(batch)?);
        args.push(xla::Literal::scalar(noise_seed));
        args.push(xla::Literal::scalar(lr));
        args.push(xla::Literal::scalar(self.cfg.clip as f32));
        args.push(xla::Literal::scalar(state.step as i32));
        let outs = self.train_program.run(&args)?;
        let n = state.n_params();
        if outs.len() != 3 * n + 3 {
            bail!("train step returned {} outputs, expected {}", outs.len(), 3 * n + 3);
        }
        let loss = scalar_f64(&outs[3 * n])?;
        let acc = scalar_f64(&outs[3 * n + 1])?;
        let gnorm = scalar_f64(&outs[3 * n + 2])?;
        state.absorb(&outs)?;
        Ok((loss, acc, gnorm))
    }

    /// Mean (loss, acc) over up to `max_batches` validation batches.
    pub fn evaluate(
        &self,
        state: &TrainState,
        max_batches: usize,
    ) -> Result<(f64, f64)> {
        let batches = self.wb.dataset.valid_batches(self.wb.meta.batch_size);
        let take = batches.len().min(max_batches.max(1));
        anyhow::ensure!(take > 0, "validation split produced no batches");
        let mut loss = 0.0;
        let mut acc = 0.0;
        for (i, b) in batches.iter().take(take).enumerate() {
            let mut args = state.param_literals()?;
            args.push(self.tokens_literal(b)?);
            // Fixed eval seed: deterministic feature draw per batch.
            args.push(xla::Literal::scalar(0xe7a1u32 + i as u32));
            let outs = self.eval_program.run(&args)?;
            loss += scalar_f64(&outs[0])?;
            acc += scalar_f64(&outs[1])?;
        }
        Ok((loss / take as f64, acc / take as f64))
    }

    fn tokens_literal(&self, batch: &[i32]) -> Result<xla::Literal> {
        let rows = self.wb.meta.batch_size as i64;
        let cols = (self.wb.meta.seq_len + 1) as i64;
        anyhow::ensure!(
            batch.len() as i64 == rows * cols,
            "batch has {} tokens, expected {}",
            batch.len(),
            rows * cols
        );
        xla::Literal::vec1(batch)
            .reshape(&[rows, cols])
            .map_err(|e| anyhow::anyhow!("{e:?}"))
    }
}

fn scalar_f64(lit: &xla::Literal) -> Result<f64> {
    lit.get_first_element::<f32>()
        .map(|v| v as f64)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Literal-resident training state for the hot loop (§Perf): the flat
/// `params ++ opt_m ++ opt_v` literal vector in manifest order, avoiding
/// the Tensor<->Literal conversions of [`TrainState`] on every step.
pub struct HotState {
    state: Vec<xla::Literal>,
    n_params: usize,
    step: u64,
}

impl HotState {
    pub fn from_state(state: &TrainState) -> Result<Self> {
        Ok(Self {
            state: state.state_literals()?,
            n_params: state.n_params(),
            step: state.step,
        })
    }

    /// Write the literal state back into the host-tensor mirror (for
    /// checkpointing / eval).
    pub fn sync_to_state(&self, state: &mut TrainState) -> Result<()> {
        use crate::runtime::literal_to_tensor;
        anyhow::ensure!(self.state.len() == 3 * self.n_params);
        for i in 0..self.n_params {
            state.params[i] = literal_to_tensor(&self.state[i])?;
            state.opt_m[i] =
                literal_to_tensor(&self.state[self.n_params + i])?;
            state.opt_v[i] =
                literal_to_tensor(&self.state[2 * self.n_params + i])?;
        }
        state.step = self.step;
        Ok(())
    }

    pub fn step(&self) -> u64 {
        self.step
    }
}
