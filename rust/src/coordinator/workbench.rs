//! Shared experiment setup: corpus synthesis, BPE training, dataset
//! assembly — cached on disk so multi-run sweeps (Figs. 2-5) pay the cost
//! once per (seed, size) rather than once per run.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::{CorpusGenerator, CorpusSpec, TokenDataset};
use crate::rng::Pcg64;
use crate::runtime::ModelMeta;
use crate::tokenizer::{Bpe, BpeTrainer};

/// Prepared data context for a model config.
pub struct Workbench {
    pub meta: ModelMeta,
    pub bpe: Bpe,
    pub dataset: Arc<TokenDataset>,
    pub cache_dir: PathBuf,
}

impl Workbench {
    /// Build (or load from cache) the corpus, tokenizer and dataset for a
    /// model config. `data_seed` controls corpus synthesis only — model
    /// init/order seeds are separate, so data is shared across variants.
    pub fn prepare(
        artifacts_dir: &Path,
        model_config: &str,
        corpus_docs: usize,
        data_seed: u64,
        cache_dir: &Path,
    ) -> Result<Self> {
        let meta =
            ModelMeta::load(&artifacts_dir.join(model_config).join("meta.json"))
                .with_context(|| {
                    format!(
                        "loading meta for {model_config} — run `make artifacts`?"
                    )
                })?;
        std::fs::create_dir_all(cache_dir)?;

        // Corpus: cached as plain text.
        let corpus_path =
            cache_dir.join(format!("corpus_s{data_seed}_d{corpus_docs}.txt"));
        let corpus = if corpus_path.exists() {
            std::fs::read_to_string(&corpus_path)?
        } else {
            let mut gen =
                CorpusGenerator::new(CorpusSpec::default(), data_seed);
            let text = gen.documents(corpus_docs);
            std::fs::write(&corpus_path, &text)?;
            text
        };

        // BPE: cached in the line format of `Bpe::save`.
        let bpe_path = cache_dir.join(format!(
            "bpe_v{}_s{data_seed}_d{corpus_docs}.bpe",
            meta.vocab_size
        ));
        let bpe = if bpe_path.exists() {
            Bpe::load(&bpe_path)?
        } else {
            let trained =
                BpeTrainer::new(meta.vocab_size).train(corpus.as_bytes())?;
            trained.save(&bpe_path)?;
            trained
        };
        anyhow::ensure!(
            bpe.vocab_size() <= meta.vocab_size,
            "tokenizer vocab {} exceeds model vocab {}",
            bpe.vocab_size(),
            meta.vocab_size
        );

        let dataset = Arc::new(TokenDataset::from_text(
            &corpus,
            &bpe,
            meta.seq_len,
            0.05,
        )?);
        Ok(Self {
            meta,
            bpe,
            dataset,
            cache_dir: cache_dir.to_path_buf(),
        })
    }

    /// Seeded RNG for batch sampling, derived from a run seed so different
    /// variants see identical batch sequences under the same seed.
    pub fn batch_rng(&self, run_seed: u64) -> Pcg64 {
        Pcg64::seed_stream(run_seed, 0xba7c4)
    }
}
