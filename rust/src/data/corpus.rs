//! Synthetic C4-like corpus generator.
//!
//! Documents are built from a procedurally generated lexicon:
//!
//! * content words drawn from a Zipf distribution (frequent short stems,
//!   long tail), partitioned into topics;
//! * each document samples a topic and mixes topic words with a shared
//!   core vocabulary, so there is *learnable long-range structure*
//!   (topic consistency) as well as local structure (syntax templates);
//! * sentences follow simple grammatical templates with function words,
//!   inflection suffixes and punctuation.
//!
//! This yields text whose unigram/bigram statistics and document shape
//! resemble web text closely enough for BPE training and next-token
//! curves, while being fully reproducible from a seed.

use crate::rng::Pcg64;

const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ou"];
const ONSETS: &[&str] = &[
    "b", "c", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s",
    "t", "v", "w", "br", "ch", "cl", "dr", "fl", "gr", "pl", "pr", "sh",
    "sl", "st", "str", "th", "tr",
];
const CODAS: &[&str] =
    &["", "", "", "n", "r", "s", "t", "l", "m", "nd", "st", "rk", "nt"];

const DETERMINERS: &[&str] = &["the", "a", "this", "that", "each", "some"];
const PREPOSITIONS: &[&str] =
    &["of", "in", "on", "with", "from", "over", "under", "through"];
const CONJUNCTIONS: &[&str] = &["and", "but", "while", "because", "so"];
const PRONOUNS: &[&str] = &["it", "they", "we", "she", "he"];
const AUXILIARIES: &[&str] = &["is", "was", "can", "will", "must", "may"];

/// Corpus shape parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// Number of distinct content stems in the lexicon.
    pub lexicon_size: usize,
    /// Number of topics partitioning the content lexicon.
    pub n_topics: usize,
    /// Zipf exponent for stem frequencies (web text ~ 1.0-1.2).
    pub zipf_s: f64,
    /// Sentences per document: uniform in [min, max].
    pub sentences_per_doc: (usize, usize),
    /// Probability a content slot uses the document topic's sub-lexicon.
    pub topic_adherence: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            lexicon_size: 2000,
            n_topics: 8,
            zipf_s: 1.1,
            sentences_per_doc: (3, 9),
            topic_adherence: 0.7,
        }
    }
}

/// Deterministic document generator.
pub struct CorpusGenerator {
    spec: CorpusSpec,
    nouns: Vec<String>,
    verbs: Vec<String>,
    adjectives: Vec<String>,
    /// Cumulative Zipf distribution over lexicon ranks.
    zipf_cdf: Vec<f64>,
    rng: Pcg64,
}

impl CorpusGenerator {
    pub fn new(spec: CorpusSpec, seed: u64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0xc0e9);
        let mut lex_rng = rng.split();
        let n = spec.lexicon_size;
        let nouns = (0..n).map(|_| make_stem(&mut lex_rng)).collect();
        let verbs = (0..n / 2).map(|_| make_stem(&mut lex_rng)).collect();
        let adjectives = (0..n / 3).map(|_| make_stem(&mut lex_rng)).collect();
        let mut zipf_cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(spec.zipf_s);
            zipf_cdf.push(acc);
        }
        for v in &mut zipf_cdf {
            *v /= acc;
        }
        Self { spec, nouns, verbs, adjectives, zipf_cdf, rng }
    }

    /// One document: topic-consistent sentences separated by spaces,
    /// terminated by a newline (the document boundary the BPE trainer and
    /// loader both respect).
    pub fn document(&mut self) -> String {
        let topic = self.rng.next_range(self.spec.n_topics as u64) as usize;
        let (lo, hi) = self.spec.sentences_per_doc;
        let n_sentences = lo + self.rng.next_range((hi - lo + 1) as u64) as usize;
        let mut doc = String::new();
        for i in 0..n_sentences {
            if i > 0 {
                doc.push(' ');
            }
            let s = self.sentence(topic);
            doc.push_str(&s);
        }
        doc.push('\n');
        doc
    }

    /// Generate `n` documents concatenated.
    pub fn documents(&mut self, n: usize) -> String {
        let mut out = String::new();
        for _ in 0..n {
            out.push_str(&self.document());
        }
        out
    }

    fn sentence(&mut self, topic: usize) -> String {
        let template = self.rng.next_range(4);
        let mut s = match template {
            0 => format!(
                "{} {} {} {} {} {}",
                pick(&mut self.rng, DETERMINERS),
                self.adjective(topic),
                self.noun(topic),
                self.verb(topic),
                pick(&mut self.rng, DETERMINERS),
                self.noun(topic),
            ),
            1 => format!(
                "{} {} {} {} {} {}",
                pick(&mut self.rng, PRONOUNS),
                pick(&mut self.rng, AUXILIARIES),
                self.verb(topic),
                pick(&mut self.rng, PREPOSITIONS),
                pick(&mut self.rng, DETERMINERS),
                self.noun(topic),
            ),
            2 => format!(
                "{} {} {} {} {} {} {} {}",
                pick(&mut self.rng, DETERMINERS),
                self.noun(topic),
                pick(&mut self.rng, PREPOSITIONS),
                pick(&mut self.rng, DETERMINERS),
                self.noun(topic),
                pick(&mut self.rng, AUXILIARIES),
                self.adjective(topic),
                pick(&mut self.rng, CONJUNCTIONS),
            ),
            _ => format!(
                "{} {} {} {}",
                pick(&mut self.rng, DETERMINERS),
                self.noun(topic),
                pick(&mut self.rng, AUXILIARIES),
                self.adjective(topic),
            ),
        };
        s.push('.');
        // Capitalize.
        if let Some(c) = s.get(0..1) {
            let up = c.to_uppercase();
            s.replace_range(0..1, &up);
        }
        s
    }

    /// Draw a lexicon rank ~ Zipf, optionally restricted to the topic's
    /// slice of the lexicon.
    fn zipf_rank(&mut self, len: usize, topic: Option<usize>) -> usize {
        let u = self.rng.next_f64();
        let rank = match self.zipf_cdf.binary_search_by(|p| {
            p.partial_cmp(&u).unwrap()
        }) {
            Ok(i) | Err(i) => i.min(self.zipf_cdf.len() - 1),
        };
        match topic {
            None => rank % len,
            Some(t) => {
                // Map the rank into the topic's stripe of the word list.
                let stripe = len / self.spec.n_topics;
                t * stripe + (rank % stripe.max(1))
            }
        }
    }

    fn topic_or_core(&mut self, topic: usize) -> Option<usize> {
        (self.rng.next_f64() < self.spec.topic_adherence).then_some(topic)
    }

    fn noun(&mut self, topic: usize) -> String {
        let t = self.topic_or_core(topic);
        let idx = self.zipf_rank(self.nouns.len(), t);
        let word = &self.nouns[idx];
        if self.rng.next_f64() < 0.25 {
            format!("{word}s")
        } else {
            word.clone()
        }
    }

    fn verb(&mut self, topic: usize) -> String {
        let t = self.topic_or_core(topic);
        let idx = self.zipf_rank(self.verbs.len(), t);
        let word = &self.verbs[idx];
        match self.rng.next_range(3) {
            0 => format!("{word}ed"),
            1 => format!("{word}ing"),
            _ => word.clone(),
        }
    }

    fn adjective(&mut self, topic: usize) -> String {
        let t = self.topic_or_core(topic);
        let idx = self.zipf_rank(self.adjectives.len(), t);
        self.adjectives[idx].clone()
    }
}

fn pick<'a>(rng: &mut Pcg64, options: &[&'a str]) -> &'a str {
    options[rng.next_range(options.len() as u64) as usize]
}

fn make_stem(rng: &mut Pcg64) -> String {
    let syllables = 1 + rng.next_range(3) as usize;
    let mut w = String::new();
    for _ in 0..syllables {
        w.push_str(pick(rng, ONSETS));
        w.push_str(pick(rng, VOWELS));
    }
    w.push_str(pick(rng, CODAS));
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_from_seed() {
        let mut a = CorpusGenerator::new(CorpusSpec::default(), 7);
        let mut b = CorpusGenerator::new(CorpusSpec::default(), 7);
        assert_eq!(a.documents(5), b.documents(5));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = CorpusGenerator::new(CorpusSpec::default(), 1);
        let mut b = CorpusGenerator::new(CorpusSpec::default(), 2);
        assert_ne!(a.documents(3), b.documents(3));
    }

    #[test]
    fn documents_end_with_newline_and_are_nonempty() {
        let mut g = CorpusGenerator::new(CorpusSpec::default(), 3);
        for _ in 0..20 {
            let d = g.document();
            assert!(d.ends_with('\n'));
            assert!(d.len() > 20, "doc too short: {d:?}");
            assert!(!d.trim_end().contains('\n'), "one doc per line");
        }
    }

    #[test]
    fn word_frequencies_are_heavy_tailed() {
        let mut g = CorpusGenerator::new(CorpusSpec::default(), 11);
        let text = g.documents(400);
        let mut counts: HashMap<&str, usize> = HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w).or_insert(0) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // Top word should dominate the median word by a large factor.
        let median = freqs[freqs.len() / 2];
        assert!(
            freqs[0] > median * 10,
            "top={} median={median}",
            freqs[0]
        );
    }

    #[test]
    fn topic_stripes_partition_the_lexicon() {
        // With full topic adherence, the content words drawn for topic t
        // must come from topic t's stripe of the word lists — the
        // mechanism that gives documents learnable long-range structure.
        let spec = CorpusSpec { topic_adherence: 1.0, ..Default::default() };
        let mut g = CorpusGenerator::new(spec.clone(), 17);
        let stripe = g.nouns.len() / spec.n_topics;
        for topic in 0..spec.n_topics {
            for _ in 0..50 {
                let idx = g.zipf_rank(g.nouns.len(), Some(topic));
                assert!(
                    (topic * stripe..(topic + 1) * stripe).contains(&idx),
                    "topic {topic} drew rank {idx} outside its stripe"
                );
            }
        }
    }

    #[test]
    fn topic_words_differ_across_topics() {
        // Sentences forced to different topics share only function words.
        let spec = CorpusSpec { topic_adherence: 1.0, ..Default::default() };
        let mut g = CorpusGenerator::new(spec, 19);
        let function_words: std::collections::HashSet<&str> = DETERMINERS
            .iter()
            .chain(PREPOSITIONS)
            .chain(CONJUNCTIONS)
            .chain(PRONOUNS)
            .chain(AUXILIARIES)
            .copied()
            .collect();
        let content = |s: &str| {
            s.to_lowercase()
                .split_whitespace()
                .map(|w| w.trim_matches('.').to_string())
                .filter(|w| !function_words.contains(w.as_str()))
                .collect::<std::collections::HashSet<_>>()
        };
        let a: std::collections::HashSet<_> = (0..30)
            .flat_map(|_| content(&g.sentence(0)))
            .collect();
        let b: std::collections::HashSet<_> = (0..30)
            .flat_map(|_| content(&g.sentence(4)))
            .collect();
        let inter = a.intersection(&b).count() as f64;
        let union = a.union(&b).count() as f64;
        assert!(
            inter / union < 0.2,
            "topics should use mostly disjoint content words (jaccard {})",
            inter / union
        );
    }
}
