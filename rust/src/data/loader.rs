//! Tokenized dataset + shuffled window sampling + prefetching stream.

use std::sync::mpsc;
use std::thread;

use anyhow::{bail, Result};

use crate::rng::Pcg64;
use crate::tokenizer::Bpe;

/// A token stream with train/validation split and random-window batching.
///
/// Language-model convention: a batch is `(batch_size, seq_len + 1)` i32
/// rows; the train step uses `[:, :-1]` as inputs and `[:, 1:]` as
/// targets.
pub struct TokenDataset {
    tokens: Vec<i32>,
    valid_start: usize,
    seq_len: usize,
}

impl TokenDataset {
    /// Tokenize a corpus and hold out the trailing `valid_frac` for eval.
    pub fn from_text(
        text: &str,
        bpe: &Bpe,
        seq_len: usize,
        valid_frac: f64,
    ) -> Result<Self> {
        let ids = bpe.encode(text);
        let tokens: Vec<i32> = ids.iter().map(|&t| t as i32).collect();
        let min_len = (seq_len + 1) * 2;
        if tokens.len() < min_len {
            bail!(
                "corpus too small: {} tokens < {min_len} required",
                tokens.len()
            );
        }
        let valid_start =
            ((tokens.len() as f64) * (1.0 - valid_frac)) as usize;
        Ok(Self { tokens, valid_start, seq_len })
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn window_at(&self, start: usize) -> &[i32] {
        &self.tokens[start..start + self.seq_len + 1]
    }

    /// Random training batch (windows drawn uniformly from the train
    /// split). Returns row-major `(batch, seq_len + 1)`.
    pub fn train_batch(&self, batch: usize, rng: &mut Pcg64) -> Vec<i32> {
        let hi = self.valid_start.saturating_sub(self.seq_len + 1);
        assert!(hi > 0, "train split smaller than one window");
        let mut out = Vec::with_capacity(batch * (self.seq_len + 1));
        for _ in 0..batch {
            let start = rng.next_range(hi as u64) as usize;
            out.extend_from_slice(self.window_at(start));
        }
        out
    }

    /// Deterministic validation batches covering the held-out split.
    pub fn valid_batches(&self, batch: usize) -> Vec<Vec<i32>> {
        let w = self.seq_len + 1;
        let mut starts = Vec::new();
        let mut s = self.valid_start;
        while s + w <= self.tokens.len() {
            starts.push(s);
            s += w;
        }
        starts
            .chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| {
                let mut rows = Vec::with_capacity(batch * w);
                for &start in c {
                    rows.extend_from_slice(self.window_at(start));
                }
                rows
            })
            .collect()
    }
}

/// Background-prefetched batch stream with bounded-channel backpressure:
/// a producer thread keeps at most `depth` batches in flight so batch
/// assembly overlaps the PJRT execute without unbounded memory growth.
pub struct BatchStream {
    rx: Option<mpsc::Receiver<Vec<i32>>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl BatchStream {
    pub fn spawn(
        dataset: std::sync::Arc<TokenDataset>,
        batch: usize,
        depth: usize,
        n_batches: usize,
        mut rng: Pcg64,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            for _ in 0..n_batches {
                let b = dataset.train_batch(batch, &mut rng);
                if tx.send(b).is_err() {
                    break; // Consumer hung up; stop producing.
                }
            }
        });
        Self { rx: Some(rx), handle: Some(handle) }
    }

    /// Next batch; `None` once the requested batch budget is exhausted.
    pub fn next(&mut self) -> Option<Vec<i32>> {
        self.rx.as_ref()?.recv().ok()
    }
}

impl Drop for BatchStream {
    fn drop(&mut self) {
        // Drop the receiver FIRST so a producer blocked on a full channel
        // sees a send error and exits; only then join.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusGenerator, CorpusSpec};
    use crate::tokenizer::BpeTrainer;
    use std::sync::Arc;

    fn tiny_dataset(seq_len: usize) -> TokenDataset {
        let mut g = CorpusGenerator::new(CorpusSpec::default(), 5);
        let text = g.documents(200);
        let bpe = BpeTrainer::new(300).train(text.as_bytes()).unwrap();
        TokenDataset::from_text(&text, &bpe, seq_len, 0.1).unwrap()
    }

    #[test]
    fn batch_shape_and_range() {
        let ds = tiny_dataset(16);
        let mut rng = Pcg64::seed(1);
        let b = ds.train_batch(4, &mut rng);
        assert_eq!(b.len(), 4 * 17);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 300 + 256));
    }

    #[test]
    fn windows_are_contiguous_token_runs() {
        let ds = tiny_dataset(8);
        let mut rng = Pcg64::seed(2);
        let b = ds.train_batch(1, &mut rng);
        // The window must appear verbatim in the underlying stream.
        let w: Vec<i32> = b.clone();
        let found = ds
            .tokens
            .windows(w.len())
            .any(|win| win == w.as_slice());
        assert!(found, "batch window not found in token stream");
    }

    #[test]
    fn train_windows_stay_out_of_validation_split() {
        let ds = tiny_dataset(8);
        let mut rng = Pcg64::seed(3);
        for _ in 0..200 {
            let _ = ds.train_batch(2, &mut rng);
        }
        // By construction: max start < valid_start - (seq_len+1). Sample
        // directly to double-check the bound.
        let hi = ds.valid_start - (ds.seq_len + 1);
        for _ in 0..1000 {
            let s = rng.next_range(hi as u64) as usize;
            assert!(s + ds.seq_len + 1 <= ds.valid_start);
        }
    }

    #[test]
    fn valid_batches_cover_holdout_deterministically() {
        let ds = tiny_dataset(8);
        let a = ds.valid_batches(2);
        let b = ds.valid_batches(2);
        assert!(!a.is_empty());
        assert_eq!(a, b);
        for batch in &a {
            assert_eq!(batch.len(), 2 * 9);
        }
    }

    #[test]
    fn rejects_corpus_smaller_than_a_window() {
        let bpe = BpeTrainer::new(260).train("tiny".as_bytes()).unwrap();
        assert!(TokenDataset::from_text("tiny", &bpe, 128, 0.1).is_err());
    }

    #[test]
    fn batch_stream_delivers_and_terminates() {
        let ds = Arc::new(tiny_dataset(8));
        let mut stream =
            BatchStream::spawn(ds, 2, 2, 5, Pcg64::seed(9));
        let mut n = 0;
        while let Some(b) = stream.next() {
            assert_eq!(b.len(), 2 * 9);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn batch_stream_drop_mid_stream_is_clean() {
        let ds = Arc::new(tiny_dataset(8));
        let mut stream =
            BatchStream::spawn(ds, 2, 1, 1000, Pcg64::seed(10));
        let _ = stream.next();
        drop(stream); // Must not deadlock.
    }

    #[test]
    fn stream_is_deterministic_given_rng() {
        let ds = Arc::new(tiny_dataset(8));
        let mut s1 = BatchStream::spawn(ds.clone(), 2, 2, 3, Pcg64::seed(4));
        let mut s2 = BatchStream::spawn(ds, 2, 2, 3, Pcg64::seed(4));
        for _ in 0..3 {
            assert_eq!(s1.next(), s2.next());
        }
    }
}
