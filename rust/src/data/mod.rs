//! Data substrate: synthetic corpus, tokenized dataset, streaming batcher.
//!
//! The paper trains on C4. That corpus (and the pretrained Gemma weights
//! that digested it) is not available here, so [`corpus`] synthesizes a
//! C4-like corpus: Zipf-distributed vocabulary, topic-conditioned content
//! words, grammatical sentence templates and document structure — enough
//! statistical signal for next-token prediction curves to be meaningful,
//! which is all the experiments need (DESIGN.md section 2).
//!
//! [`loader`] turns text + BPE into a token stream and serves shuffled
//! `(batch, seq_len + 1)` windows; [`loader::BatchStream`] adds a
//! prefetch thread with bounded-channel backpressure so tokenization never
//! blocks the train loop.

pub mod corpus;
pub mod loader;

pub use corpus::{CorpusGenerator, CorpusSpec};
pub use loader::{BatchStream, TokenDataset};
