//! DARKFormer — Data-Aware Random-feature Kernel transformer, full-stack
//! reproduction.
//!
//! Three layers (see DESIGN.md):
//! 1. **Pallas kernels** (`python/compile/kernels/`) — PRF feature maps and
//!    chunked causal linear attention, AOT-lowered to HLO text.
//! 2. **JAX model** (`python/compile/`) — Gemma-style decoder with six
//!    attention variants; `make artifacts` lowers init/train/eval steps.
//! 3. **This crate** — the runtime coordinator: loads the HLO artifacts via
//!    PJRT, owns data, training loops, experiments and benches. Python is
//!    never on the training path.
//! The crate also contains a pure-Rust reproduction of the paper's theory
//! ([`rfa`]): PRF estimators, the batched feature-map engine and
//! linear-attention forward, the optimal importance-sampling proposal of
//! Theorem 3.2, and Monte-Carlo variance measurement.
//!
//! Everything PJRT/XLA-dependent (the [`runtime`] program loader, the
//! trainer/figure harnesses in [`coordinator`], the `darkformer` binary)
//! is gated behind the `pjrt` cargo feature so the theory stack builds
//! and tests offline with no artifacts: `cargo build --release && cargo
//! test -q` is the artifact-free tier-1 path, `--features pjrt` compiles
//! the full coordinator (against the vendored `xla` stub by default).

pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;

pub mod data;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod rfa;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod tokenizer;
