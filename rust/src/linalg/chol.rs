//! Incremental Cholesky maintenance: rank-1 and blocked rank-k
//! up/downdates of a lower-triangular factor, in place.
//!
//! Given `L` lower triangular with `A = L·Lᵀ`, these kernels rewrite
//! `L` so the identity holds for `A ± x·xᵀ` in O(n²) per vector — the
//! workhorse behind `rfa::serve`'s maintained-factor resample epochs,
//! where refactorizing the shrunk second moment from scratch would pay
//! O(n³) per head per boundary (see "Online bank resampling: the epoch
//! contract" in `rfa::serve`).
//!
//! The recurrence is the classical plane-rotation scheme (Golub & Van
//! Loan §6.5.4): column `k` combines the old column with the carried
//! vector through a rotation chosen to zero the carried head entry.
//! Updates (`+x·xᵀ`) are unconditionally stable — adding a positive
//! semidefinite term keeps `A` SPD, and the new pivot
//! `r = √(L²ₖₖ + x²ₖ) ≥ Lₖₖ > 0` never cancels. Downdates (`−x·xᵀ`)
//! can leave the matrix indefinite, so they validate `L²ₖₖ − x²ₖ > 0`
//! at every pivot and report failure without touching `self` —
//! mirroring the `Option`-shaped SPD rejection of
//! [`Matrix::cholesky`].

use super::mat::Matrix;

impl Matrix {
    /// In-place rank-1 *update* of a lower Cholesky factor: on entry
    /// `self = L` with `A = L·Lᵀ`; on exit `self·selfᵀ = A + x·xᵀ`.
    ///
    /// O(n²), no allocation beyond one carried n-vector. The caller
    /// owns the invariant that `self` really is a Cholesky factor
    /// (lower triangular, strictly positive diagonal) — e.g. the
    /// output of [`Matrix::cholesky`] or a previous up/downdate; the
    /// strict upper triangle is neither read nor written.
    ///
    /// Panics if `self` is not square or `x.len()` mismatches.
    pub fn cholesky_update_rank1(&mut self, x: &[f64]) {
        assert_eq!(
            self.rows(),
            self.cols(),
            "cholesky_update_rank1 needs a square factor"
        );
        let n = self.rows();
        assert_eq!(x.len(), n, "update vector length mismatch");
        let mut w = x.to_vec();
        for k in 0..n {
            let lkk = self[(k, k)];
            let r = (lkk * lkk + w[k] * w[k]).sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            self[(k, k)] = r;
            for i in (k + 1)..n {
                self[(i, k)] = (self[(i, k)] + s * w[i]) / c;
                w[i] = c * w[i] - s * self[(i, k)];
            }
        }
    }

    /// In-place rank-1 *downdate*: on entry `self = L` with
    /// `A = L·Lᵀ`; on success `self·selfᵀ = A − x·xᵀ` and `true` is
    /// returned. If `A − x·xᵀ` is not positive definite (any pivot
    /// `L²ₖₖ − w²ₖ` hits zero or below), returns `false` and leaves
    /// `self` exactly as it was — SPD rejection is a clean refusal,
    /// never a half-applied factor.
    ///
    /// Panics if `self` is not square or `x.len()` mismatches.
    #[must_use = "a false return means the downdate was refused"]
    pub fn cholesky_downdate_rank1(&mut self, x: &[f64]) -> bool {
        assert_eq!(
            self.rows(),
            self.cols(),
            "cholesky_downdate_rank1 needs a square factor"
        );
        let n = self.rows();
        assert_eq!(x.len(), n, "downdate vector length mismatch");
        let mut l = self.clone();
        let mut w = x.to_vec();
        for k in 0..n {
            let lkk = l[(k, k)];
            let d = lkk * lkk - w[k] * w[k];
            if d <= 0.0 {
                return false;
            }
            let r = d.sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            l[(k, k)] = r;
            for i in (k + 1)..n {
                l[(i, k)] = (l[(i, k)] - s * w[i]) / c;
                w[i] = c * w[i] - s * l[(i, k)];
            }
        }
        *self = l;
        true
    }

    /// Blocked rank-k update: applies [`Matrix::cholesky_update_rank1`]
    /// to each row of `xs` in order, so on exit
    /// `self·selfᵀ = A + Σᵢ xsᵢ·xsᵀᵢ`. O(k·n²) total — the inter-epoch
    /// cost of folding `k` new key observations into a maintained
    /// second-moment factor. Application order is part of the bitwise
    /// result; callers that need determinism must fix it (the serving
    /// layer uses stream order).
    pub fn cholesky_update(&mut self, xs: &[Vec<f64>]) {
        for x in xs {
            self.cholesky_update_rank1(x);
        }
    }
}
