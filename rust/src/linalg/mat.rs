//! Row-major dense matrix, generic over the storage [`Scalar`].
//!
//! One kernel structure serves every precision: the tiled
//! [`Mat::matmul`], the transpose-free [`Mat::matmul_transb`] /
//! [`Mat::matmul_transa`] contractions, and the [`Scalar::dot`]-based
//! row kernels are written once against the trait. The inner loops run
//! through the sealed [`Scalar`] kernel hooks (`axpy`/`axpy4`, `dot`/
//! `dot4`, `accum_row`, `dot_seq_accum`), which dispatch into
//! [`crate::linalg::simd`] — explicit AVX2/AVX-512/NEON microkernels with
//! a portable fallback, all bitwise-identical, so this file only decides
//! *tiling and traversal order* and never sees an intrinsic. Length-L
//! reductions ([`Mat::col_sums`], [`Mat::matvec_accum`]) land in
//! [`Scalar::Accum`] per the accumulation-policy contract.
//!
//! Decompositions (Cholesky, eigen, inverses) stay f64-only in
//! `impl Mat<f64>` — they are setup-time operations where precision
//! matters and throughput does not; [`Matrix32`] deliberately carries
//! only the multiply/contract surface the attention hot path needs.

use std::fmt;
use std::ops::{Index, IndexMut};

use super::scalar::Scalar;

/// Dense `rows x cols` matrix of `T`, row-major.
///
/// [`Matrix`] (= `Mat<f64>`) is the default precision and carries every
/// decomposition; [`Matrix32`] (= `Mat<f32>`) is the attention engine's
/// SIMD hot path — half the memory traffic, twice the lanes per register.
#[derive(Clone, PartialEq)]
pub struct Mat<T: Scalar> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

/// f64 matrix — the default precision, with the full decomposition
/// surface the RFA analysis needs.
pub type Matrix = Mat<f64>;

/// f32 matrix — the SIMD hot-path storage (multiply/contract surface
/// only).
pub type Matrix32 = Mat<f32>;

impl<T: Scalar> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat<{}> {}x{} [", T::NAME, self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  [")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl<T: Scalar> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::ZERO; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of rows `[r0, r1)` as a standalone matrix. Rows are contiguous
    /// in the row-major layout, so this is one memcpy — the chunked
    /// attention engine uses it to slice sequences into blocks.
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat<T> {
        assert!(r0 <= r1 && r1 <= self.rows, "row_block out of range");
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Column sums `out[j] = Σ_r self[r, j]` — the `Φ(K)ᵀ·1` normalizer
    /// summary, streamed over contiguous rows and accumulated in
    /// [`Scalar::Accum`]: a monotone sum of positives whose storage-width
    /// roundoff would grow linearly with the row count.
    pub fn col_sums(&self) -> Vec<T::Accum> {
        let mut out = vec![<T::Accum as Scalar>::ZERO; self.cols];
        for r in 0..self.rows {
            T::accum_row(&mut out, self.row(r));
        }
        out
    }

    /// `self · other`, tiled for cache reuse.
    ///
    /// Loop order is jb → kb → i → k → j: for each (column, inner) tile of
    /// `other`, every row of the output accumulates against a panel of
    /// `other` that stays resident in cache across the whole `i` sweep.
    /// Per output element the `k` accumulation still runs in ascending
    /// order, so results are bitwise-identical to the naive ikj kernel.
    pub fn matmul(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // Tile sizes shared across precisions: a KT×JT panel of `other`
        // is 128 KiB in f64 (L2-resident on anything this runs on) and
        // 64 KiB in f32, while the JT-wide output row chunk stays in L1
        // across the k loop either way.
        const KT: usize = 64;
        const JT: usize = 256;
        let mut jb = 0;
        while jb < n {
            let je = (jb + JT).min(n);
            let mut kb = 0;
            while kb < kk {
                let ke = (kb + KT).min(kk);
                for i in 0..m {
                    let arow = &self.data[i * kk..(i + 1) * kk];
                    let orow = &mut out.data[i * n + jb..i * n + je];
                    // Register-blocked: four k-panels per pass over the
                    // output row chunk. Per element the k accumulation
                    // still runs in ascending order (bitwise vs the
                    // unblocked loop); KT is a multiple of 4, so the
                    // remainder loop only fires in the last k tile.
                    let mut k = kb;
                    while k + 4 <= ke {
                        T::axpy4(
                            orow,
                            [arow[k], arow[k + 1], arow[k + 2], arow[k + 3]],
                            [
                                &other.data[k * n + jb..k * n + je],
                                &other.data[(k + 1) * n + jb..(k + 1) * n + je],
                                &other.data[(k + 2) * n + jb..(k + 2) * n + je],
                                &other.data[(k + 3) * n + jb..(k + 3) * n + je],
                            ],
                        );
                        k += 4;
                    }
                    while k < ke {
                        let brow = &other.data[k * n + jb..k * n + je];
                        T::axpy(orow, arow[k], brow);
                        k += 1;
                    }
                }
                kb = ke;
            }
            jb = je;
        }
        out
    }

    /// `self · otherᵀ` without materializing the transpose.
    ///
    /// `other` is `n×k` with `self` `m×k`; the result is `m×n`. Both
    /// operands are walked along contiguous rows through the unrolled
    /// [`Scalar::dot`] kernel, so this is the preferred kernel for
    /// feature-map contractions `Φ(Q)·Φ(K)ᵀ` and projection products
    /// `X·Ωᵀ` where the transposed operand is naturally stored row-major.
    pub fn matmul_transb(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            // Four output columns per pass share the `arow` loads through
            // the dot4 microkernel; each output is still the plain
            // `Scalar::dot` fold, so blocking is bitwise-free.
            let mut j = 0;
            while j + 4 <= n {
                let d = T::dot4(
                    arow,
                    [
                        other.row(j),
                        other.row(j + 1),
                        other.row(j + 2),
                        other.row(j + 3),
                    ],
                );
                orow[j..j + 4].copy_from_slice(&d);
                j += 4;
            }
            while j < n {
                orow[j] = T::dot(arow, other.row(j));
                j += 1;
            }
        }
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    ///
    /// `self` is `k×m` and `other` `k×n`; the result is `m×n`, accumulated
    /// as `k` rank-1 updates `out += a_rᵀ ⊗ b_r`. Every operand row and
    /// every output row is walked contiguously, which is exactly the
    /// access pattern of the summary contractions `Φ(K)ᵀ·V` where both
    /// factors are naturally stored row-major with `k = L` long.
    pub fn matmul_transa(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.rows, other.rows, "matmul_transa shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // Four rank-1 updates per pass over the output: per element the r
        // accumulation still applies in ascending order (bitwise vs the
        // one-row-at-a-time loop), but `out` is loaded/stored once per
        // block of four instead of once per row.
        let mut r = 0;
        while r + 4 <= k {
            let arows = [self.row(r), self.row(r + 1), self.row(r + 2), self.row(r + 3)];
            let brows = [
                other.row(r),
                other.row(r + 1),
                other.row(r + 2),
                other.row(r + 3),
            ];
            for i in 0..m {
                let orow = &mut out.data[i * n..(i + 1) * n];
                let a = [arows[0][i], arows[1][i], arows[2][i], arows[3][i]];
                T::axpy4(orow, a, brows);
            }
            r += 4;
        }
        while r < k {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &a) in arow.iter().enumerate() {
                let orow = &mut out.data[i * n..(i + 1) * n];
                T::axpy(orow, a, brow);
            }
            r += 1;
        }
        out
    }

    /// `self · x` with each row reduced sequentially in
    /// [`Scalar::Accum`] — the denominator kernel `Φ(Q)·z` of the causal
    /// readout, where numerator/denominator share correlated error and
    /// the division must happen in the accumulator domain.
    pub fn matvec_accum(&self, x: &[T]) -> Vec<T::Accum> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| T::dot_seq_accum(self.row(r), x)).collect()
    }

    /// Transpose as a standalone matrix.
    ///
    /// Cache-blocked in 32×32 tiles: within a tile both the row-major
    /// reads and the column-strided writes touch at most 32 distinct
    /// cache lines, so one of the two streams always stays resident
    /// instead of thrashing on every element the way the naive
    /// column-strided double loop does. A pure permutation — output is
    /// bitwise-identical regardless of blocking. Sits on snapshot/serve
    /// paths (and under the `matmul(&b.transpose())` test references).
    pub fn transpose(&self) -> Mat<T> {
        let mut t = Mat::zeros(self.cols, self.rows);
        const B: usize = 32;
        let mut rb = 0;
        while rb < self.rows {
            let re = (rb + B).min(self.rows);
            let mut cb = 0;
            while cb < self.cols {
                let ce = (cb + B).min(self.cols);
                for r in rb..re {
                    for c in cb..ce {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
                cb = ce;
            }
            rb = re;
        }
        t
    }

    pub fn scale(&self, s: T) -> Mat<T> {
        let data = self.data.iter().map(|&a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Maximum absolute entrywise difference (in f64 so the comparison
    /// itself never rounds).
    pub fn max_abs_diff(&self, other: &Mat<T>) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }
}

// ---------------------------------------------------------------------
// f32 compatibility surface (the old `Matrix32` names)
// ---------------------------------------------------------------------

impl Mat<f32> {
    /// Downcast an f64 matrix (round-to-nearest per entry).
    pub fn from_f64(m: &Matrix) -> Self {
        Self {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Upcast to f64 (exact: every f32 is representable).
    pub fn to_f64(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&x| x as f64).collect(),
        )
    }

    /// Column sums accumulated in f64 — alias of the generic
    /// [`Mat::col_sums`] under the name the f32 stack has always used.
    pub fn col_sums_f64(&self) -> Vec<f64> {
        self.col_sums()
    }
}

// ---------------------------------------------------------------------
// f64-only surface: constructors and decompositions
// ---------------------------------------------------------------------

impl Matrix {
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        let data = rows.iter().flatten().copied().collect();
        Self { rows: r, cols: c, data }
    }

    pub fn diag(values: &[f64]) -> Self {
        let n = values.len();
        let mut m = Self::zeros(n, n);
        for (i, &v) in values.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_accum(x)
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Cholesky factorization `A = L L^T` for symmetric positive definite
    /// `A`. Returns lower-triangular `L`, or `None` if not SPD.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "cholesky needs a square matrix");
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    /// Solve `A x = b` for SPD `A` via Cholesky.
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        let n = self.rows;
        // Forward substitution: L y = b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= l[(i, k)] * y[k];
            }
            y[i] = sum / l[(i, i)];
        }
        // Back substitution: L^T x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l[(k, i)] * x[k];
            }
            x[i] = sum / l[(i, i)];
        }
        Some(x)
    }

    /// Inverse of an SPD matrix via Cholesky column solves.
    pub fn inverse_spd(&self) -> Option<Matrix> {
        let n = self.rows;
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for c in 0..n {
            e[c] = 1.0;
            let x = self.solve_spd(&e)?;
            e[c] = 0.0;
            for r in 0..n {
                inv[(r, c)] = x[r];
            }
        }
        Some(inv)
    }

    /// General inverse via Gauss–Jordan with partial pivoting. Returns
    /// `None` for (numerically) singular matrices.
    pub fn inverse(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Pivot.
            let pivot = (col..n)
                .max_by(|&i, &j| {
                    a[(i, col)].abs().partial_cmp(&a[(j, col)].abs()).unwrap()
                })
                .unwrap();
            if a[(pivot, col)].abs() < 1e-14 {
                return None;
            }
            if pivot != col {
                for k in 0..n {
                    a.data.swap(pivot * n + k, col * n + k);
                    inv.data.swap(pivot * n + k, col * n + k);
                }
            }
            let d = a[(col, col)];
            for k in 0..n {
                a[(col, k)] /= d;
                inv[(col, k)] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for k in 0..n {
                    a[(r, k)] -= f * a[(col, k)];
                    inv[(r, k)] -= f * inv[(col, k)];
                }
            }
        }
        Some(inv)
    }

    /// Symmetric eigendecomposition by the cyclic Jacobi method.
    ///
    /// Returns `(eigenvalues, eigenvectors)` with eigenvectors as columns,
    /// sorted by descending eigenvalue. Suitable for the small (d <= 256)
    /// covariance matrices the RFA analysis works with.
    pub fn jacobi_eigen(&self) -> (Vec<f64>, Matrix) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut v = Matrix::identity(n);
        for _sweep in 0..100 {
            let mut off = 0.0;
            for r in 0..n {
                for c in r + 1..n {
                    off += a[(r, c)] * a[(r, c)];
                }
            }
            if off.sqrt() < super::TOL * (1.0 + a.frobenius_norm()) {
                break;
            }
            for p in 0..n {
                for q in p + 1..n {
                    let apq = a[(p, q)];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                    let t = theta.signum()
                        / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // Rotate rows/cols p and q of A.
                    for k in 0..n {
                        let akp = a[(k, p)];
                        let akq = a[(k, q)];
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[(p, k)];
                        let aqk = a[(q, k)];
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                    }
                    for k in 0..n {
                        let vkp = v[(k, p)];
                        let vkq = v[(k, q)];
                        v[(k, p)] = c * vkp - s * vkq;
                        v[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
        let mut pairs: Vec<(f64, usize)> =
            (0..n).map(|i| (a[(i, i)], i)).collect();
        pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
        let eigvals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let mut eigvecs = Matrix::zeros(n, n);
        for (new_c, &(_, old_c)) in pairs.iter().enumerate() {
            for r in 0..n {
                eigvecs[(r, new_c)] = v[(r, old_c)];
            }
        }
        (eigvals, eigvecs)
    }
}

impl<T: Scalar> Index<(usize, usize)> for Mat<T> {
    type Output = T;

    fn index(&self, (r, c): (usize, usize)) -> &T {
        &self.data[r * self.cols + c]
    }
}

impl<T: Scalar> IndexMut<(usize, usize)> for Mat<T> {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} !~ {b}");
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    /// Reference ijk matmul to pin the tiled kernel against.
    fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        use crate::rng::{GaussianExt, Pcg64};
        let mut rng = Pcg64::seed(seed);
        Matrix::from_vec(rows, cols, rng.gaussian_vec(rows * cols))
    }

    fn random32(rows: usize, cols: usize, seed: u64) -> Matrix32 {
        Matrix32::from_f64(&random_matrix(rows, cols, seed))
    }

    #[test]
    fn tiled_matmul_matches_naive_across_tile_boundaries() {
        // Sizes straddling the KT=64 / JT=256 tile edges, plus odd shapes.
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 64, 63),
            (8, 65, 257),
            (70, 130, 300),
        ] {
            let a = random_matrix(m, k, 1000 + m as u64);
            let b = random_matrix(k, n, 2000 + n as u64);
            let tiled = a.matmul(&b);
            let naive = matmul_naive(&a, &b);
            assert!(
                tiled.max_abs_diff(&naive) < 1e-10,
                "({m},{k},{n}): diff={}",
                tiled.max_abs_diff(&naive)
            );
        }
    }

    #[test]
    fn matmul_transb_matches_explicit_transpose() {
        for &(m, k, n) in &[(1, 1, 1), (4, 3, 5), (9, 66, 31), (33, 128, 12)] {
            let a = random_matrix(m, k, 31 + k as u64);
            let b = random_matrix(n, k, 77 + m as u64);
            let fast = a.matmul_transb(&b);
            let reference = a.matmul(&b.transpose());
            assert!(
                fast.max_abs_diff(&reference) < 1e-10,
                "({m},{k},{n}): diff={}",
                fast.max_abs_diff(&reference)
            );
        }
    }

    #[test]
    fn matmul_handles_zero_entries_densely() {
        // The old kernel special-cased a == 0.0; the tiled kernel must be
        // exact for sparse-ish inputs too.
        let mut a = Matrix::zeros(5, 6);
        a[(0, 0)] = 2.0;
        a[(4, 5)] = -3.0;
        let b = random_matrix(6, 4, 9);
        assert!(a.matmul(&b).max_abs_diff(&matmul_naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn matmul_transa_matches_explicit_transpose() {
        for &(k, m, n) in &[(1, 1, 1), (5, 3, 4), (66, 9, 31), (128, 33, 12)] {
            let a = random_matrix(k, m, 101 + k as u64);
            let b = random_matrix(k, n, 202 + n as u64);
            let fast = a.matmul_transa(&b);
            let reference = a.transpose().matmul(&b);
            assert!(
                fast.max_abs_diff(&reference) < 1e-10,
                "({k},{m},{n}): diff={}",
                fast.max_abs_diff(&reference)
            );
        }
    }

    /// All three f32 contraction kernels vs the f64 instantiation of the
    /// same generic code on the exact same (f32-representable) entries:
    /// agreement to f32 accumulation noise across tile/unroll boundaries.
    #[test]
    fn f32_kernels_match_f64_reference() {
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 64, 63),
            (8, 65, 257),
            (33, 130, 12),
        ] {
            let a = random32(m, k, 11 + m as u64);
            let b = random32(k, n, 22 + n as u64);
            let bt = random32(n, k, 33 + n as u64);
            let a64 = a.to_f64();

            let mm = a.matmul(&b).to_f64();
            let mm_ref = a64.matmul(&b.to_f64());
            assert!(mm.max_abs_diff(&mm_ref) < 1e-4 * k as f64);

            let tb = a.matmul_transb(&bt).to_f64();
            let tb_ref = a64.matmul_transb(&bt.to_f64());
            assert!(tb.max_abs_diff(&tb_ref) < 1e-4 * k as f64);

            let bt2 = random32(m, n, 44 + n as u64);
            let ta = a.matmul_transa(&bt2).to_f64();
            let ta_ref = a64.matmul_transa(&bt2.to_f64());
            assert!(ta.max_abs_diff(&ta_ref) < 1e-4 * m as f64);
        }
    }

    #[test]
    fn col_sums_accumulate_in_f64() {
        // 2^24 + 1 is not representable in f32; the Accum=f64 policy over
        // f32 entries must still resolve the +1.
        let l = 1 << 12;
        let mut data = vec![4096.0f32; l];
        data[0] = 4097.0;
        let m = Matrix32::from_vec(l, 1, data);
        let s = m.col_sums_f64();
        assert_eq!(s[0], 4096.0 * (l as f64) + 1.0);
    }

    #[test]
    fn round_trip_and_row_block_f32() {
        let m = random32(7, 5, 99);
        assert_eq!(Matrix32::from_f64(&m.to_f64()), m);
        let block = m.row_block(2, 5);
        assert_eq!(block.rows(), 3);
        assert_eq!(block.row(0), m.row(2));
        assert_eq!(block.row(2), m.row(4));
    }

    #[test]
    fn matvec_accum_is_the_matvec_kernel() {
        // matvec (f64 compat name) and the generic Accum kernel agree,
        // and the f32 instantiation widens products before summing.
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[5.0, 6.0]), vec![17.0, 39.0]);
        let a32 = Matrix32::from_f64(&a);
        assert_eq!(a32.matvec_accum(&[5.0f32, 6.0]), vec![17.0f64, 39.0]);
    }

    #[test]
    fn row_block_and_col_sums() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ]);
        let block = a.row_block(1, 3);
        assert_eq!((block.rows(), block.cols()), (2, 2));
        assert_eq!(block.data(), &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.row_block(1, 1).rows(), 0);
        assert_eq!(a.col_sums(), vec![9.0, 12.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::from_rows(&[vec![1.0, -2.0], vec![0.5, 3.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = Matrix::from_rows(&[
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.0],
            vec![0.6, 1.0, 3.0],
        ]);
        let l = a.cholesky().unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(a.cholesky().is_none());
    }

    #[test]
    fn solve_spd_matches_direct() {
        let a = Matrix::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = a.solve_spd(&[1.0, 2.0]).unwrap();
        // 4x + y = 1 ; x + 3y = 2  =>  x = 1/11, y = 7/11
        assert_close(x[0], 1.0 / 11.0, 1e-12);
        assert_close(x[1], 7.0 / 11.0, 1e-12);
    }

    #[test]
    fn inverse_spd_and_general_agree() {
        let a = Matrix::from_rows(&[
            vec![2.0, 0.5, 0.1],
            vec![0.5, 1.5, 0.2],
            vec![0.1, 0.2, 1.0],
        ]);
        let i1 = a.inverse_spd().unwrap();
        let i2 = a.inverse().unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-10);
        assert!(a.matmul(&i1).max_abs_diff(&Matrix::identity(3)) < 1e-10);
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(a.inverse().is_none());
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let (vals, _) = a.jacobi_eigen();
        assert_close(vals[0], 3.0, 1e-12);
        assert_close(vals[1], 2.0, 1e-12);
        assert_close(vals[2], 1.0, 1e-12);
    }

    #[test]
    fn jacobi_reconstructs_symmetric() {
        let a = Matrix::from_rows(&[
            vec![2.0, 1.0, 0.3],
            vec![1.0, 3.0, -0.5],
            vec![0.3, -0.5, 1.5],
        ]);
        let (vals, vecs) = a.jacobi_eigen();
        let rec = vecs.matmul(&Matrix::diag(&vals)).matmul(&vecs.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9, "diff={}", a.max_abs_diff(&rec));
        // Eigenvectors orthonormal.
        let g = vecs.transpose().matmul(&vecs);
        assert!(g.max_abs_diff(&Matrix::identity(3)) < 1e-9);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, _) = a.jacobi_eigen();
        assert_close(vals[0], 3.0, 1e-12);
        assert_close(vals[1], 1.0, 1e-12);
    }
}
