//! Row-major dense matrix with the decompositions the RFA analysis needs.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense `rows x cols` matrix of `f64`, row-major.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  [")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        let data = rows.iter().flatten().copied().collect();
        Self { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn diag(values: &[f64]) -> Self {
        let n = values.len();
        let mut m = Self::zeros(n, n);
        for (i, &v) in values.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: streams over `other`'s rows, cache-friendly for
        // row-major layout.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Maximum absolute entrywise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Cholesky factorization `A = L L^T` for symmetric positive definite
    /// `A`. Returns lower-triangular `L`, or `None` if not SPD.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "cholesky needs a square matrix");
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    /// Solve `A x = b` for SPD `A` via Cholesky.
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        let n = self.rows;
        // Forward substitution: L y = b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= l[(i, k)] * y[k];
            }
            y[i] = sum / l[(i, i)];
        }
        // Back substitution: L^T x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l[(k, i)] * x[k];
            }
            x[i] = sum / l[(i, i)];
        }
        Some(x)
    }

    /// Inverse of an SPD matrix via Cholesky column solves.
    pub fn inverse_spd(&self) -> Option<Matrix> {
        let n = self.rows;
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for c in 0..n {
            e[c] = 1.0;
            let x = self.solve_spd(&e)?;
            e[c] = 0.0;
            for r in 0..n {
                inv[(r, c)] = x[r];
            }
        }
        Some(inv)
    }

    /// General inverse via Gauss–Jordan with partial pivoting. Returns
    /// `None` for (numerically) singular matrices.
    pub fn inverse(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Pivot.
            let pivot = (col..n)
                .max_by(|&i, &j| {
                    a[(i, col)].abs().partial_cmp(&a[(j, col)].abs()).unwrap()
                })
                .unwrap();
            if a[(pivot, col)].abs() < 1e-14 {
                return None;
            }
            if pivot != col {
                for k in 0..n {
                    a.data.swap(pivot * n + k, col * n + k);
                    inv.data.swap(pivot * n + k, col * n + k);
                }
            }
            let d = a[(col, col)];
            for k in 0..n {
                a[(col, k)] /= d;
                inv[(col, k)] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for k in 0..n {
                    a[(r, k)] -= f * a[(col, k)];
                    inv[(r, k)] -= f * inv[(col, k)];
                }
            }
        }
        Some(inv)
    }

    /// Symmetric eigendecomposition by the cyclic Jacobi method.
    ///
    /// Returns `(eigenvalues, eigenvectors)` with eigenvectors as columns,
    /// sorted by descending eigenvalue. Suitable for the small (d <= 256)
    /// covariance matrices the RFA analysis works with.
    pub fn jacobi_eigen(&self) -> (Vec<f64>, Matrix) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut v = Matrix::identity(n);
        for _sweep in 0..100 {
            let mut off = 0.0;
            for r in 0..n {
                for c in r + 1..n {
                    off += a[(r, c)] * a[(r, c)];
                }
            }
            if off.sqrt() < super::TOL * (1.0 + a.frobenius_norm()) {
                break;
            }
            for p in 0..n {
                for q in p + 1..n {
                    let apq = a[(p, q)];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                    let t = theta.signum()
                        / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // Rotate rows/cols p and q of A.
                    for k in 0..n {
                        let akp = a[(k, p)];
                        let akq = a[(k, q)];
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[(p, k)];
                        let aqk = a[(q, k)];
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                    }
                    for k in 0..n {
                        let vkp = v[(k, p)];
                        let vkq = v[(k, q)];
                        v[(k, p)] = c * vkp - s * vkq;
                        v[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
        let mut pairs: Vec<(f64, usize)> =
            (0..n).map(|i| (a[(i, i)], i)).collect();
        pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
        let eigvals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let mut eigvecs = Matrix::zeros(n, n);
        for (new_c, &(_, old_c)) in pairs.iter().enumerate() {
            for r in 0..n {
                eigvecs[(r, new_c)] = v[(r, old_c)];
            }
        }
        (eigvals, eigvecs)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} !~ {b}");
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::from_rows(&[vec![1.0, -2.0], vec![0.5, 3.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = Matrix::from_rows(&[
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.0],
            vec![0.6, 1.0, 3.0],
        ]);
        let l = a.cholesky().unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(a.cholesky().is_none());
    }

    #[test]
    fn solve_spd_matches_direct() {
        let a = Matrix::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = a.solve_spd(&[1.0, 2.0]).unwrap();
        // 4x + y = 1 ; x + 3y = 2  =>  x = 1/11, y = 7/11
        assert_close(x[0], 1.0 / 11.0, 1e-12);
        assert_close(x[1], 7.0 / 11.0, 1e-12);
    }

    #[test]
    fn inverse_spd_and_general_agree() {
        let a = Matrix::from_rows(&[
            vec![2.0, 0.5, 0.1],
            vec![0.5, 1.5, 0.2],
            vec![0.1, 0.2, 1.0],
        ]);
        let i1 = a.inverse_spd().unwrap();
        let i2 = a.inverse().unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-10);
        assert!(a.matmul(&i1).max_abs_diff(&Matrix::identity(3)) < 1e-10);
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(a.inverse().is_none());
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let (vals, _) = a.jacobi_eigen();
        assert_close(vals[0], 3.0, 1e-12);
        assert_close(vals[1], 2.0, 1e-12);
        assert_close(vals[2], 1.0, 1e-12);
    }

    #[test]
    fn jacobi_reconstructs_symmetric() {
        let a = Matrix::from_rows(&[
            vec![2.0, 1.0, 0.3],
            vec![1.0, 3.0, -0.5],
            vec![0.3, -0.5, 1.5],
        ]);
        let (vals, vecs) = a.jacobi_eigen();
        let rec = vecs.matmul(&Matrix::diag(&vals)).matmul(&vecs.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9, "diff={}", a.max_abs_diff(&rec));
        // Eigenvectors orthonormal.
        let g = vecs.transpose().matmul(&vecs);
        assert!(g.max_abs_diff(&Matrix::identity(3)) < 1e-9);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, _) = a.jacobi_eigen();
        assert_close(vals[0], 3.0, 1e-12);
        assert_close(vals[1], 1.0, 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let y = a.matvec(&[5.0, 6.0]);
        assert_eq!(y, vec![17.0, 39.0]);
    }
}
