//! `f32` dense matrix: the SIMD hot-path storage for the attention engine.
//!
//! Same row-major layout and the same tiled kernel structure as the `f64`
//! [`Matrix`](super::Matrix), but with half the memory traffic and twice
//! the SIMD lanes per vector register. All kernels are written as flat
//! contiguous-slice loops (`iter_mut().zip(..)` over row chunks) so LLVM
//! autovectorizes them; the 8-wide unrolled dot keeps eight independent
//! accumulators in flight to hide FMA latency.
//!
//! This type deliberately carries *only* the multiply/contract surface the
//! attention hot path needs. Decompositions (Cholesky, eigen, inverses)
//! stay f64-only in [`Matrix`](super::Matrix) — they are setup-time
//! operations where precision matters and throughput does not.

use std::fmt;
use std::ops::{Index, IndexMut};

use super::Matrix;

/// Dense `rows x cols` matrix of `f32`, row-major.
#[derive(Clone, PartialEq)]
pub struct Matrix32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Matrix32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix32 {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  [")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Downcast an f64 matrix (round-to-nearest per entry).
    pub fn from_f64(m: &Matrix) -> Self {
        Self {
            rows: m.rows(),
            cols: m.cols(),
            data: m.data().iter().map(|&x| x as f32).collect(),
        }
    }

    /// Upcast to f64 (exact: every f32 is representable).
    pub fn to_f64(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&x| x as f64).collect(),
        )
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of rows `[r0, r1)` — one memcpy in the row-major layout.
    pub fn row_block(&self, r0: usize, r1: usize) -> Matrix32 {
        assert!(r0 <= r1 && r1 <= self.rows, "row_block out of range");
        Matrix32 {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// `self · other`, tiled exactly like [`Matrix::matmul`]: jb → kb → i
    /// → k → j with the `other` panel cache-resident and the inner j loop
    /// a contiguous axpy that autovectorizes to full-width f32 lanes.
    pub fn matmul(&self, other: &Matrix32) -> Matrix32 {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix32::zeros(m, n);
        // f32 halves the panel footprint vs the f64 kernel; keep the same
        // element counts so the tuning carries over (panel = 64 KiB).
        const KT: usize = 64;
        const JT: usize = 256;
        let mut jb = 0;
        while jb < n {
            let je = (jb + JT).min(n);
            let mut kb = 0;
            while kb < kk {
                let ke = (kb + KT).min(kk);
                for i in 0..m {
                    let arow = &self.data[i * kk..(i + 1) * kk];
                    let orow = &mut out.data[i * n + jb..i * n + je];
                    for k in kb..ke {
                        let a = arow[k];
                        let brow = &other.data[k * n + jb..k * n + je];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
                kb = ke;
            }
            jb = je;
        }
        out
    }

    /// `self · otherᵀ` without materializing the transpose; both operands
    /// stream along contiguous rows (the `Φ(Q)·Φ(K)ᵀ` gram kernel).
    pub fn matmul_transb(&self, other: &Matrix32) -> Matrix32 {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Matrix32::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (o, j) in orow.iter_mut().zip(0..n) {
                *o = dot32(arow, other.row(j));
            }
        }
        out
    }

    /// `selfᵀ · other` as `k` rank-1 updates (the `Φ(K)ᵀ·V` summary
    /// kernel); every row access is contiguous.
    pub fn matmul_transa(&self, other: &Matrix32) -> Matrix32 {
        assert_eq!(self.rows, other.rows, "matmul_transa shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix32::zeros(m, n);
        for r in 0..k {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &a) in arow.iter().enumerate() {
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Column sums `out[j] = Σ_r self[r, j]`, accumulated in f64: this is
    /// the `Φ(K)ᵀ·1` denominator summary, a monotone sum of positives
    /// whose f32 roundoff would grow linearly with the row count.
    pub fn col_sums_f64(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x as f64;
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Matrix32 {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix32 { rows: self.rows, cols: self.cols, data }
    }

    /// Maximum absolute entrywise difference (in f64 to avoid the
    /// comparison itself rounding).
    pub fn max_abs_diff(&self, other: &Matrix32) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .fold(0.0, f64::max)
    }
}

/// f32 dot with eight independent accumulators: at 8 f32 lanes per
/// 256-bit register this keeps a full vector of FMAs in flight per
/// accumulator. Summation order differs from a sequential fold (fine for
/// fresh gram entries, same contract as the f64 `dot_unrolled`).
pub fn dot32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for (a, (&x, &y)) in acc.iter_mut().zip(xa.iter().zip(xb)) {
            *a += x * y;
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
        + tail
}

impl Index<(usize, usize)> for Matrix32 {
    type Output = f32;

    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix32 {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random32(rows: usize, cols: usize, seed: u64) -> Matrix32 {
        use crate::rng::{GaussianExt, Pcg64};
        let mut rng = Pcg64::seed(seed);
        Matrix32::from_vec(
            rows,
            cols,
            rng.gaussian_vec(rows * cols).iter().map(|&x| x as f32).collect(),
        )
    }

    /// All three contraction kernels vs the f64 reference on the exact
    /// same (f32-representable) entries: agreement to f32 accumulation
    /// noise across tile/unroll boundaries.
    #[test]
    fn kernels_match_f64_reference() {
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 64, 63),
            (8, 65, 257),
            (33, 130, 12),
        ] {
            let a = random32(m, k, 11 + m as u64);
            let b = random32(k, n, 22 + n as u64);
            let bt = random32(n, k, 33 + n as u64);
            let a64 = a.to_f64();

            let mm = a.matmul(&b).to_f64();
            let mm_ref = a64.matmul(&b.to_f64());
            assert!(mm.max_abs_diff(&mm_ref) < 1e-4 * k as f64);

            let tb = a.matmul_transb(&bt).to_f64();
            let tb_ref = a64.matmul_transb(&bt.to_f64());
            assert!(tb.max_abs_diff(&tb_ref) < 1e-4 * k as f64);

            let bt2 = random32(m, n, 44 + n as u64);
            let ta = a.matmul_transa(&bt2).to_f64();
            let ta_ref = a64.matmul_transa(&bt2.to_f64());
            assert!(ta.max_abs_diff(&ta_ref) < 1e-4 * m as f64);
        }
    }

    #[test]
    fn col_sums_accumulate_in_f64() {
        // 2^24 + 1 is not representable in f32; an f64 accumulator over
        // f32 entries must still resolve the +1.
        let l = 1 << 12;
        let mut data = vec![4096.0f32; l];
        data[0] = 4097.0;
        let m = Matrix32::from_vec(l, 1, data);
        let s = m.col_sums_f64();
        assert_eq!(s[0], 4096.0 * (l as f64) + 1.0);
    }

    #[test]
    fn round_trip_and_row_block() {
        let m = random32(7, 5, 99);
        assert_eq!(Matrix32::from_f64(&m.to_f64()), m);
        let block = m.row_block(2, 5);
        assert_eq!(block.rows(), 3);
        assert_eq!(block.row(0), m.row(2));
        assert_eq!(block.row(2), m.row(4));
    }

    #[test]
    fn dot32_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32) * 0.25 - 4.0).collect();
        let b: Vec<f32> = (0..37).map(|i| 1.5 - (i as f32) * 0.125).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot32(&a, &b) - naive).abs() < 1e-3);
    }
}
