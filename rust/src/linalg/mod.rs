//! Dense linear-algebra substrate (row-major).
//!
//! Powers the pure-Rust random-feature analysis in [`crate::rfa`]: building
//! anisotropic covariances, Cholesky-sampling Gaussians, and evaluating the
//! closed-form optimal proposal of Theorem 3.2, which needs
//! `(I + 2L)(I - 2L)^{-1}` and eigen-decompositions. Deliberately small —
//! just what the reproduction needs, tested against hand-computable cases.
//!
//! Storage precisions live behind the sealed [`Scalar`] backend trait:
//! one generic [`Mat<T>`] carries the SIMD-tiled multiply/contract
//! kernels for every precision, with [`Matrix`] (= `Mat<f64>`) the
//! default that additionally carries every decomposition, and
//! [`Matrix32`] (= `Mat<f32>`) the attention engine's hot path — half
//! the memory traffic, twice the lanes per register. Long reductions
//! always accumulate in [`Scalar::Accum`] (f64); see `scalar.rs` for the
//! policy contract.

mod mat;
mod scalar;

pub use mat::{Mat, Matrix, Matrix32};
pub use scalar::{dot32, dot_unrolled as dot, Scalar};

/// Machine tolerance used by the iterative routines.
pub const TOL: f64 = 1e-12;
