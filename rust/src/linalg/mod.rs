//! Dense linear-algebra substrate (f64, row-major).
//!
//! Powers the pure-Rust random-feature analysis in [`crate::rfa`]: building
//! anisotropic covariances, Cholesky-sampling Gaussians, and evaluating the
//! closed-form optimal proposal of Theorem 3.2, which needs
//! `(I + 2L)(I - 2L)^{-1}` and eigen-decompositions. Deliberately small —
//! just what the reproduction needs, tested against hand-computable cases.

mod matrix;

pub use matrix::Matrix;

/// Machine tolerance used by the iterative routines.
pub const TOL: f64 = 1e-12;
