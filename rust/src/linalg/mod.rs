//! Dense linear-algebra substrate (row-major).
//!
//! Powers the pure-Rust random-feature analysis in [`crate::rfa`]: building
//! anisotropic covariances, Cholesky-sampling Gaussians, and evaluating the
//! closed-form optimal proposal of Theorem 3.2, which needs
//! `(I + 2L)(I - 2L)^{-1}` and eigen-decompositions. Deliberately small —
//! just what the reproduction needs, tested against hand-computable cases.
//!
//! Two storage precisions share the kernel structure: [`Matrix`] (f64) is
//! the default and carries every decomposition; [`Matrix32`] (f32) carries
//! only the multiply/contract surface and is the attention engine's SIMD
//! hot path — half the memory traffic, twice the lanes per register.

mod matrix;
mod matrix32;

pub use matrix::{dot_unrolled as dot, Matrix};
pub use matrix32::{dot32, Matrix32};

/// Machine tolerance used by the iterative routines.
pub const TOL: f64 = 1e-12;
