//! Dense linear-algebra substrate (row-major) with runtime-dispatched
//! explicit-SIMD microkernels.
//!
//! Powers the pure-Rust random-feature analysis in [`crate::rfa`]: building
//! anisotropic covariances, Cholesky-sampling Gaussians, and evaluating the
//! closed-form optimal proposal of Theorem 3.2, which needs
//! `(I + 2L)(I - 2L)^{-1}` and eigen-decompositions. Deliberately small —
//! just what the reproduction needs, tested against hand-computable cases.
//!
//! # Layering: who decides what
//!
//! The stack separates three concerns, one module each:
//!
//! * **`mat` — tiling and traversal order.** `Mat<T>` owns shapes, cache
//!   tiles (`matmul`'s KT×JT panels, the blocked `transpose`), and which
//!   microkernel each contraction feeds (`axpy4` row updates, `dot4`
//!   column blocks, rank-1 `axpy` sweeps). It never sees an intrinsic.
//! * **`scalar` — precision and policy.** The sealed [`Scalar`] trait
//!   binds one storage precision to its kernel hooks (`dot`, `dot4`,
//!   `axpy`, `axpy4`, `accum_row`, `dot_seq_accum`, `feature_finish`) and
//!   to the accumulation policy [`Scalar::Accum`] (= `f64` for every
//!   impl): sequence-length sums — running `S`/`z`, denominators, the
//!   feature-map exponent — always accumulate in f64. Because the hooks
//!   hang off the sealed trait, `Mat<T>` call sites are identical for
//!   every precision and adding a precision (bf16/f16 emulation: double
//!   the lanes, half the session-resident bytes) stays a one-impl job.
//! * **[`simd`] — instruction selection.** Each hook dispatches on a
//!   process-wide ISA decided *once* (AVX2/AVX-512 via
//!   `is_x86_feature_detected!`, NEON as the aarch64 baseline, portable
//!   scalar fallback everywhere else) and cached in an atomic. The
//!   `RFA_SIMD=scalar` env override forces the fallback for A/B timing;
//!   [`simd::set_isa`] switches in-process (benches, dual-mode tests);
//!   [`simd::active_isa`] names the effective ISA for `BENCH_*.json`.
//!
//! # The bitwise contract
//!
//! Every ISA's kernels are **bitwise-identical** to the portable
//! reference in [`simd::fallback`] — the dispatch decision is invisible
//! in results, only in throughput. That is what lets `rfa_generic.rs`
//! pin end-to-end forwards with `assert_eq!` under *both* dispatch
//! modes, and what makes serve-layer determinism (snapshots, epoch
//! resume) independent of the machine's vector width. How each kernel
//! family earns the property (frozen accumulator layouts, no FMA,
//! scalar-order reductions, scalar libm `exp`, in-order sequential
//! folds) is documented in [`simd::fallback`]; the procedure for adding
//! a new ISA without breaking it is in [`simd`]'s module docs.
//!
//! [`Matrix`] (= `Mat<f64>`) is the default precision and additionally
//! carries every decomposition (the incremental rank-1/rank-k Cholesky
//! up/downdates live in the `chol` module, same `impl Matrix` surface);
//! [`Matrix32`] (= `Mat<f32>`) is the
//! attention engine's hot path — half the memory traffic, twice the
//! lanes per register.

mod chol;
mod mat;
mod scalar;
pub mod simd;

pub use mat::{Mat, Matrix, Matrix32};
pub use scalar::{dot32, dot_unrolled as dot, Scalar};

/// Machine tolerance used by the iterative routines.
pub const TOL: f64 = 1e-12;
