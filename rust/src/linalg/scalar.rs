//! The sealed [`Scalar`] backend trait: one element type per storage
//! precision, one shared set of kernels, one accumulation policy.
//!
//! Before this trait the crate carried two hand-maintained copies of the
//! whole attention stack (`Matrix`/`Matrix32`, `feature_matrix{,32}`,
//! `CausalState{,32}`, …). The estimator mathematics is precision-agnostic
//! — the FAVOR+ lineage changes *storage* width, never the algebra — and
//! the only real degree of freedom is where long accumulations happen.
//! [`Scalar`] encodes exactly that:
//!
//! * the element type and its conversions to/from `f64`;
//! * the precision-tuned unrolled [`Scalar::dot`] kernel (four f64
//!   accumulators, eight f32 lanes — see [`dot_unrolled`] / [`dot32`]);
//! * the **accumulation policy** as the associated type
//!   [`Scalar::Accum`]: every sum whose length grows with the sequence —
//!   the running `S`/`z` prefixes, per-row denominators, and the
//!   feature-map exponent — accumulates in `Accum`, which is **`f64` for
//!   every precision in the sealed set**. Storage width is a throughput
//!   choice; the accumulator width is a correctness contract
//!   (an f32 running sum over L positive terms would accumulate
//!   O(L·ε₃₂) relative error — ≈1% at L=10⁵).
//!
//! The trait is sealed: adding a precision (e.g. a bf16 emulation) means
//! adding one impl here — with `Accum = f64` — and the whole pipeline
//! (`Mat<T>` → `FeatureBank::feature_matrix_t` → `CausalState<T>` →
//! `rfa::serve`) exists for it immediately.

use std::borrow::Cow;
use std::fmt;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Sub, SubAssign};

use super::mat::Mat;
use super::simd;

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// Element type of a [`Mat`]: the storage precision of one attention
/// stack, with its kernels and its accumulation policy. Sealed — the set
/// of precisions is closed over the impls in this module.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + fmt::Debug
    + fmt::Display
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + 'static
{
    /// The accumulator element for sequence-length reductions: running
    /// `S = Σ φ(k_j)·v_jᵀ` / `z = Σ φ(k_j)` prefixes, per-row
    /// denominators, and the feature-map exponent. **`f64` for every
    /// impl** — this is the documented accumulation-policy contract, not
    /// a per-precision tuning knob (see the module docs and
    /// [`crate::rfa::engine`]).
    type Accum: Scalar;

    /// Human-readable precision name (`"f64"` / `"f32"`), used by
    /// [`Mat`]'s `Debug` header.
    const NAME: &'static str;
    const ZERO: Self;
    const ONE: Self;

    /// Round an `f64` value to this precision (identity for `f64`).
    fn from_f64(x: f64) -> Self;

    /// Widen to `f64` (exact: every storage precision embeds in f64).
    fn to_f64(self) -> f64;

    /// Widen into the accumulator domain (exact).
    fn to_accum(self) -> Self::Accum;

    /// Round an accumulated value back to storage precision — the single
    /// point where the policy's one-rounding-per-output happens.
    fn from_accum(a: Self::Accum) -> Self;

    /// `e^self`. The pipeline only exponentiates in the accumulator
    /// domain (the exponent is a cancellation-sensitive difference); this
    /// exists on the trait so `T::Accum` carries it.
    fn exp(self) -> Self;

    /// Unrolled dot kernel with precision-tuned accumulator count:
    /// [`dot_unrolled`] (4 independent f64 accumulators) for `f64`,
    /// [`dot32`] (8 f32 lanes) for `f32`. Summation order differs from a
    /// sequential fold — fine for fresh gram entries, the contract both
    /// kernels have always had. Routes through [`crate::linalg::simd`]:
    /// the explicit-SIMD implementations keep the exact accumulator
    /// layout, so dispatch never changes the result.
    fn dot(a: &[Self], b: &[Self]) -> Self;

    /// Four dots against a shared left operand — the `matmul_transb`
    /// 4-column microkernel. Each output is bitwise-equal to a separate
    /// [`Scalar::dot`] call; the SIMD paths share the left-operand loads.
    fn dot4(a: &[Self], b: [&[Self]; 4]) -> [Self; 4];

    /// `out[j] += a * x[j]` — the tiled `matmul` row update. Elementwise,
    /// so every ISA is bitwise-identical.
    fn axpy(out: &mut [Self], a: Self, x: &[Self]);

    /// Register-blocked 4-column row update: per output element the four
    /// `mul`+`add` pairs apply in ascending operand order, bitwise-equal
    /// to four consecutive [`Scalar::axpy`] calls but with one load/store
    /// pass over `out`.
    fn axpy4(out: &mut [Self], a: [Self; 4], x: [&[Self]; 4]);

    /// `out[j] += row[j]` widened into the accumulator domain — one row
    /// step of `Mat::col_sums`.
    fn accum_row(out: &mut [Self::Accum], row: &[Self]);

    /// Strictly sequential widening dot in the accumulator domain — the
    /// `Mat::matvec_accum` fold behind denominators and normalizers (one
    /// running sum in ascending index order, *not* the reassociated
    /// [`Scalar::dot`] fold). SIMD may vectorize only the widen+multiply
    /// stage.
    fn dot_seq_accum(a: &[Self], b: &[Self]) -> Self::Accum;

    /// Feature-map finish `row[j] = exp(row[j] - a) * sqrt_w[j]`: widen to
    /// the accumulator domain, subtract, scalar-libm `exp`, scale, round
    /// back to storage once per element (the exponent inner loop of
    /// `FeatureBank::feature_matrix_t`).
    fn feature_finish(row: &mut [Self], a: f64, sqrt_w: &[f64]);

    /// Borrow-or-round an f64 matrix into this precision: a borrow when
    /// `Self` *is* f64, one rounded copy otherwise. This is how f64-side
    /// inputs (values, drawn banks) enter a `T`-precision forward without
    /// taxing the f64 path with copies.
    fn mat_from_f64(m: &Mat<f64>) -> Cow<'_, Mat<Self>>;

    /// Borrow-or-round an accumulator-precision matrix (the running
    /// state) into storage precision — the once-per-chunk state rounding
    /// of the engine policy. A borrow when storage == accumulator.
    fn mat_from_accum(m: &Mat<Self::Accum>) -> Cow<'_, Mat<Self>>;

    /// Slice counterpart of [`Scalar::mat_from_accum`] (the running `z`).
    fn slice_from_accum(z: &[Self::Accum]) -> Cow<'_, [Self]>;
}

impl Scalar for f64 {
    type Accum = f64;

    const NAME: &'static str = "f64";
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn to_accum(self) -> f64 {
        self
    }

    #[inline(always)]
    fn from_accum(a: f64) -> Self {
        a
    }

    #[inline(always)]
    fn exp(self) -> Self {
        f64::exp(self)
    }

    #[inline(always)]
    fn dot(a: &[Self], b: &[Self]) -> Self {
        simd::dot_f64(a, b)
    }

    #[inline(always)]
    fn dot4(a: &[Self], b: [&[Self]; 4]) -> [Self; 4] {
        simd::dot4_f64(a, b)
    }

    #[inline(always)]
    fn axpy(out: &mut [Self], a: Self, x: &[Self]) {
        simd::axpy_f64(out, a, x)
    }

    #[inline(always)]
    fn axpy4(out: &mut [Self], a: [Self; 4], x: [&[Self]; 4]) {
        simd::axpy4_f64(out, a, x)
    }

    #[inline(always)]
    fn accum_row(out: &mut [f64], row: &[Self]) {
        simd::accum_row_f64(out, row)
    }

    #[inline(always)]
    fn dot_seq_accum(a: &[Self], b: &[Self]) -> f64 {
        simd::dot_seq_f64(a, b)
    }

    #[inline(always)]
    fn feature_finish(row: &mut [Self], a: f64, sqrt_w: &[f64]) {
        simd::feature_finish_f64(row, a, sqrt_w)
    }

    fn mat_from_f64(m: &Mat<f64>) -> Cow<'_, Mat<f64>> {
        Cow::Borrowed(m)
    }

    fn mat_from_accum(m: &Mat<f64>) -> Cow<'_, Mat<f64>> {
        Cow::Borrowed(m)
    }

    fn slice_from_accum(z: &[f64]) -> Cow<'_, [f64]> {
        Cow::Borrowed(z)
    }
}

impl Scalar for f32 {
    type Accum = f64;

    const NAME: &'static str = "f32";
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn to_accum(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_accum(a: f64) -> Self {
        a as f32
    }

    #[inline(always)]
    fn exp(self) -> Self {
        f32::exp(self)
    }

    #[inline(always)]
    fn dot(a: &[Self], b: &[Self]) -> Self {
        simd::dot_f32(a, b)
    }

    #[inline(always)]
    fn dot4(a: &[Self], b: [&[Self]; 4]) -> [Self; 4] {
        simd::dot4_f32(a, b)
    }

    #[inline(always)]
    fn axpy(out: &mut [Self], a: Self, x: &[Self]) {
        simd::axpy_f32(out, a, x)
    }

    #[inline(always)]
    fn axpy4(out: &mut [Self], a: [Self; 4], x: [&[Self]; 4]) {
        simd::axpy4_f32(out, a, x)
    }

    #[inline(always)]
    fn accum_row(out: &mut [f64], row: &[Self]) {
        simd::accum_row_f32(out, row)
    }

    #[inline(always)]
    fn dot_seq_accum(a: &[Self], b: &[Self]) -> f64 {
        simd::dot_seq_f32(a, b)
    }

    #[inline(always)]
    fn feature_finish(row: &mut [Self], a: f64, sqrt_w: &[f64]) {
        simd::feature_finish_f32(row, a, sqrt_w)
    }

    fn mat_from_f64(m: &Mat<f64>) -> Cow<'_, Mat<f32>> {
        Cow::Owned(Mat::<f32>::from_f64(m))
    }

    fn mat_from_accum(m: &Mat<f64>) -> Cow<'_, Mat<f32>> {
        Self::mat_from_f64(m)
    }

    fn slice_from_accum(z: &[f64]) -> Cow<'_, [f32]> {
        Cow::Owned(z.iter().map(|&x| x as f32).collect())
    }
}

/// f64 dot product with four independent accumulators: breaks the
/// add-latency dependency chain so multiple multiply/adds stay in flight.
/// Summation order differs from a sequential fold, which is fine for the
/// fresh entries [`Mat::matmul_transb`] produces. Public as
/// [`crate::linalg::dot`]: the attention engines use it for masked
/// row-wise score computation where a full gram would waste work.
///
/// Dispatches through [`crate::linalg::simd`]; the reference body (and
/// frozen fold shape every ISA reproduces bitwise) is
/// [`crate::linalg::simd::fallback::dot_f64`].
#[inline(always)]
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    simd::dot_f64(a, b)
}

/// f32 dot with eight independent accumulators — one full 256-bit vector
/// of f32 lanes per step. Summation order differs from a sequential fold
/// (fine for fresh gram entries, same contract as the f64
/// [`dot_unrolled`]).
///
/// Dispatches through [`crate::linalg::simd`]; the reference body is
/// [`crate::linalg::simd::fallback::dot_f32`].
#[inline(always)]
pub fn dot32(a: &[f32], b: &[f32]) -> f32 {
    simd::dot_f32(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot32_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32) * 0.25 - 4.0).collect();
        let b: Vec<f32> = (0..37).map(|i| 1.5 - (i as f32) * 0.125).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot32(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn conversions_are_exact_where_promised() {
        // Widening is exact; f64 conversions are all identities.
        assert_eq!(<f32 as Scalar>::to_f64(0.1f32), 0.1f32 as f64);
        assert_eq!(<f64 as Scalar>::from_f64(0.1), 0.1);
        assert_eq!(<f64 as Scalar>::to_accum(0.1), 0.1);
        assert_eq!(<f32 as Scalar>::from_accum(1.0 + 1e-12), 1.0f32);
    }

    #[test]
    fn f64_state_conversions_borrow() {
        // The f64 path must not pay copies at the precision boundary.
        let m = Mat::<f64>::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(matches!(
            <f64 as Scalar>::mat_from_accum(&m),
            Cow::Borrowed(_)
        ));
        let z = [1.0f64, 2.0];
        assert!(matches!(
            <f64 as Scalar>::slice_from_accum(&z),
            Cow::Borrowed(_)
        ));
    }
}
