//! AVX-512 microkernels (x86-64, behind the off-by-default `avx512` cargo
//! feature — the 512-bit intrinsics stabilized much later than AVX2, so
//! the default build keeps the older-toolchain-friendly surface).
//!
//! Only the *elementwise* kernels get 512-bit variants: they carry one
//! independent rounding chain per output element, so doubling the lane
//! width is bitwise-free. The dot-family folds are pinned to the 256-bit
//! lane decomposition (four f64 / eight f32 accumulators) and route to the
//! AVX2 bodies in [`super::x86`] — a 512-bit fold would change the
//! association and break the bitwise contract.

#![cfg(all(target_arch = "x86_64", feature = "avx512"))]

use core::arch::x86_64::*;

/// `out[j] += a * x[j]` at 512-bit width (elementwise ⇒ bitwise).
///
/// # Safety
/// Caller must ensure the CPU supports AVX-512F.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 8 * 8;
    let av = _mm512_set1_pd(a);
    let mut i = 0;
    while i < body {
        let o = _mm512_loadu_pd(out.as_ptr().add(i));
        let v = _mm512_loadu_pd(x.as_ptr().add(i));
        _mm512_storeu_pd(out.as_mut_ptr().add(i), _mm512_add_pd(o, _mm512_mul_pd(av, v)));
        i += 8;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// `out[j] += a * x[j]` at 512-bit width (single-precision).
///
/// # Safety
/// Caller must ensure the CPU supports AVX-512F.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 16 * 16;
    let av = _mm512_set1_ps(a);
    let mut i = 0;
    while i < body {
        let o = _mm512_loadu_ps(out.as_ptr().add(i));
        let v = _mm512_loadu_ps(x.as_ptr().add(i));
        _mm512_storeu_ps(out.as_mut_ptr().add(i), _mm512_add_ps(o, _mm512_mul_ps(av, v)));
        i += 16;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// Register-blocked 4-column update at 512-bit width; per element the four
/// `mul`+`add` pairs apply in ascending operand order (elementwise ⇒
/// bitwise vs [`super::fallback::axpy4_f64`]).
///
/// # Safety
/// Caller must ensure the CPU supports AVX-512F.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy4_f64(out: &mut [f64], a: [f64; 4], x: [&[f64]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let a0 = _mm512_set1_pd(a[0]);
    let a1 = _mm512_set1_pd(a[1]);
    let a2 = _mm512_set1_pd(a[2]);
    let a3 = _mm512_set1_pd(a[3]);
    let body = n / 8 * 8;
    let mut i = 0;
    while i < body {
        let mut o = _mm512_loadu_pd(out.as_ptr().add(i));
        o = _mm512_add_pd(o, _mm512_mul_pd(a0, _mm512_loadu_pd(x[0].as_ptr().add(i))));
        o = _mm512_add_pd(o, _mm512_mul_pd(a1, _mm512_loadu_pd(x[1].as_ptr().add(i))));
        o = _mm512_add_pd(o, _mm512_mul_pd(a2, _mm512_loadu_pd(x[2].as_ptr().add(i))));
        o = _mm512_add_pd(o, _mm512_mul_pd(a3, _mm512_loadu_pd(x[3].as_ptr().add(i))));
        _mm512_storeu_pd(out.as_mut_ptr().add(i), o);
        i += 8;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// Register-blocked 4-column update at 512-bit width (single-precision).
///
/// # Safety
/// Caller must ensure the CPU supports AVX-512F.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy4_f32(out: &mut [f32], a: [f32; 4], x: [&[f32]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let a0 = _mm512_set1_ps(a[0]);
    let a1 = _mm512_set1_ps(a[1]);
    let a2 = _mm512_set1_ps(a[2]);
    let a3 = _mm512_set1_ps(a[3]);
    let body = n / 16 * 16;
    let mut i = 0;
    while i < body {
        let mut o = _mm512_loadu_ps(out.as_ptr().add(i));
        o = _mm512_add_ps(o, _mm512_mul_ps(a0, _mm512_loadu_ps(x[0].as_ptr().add(i))));
        o = _mm512_add_ps(o, _mm512_mul_ps(a1, _mm512_loadu_ps(x[1].as_ptr().add(i))));
        o = _mm512_add_ps(o, _mm512_mul_ps(a2, _mm512_loadu_ps(x[2].as_ptr().add(i))));
        o = _mm512_add_ps(o, _mm512_mul_ps(a3, _mm512_loadu_ps(x[3].as_ptr().add(i))));
        _mm512_storeu_ps(out.as_mut_ptr().add(i), o);
        i += 16;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}
