//! Portable reference kernels — the semantic contract for every ISA.
//!
//! Each function here is the *definition* of the corresponding dispatch
//! entry point in [`super`]: an explicit-SIMD implementation for some ISA
//! is correct iff it produces bitwise-identical results to the function in
//! this module for every input. The fold shapes are frozen:
//!
//! - [`dot_f64`] is the historical `dot_unrolled` kernel: four independent
//!   accumulators over `chunks_exact(4)`, sequential tail, reduced as
//!   `(acc0 + acc1) + (acc2 + acc3) + tail`. A 256-bit lane group (or two
//!   128-bit NEON registers) maps onto those four accumulators exactly, so
//!   AVX2/NEON dots are bitwise-identical by construction.
//! - [`dot_f32`] is the historical `dot32` kernel: eight accumulators over
//!   `chunks_exact(8)`, reduced pairwise as
//!   `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7)) + tail` — one 8-lane `f32`
//!   vector, or two NEON quads.
//! - The elementwise kernels ([`axpy_f64`], [`axpy4_f64`], [`accum_row_f64`],
//!   their `f32` twins, and [`feature_finish_f64`]/[`feature_finish_f32`])
//!   have one independent rounding chain per output element, so *any*
//!   vector width is bitwise-identical as long as the per-element operation
//!   order is preserved (`mul` then `add`, never fused).
//! - [`dot_seq_f64`]/[`dot_seq_f32`] are the strictly sequential widening
//!   folds behind `Mat::matvec_accum` (denominator contract: one running
//!   `f64` accumulator, ascending index order). SIMD variants may vectorize
//!   the widen+multiply stage only; the fold itself must stay in-order.
//!
//! These functions double as the oracle for `rust/tests/linalg_simd.rs`.

/// Dot product with four independent accumulators (frozen `dot_unrolled`).
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Dot product with eight independent `f32` accumulators (frozen `dot32`).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
        acc[4] += xa[4] * xb[4];
        acc[5] += xa[5] * xb[5];
        acc[6] += xa[6] * xb[6];
        acc[7] += xa[7] * xb[7];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// Four dot products against a shared left operand; each output is the
/// plain [`dot_f64`] fold, so this is bitwise-equal to four separate calls.
pub fn dot4_f64(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    [
        dot_f64(a, b[0]),
        dot_f64(a, b[1]),
        dot_f64(a, b[2]),
        dot_f64(a, b[3]),
    ]
}

/// Four dot products against a shared left operand ([`dot_f32`] fold).
pub fn dot4_f32(a: &[f32], b: [&[f32]; 4]) -> [f32; 4] {
    [
        dot_f32(a, b[0]),
        dot_f32(a, b[1]),
        dot_f32(a, b[2]),
        dot_f32(a, b[3]),
    ]
}

/// `out[j] += a * x[j]` — the inner row update of the tiled `matmul`.
pub fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

/// `out[j] += a * x[j]` (single-precision).
pub fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

/// Register-blocked 4-column update:
/// `out[j] += a[0]*x0[j]; out[j] += a[1]*x1[j]; ...` per element, in
/// ascending operand order. Per output element this is exactly the
/// rounding chain of four consecutive [`axpy_f64`] calls, so fusing the
/// four updates into one pass over `out` is bitwise-free.
pub fn axpy4_f64(out: &mut [f64], a: [f64; 4], x: [&[f64]; 4]) {
    debug_assert_eq!(out.len(), x[0].len());
    debug_assert_eq!(out.len(), x[1].len());
    debug_assert_eq!(out.len(), x[2].len());
    debug_assert_eq!(out.len(), x[3].len());
    for (j, o) in out.iter_mut().enumerate() {
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// Register-blocked 4-column update (single-precision).
pub fn axpy4_f32(out: &mut [f32], a: [f32; 4], x: [&[f32]; 4]) {
    debug_assert_eq!(out.len(), x[0].len());
    debug_assert_eq!(out.len(), x[1].len());
    debug_assert_eq!(out.len(), x[2].len());
    debug_assert_eq!(out.len(), x[3].len());
    for (j, o) in out.iter_mut().enumerate() {
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// `out[j] += row[j]` — one row step of `Mat::<f64>::col_sums`.
pub fn accum_row_f64(out: &mut [f64], row: &[f64]) {
    debug_assert_eq!(out.len(), row.len());
    for (o, &v) in out.iter_mut().zip(row) {
        *o += v;
    }
}

/// `out[j] += row[j] as f64` — one widening row step of
/// `Mat::<f32>::col_sums` (the `Scalar::Accum = f64` policy).
pub fn accum_row_f32(out: &mut [f64], row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    for (o, &v) in out.iter_mut().zip(row) {
        *o += v as f64;
    }
}

/// Strictly sequential dot in the accumulator type: one running `f64`
/// sum in ascending index order (the `matvec_accum` denominator fold).
pub fn dot_seq_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Strictly sequential widening dot: products formed in `f64`, summed in
/// ascending index order.
pub fn dot_seq_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as f64 * y as f64;
    }
    acc
}

/// Feature-map finish: `row[j] = exp(row[j] - a) * sqrt_w[j]`, all in
/// `f64`. `exp` is always the scalar libm call — a vector polynomial
/// `exp` could not be bitwise-identical — so SIMD variants may vectorize
/// only the subtract/multiply stages around it.
pub fn feature_finish_f64(row: &mut [f64], a: f64, sqrt_w: &[f64]) {
    debug_assert_eq!(row.len(), sqrt_w.len());
    for (p, &sw) in row.iter_mut().zip(sqrt_w) {
        *p = (*p - a).exp() * sw;
    }
}

/// Feature-map finish on `f32` storage: widen to `f64`, subtract,
/// scalar-libm `exp`, scale, round once back to `f32` (round-to-nearest,
/// identical to an `as f32` cast).
pub fn feature_finish_f32(row: &mut [f32], a: f64, sqrt_w: &[f64]) {
    debug_assert_eq!(row.len(), sqrt_w.len());
    for (p, &sw) in row.iter_mut().zip(sqrt_w) {
        *p = ((*p as f64 - a).exp() * sw) as f32;
    }
}
