//! Runtime-dispatched explicit-SIMD microkernels for the `Scalar` stack.
//!
//! # Architecture
//!
//! This module is the single funnel between the generic [`Mat<T>`] /
//! [`Scalar`] call sites and the per-ISA kernel implementations:
//!
//! ```text
//!   Mat<T> / FeatureBank / CausalState
//!        │  (sealed Scalar kernel hooks: dot, dot4, axpy, axpy4, ...)
//!        ▼
//!   linalg::simd  — dispatch functions (this file)
//!        │  isa(): one cached AtomicU8, detection runs once
//!        ├── x86.rs      AVX2 kernels            (x86-64)
//!        ├── avx512.rs   AVX-512 elementwise     (x86-64 + `avx512` feature)
//!        ├── neon.rs     NEON kernels            (aarch64)
//!        └── fallback.rs portable reference      (every target)
//! ```
//!
//! The ISA is detected once (`is_x86_feature_detected!` on x86-64; NEON is
//! baseline on aarch64) and cached in a process-wide atomic. The
//! `RFA_SIMD` environment variable overrides detection at first use —
//! `RFA_SIMD=scalar` forces the portable fallback everywhere (A/B timing,
//! debugging), and a named ISA (`avx2`, `avx512`, `neon`) is honored only
//! if the running CPU actually supports it. [`set_isa`] changes the
//! effective ISA in-process (benches use it for dispatched-vs-scalar
//! speedup metrics; tests use it to run golden pins under both modes).
//!
//! # Bitwise policy
//!
//! Every kernel in every ISA module is **bitwise-identical** to its
//! [`fallback`] reference — the fallback bodies are the frozen historical
//! kernels (`dot_unrolled`, `dot32`, the tiled-matmul row update, the
//! sequential `matvec_accum` fold, the feature-map exponent loop), and
//! `rust/tests/linalg_simd.rs` pins dispatched-vs-fallback equality with
//! `to_bits` across adversarial shapes. The fold disciplines that make
//! bitwise-at-any-ISA possible are documented in [`fallback`]; the short
//! version: no FMA, lane groups mapped exactly onto the historical
//! accumulator layout, scalar-order reductions, scalar libm `exp`, and
//! sequential folds vectorized only in their widen+multiply stage.
//! Because switching ISA never changes results, a mid-computation
//! [`set_isa`] from another thread is numerically benign.
//!
//! # Adding an ISA
//!
//! 1. Add a variant to [`Isa`] and a `<isa>.rs` module whose kernels are
//!    bitwise-identical to [`fallback`] (match the accumulator layouts —
//!    e.g. a 512-bit dot must still fold as four f64 / eight f32 lanes).
//! 2. Teach [`supported`]/`detect` to report it (runtime feature check,
//!    gated on `target_arch` and, if the intrinsics are newer than the
//!    repo's floor toolchain, a cargo feature like `avx512`).
//! 3. Add an early-return arm to each dispatch function below and a name
//!    to [`active_isa`].
//! 4. Extend the forced-ISA loop in `rust/tests/linalg_simd.rs`; the
//!    property suite and the `rfa_generic.rs` golden pins do the rest.
//!
//! [`Mat<T>`]: crate::linalg::Mat
//! [`Scalar`]: crate::linalg::Scalar

pub mod fallback;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set families the dispatcher can route to.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable fallback — the frozen reference kernels, no `std::arch`.
    Scalar = 0,
    /// 128-bit aarch64 NEON (baseline on that architecture).
    Neon = 1,
    /// 256-bit x86-64 AVX2.
    Avx2 = 2,
    /// 512-bit x86-64 AVX-512F (requires the `avx512` cargo feature;
    /// dot-family folds still run the 256-bit AVX2 bodies — see
    /// `avx512.rs`).
    Avx512 = 3,
}

/// Sentinel for "not yet initialized" in the cached-ISA atomic.
const UNSET: u8 = u8::MAX;

/// Process-wide effective ISA, initialized on first kernel call.
static ISA: AtomicU8 = AtomicU8::new(UNSET);

fn decode(v: u8) -> Isa {
    match v {
        1 => Isa::Neon,
        2 => Isa::Avx2,
        3 => Isa::Avx512,
        _ => Isa::Scalar,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Isa {
    #[cfg(feature = "avx512")]
    if is_x86_feature_detected!("avx512f") {
        return Isa::Avx512;
    }
    if is_x86_feature_detected!("avx2") {
        return Isa::Avx2;
    }
    Isa::Scalar
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Isa {
    Isa::Scalar
}

/// Whether the running CPU (and compiled feature set) can execute kernels
/// for `target`. [`Isa::Scalar`] is always supported.
pub fn supported(target: Isa) -> bool {
    match target {
        Isa::Scalar => true,
        Isa::Neon => cfg!(target_arch = "aarch64"),
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                is_x86_feature_detected!("avx2")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        Isa::Avx512 => {
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            {
                is_x86_feature_detected!("avx512f")
            }
            #[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
            {
                false
            }
        }
    }
}

/// What detection alone would pick on this machine (ignores the cached
/// override state and `RFA_SIMD`).
pub fn detected_isa() -> Isa {
    detect()
}

fn initial() -> Isa {
    match std::env::var("RFA_SIMD").as_deref() {
        Ok("scalar") => Isa::Scalar,
        Ok("neon") if supported(Isa::Neon) => Isa::Neon,
        Ok("avx2") if supported(Isa::Avx2) => Isa::Avx2,
        Ok("avx512") if supported(Isa::Avx512) => Isa::Avx512,
        _ => detect(),
    }
}

/// The effective ISA every dispatch function routes on. First call runs
/// detection (honoring `RFA_SIMD`) and caches the result; afterwards this
/// is one relaxed atomic load.
pub fn isa() -> Isa {
    let v = ISA.load(Ordering::Relaxed);
    if v != UNSET {
        return decode(v);
    }
    let init = initial();
    ISA.store(init as u8, Ordering::Relaxed);
    init
}

/// Force the effective ISA for this process and return the previous one.
///
/// Unsupported targets are sanitized to [`Isa::Scalar`], so the dispatch
/// functions never route to a kernel the CPU cannot run. Benches use
/// `set_isa(Isa::Scalar)` + restore for dispatched-vs-scalar A/B timing;
/// `rfa_generic.rs` uses it to run the golden pins under both modes. The
/// setting is process-global; since every ISA is bitwise-identical,
/// concurrent readers only ever see a performance difference.
pub fn set_isa(target: Isa) -> Isa {
    let prev = isa();
    let eff = if supported(target) { target } else { Isa::Scalar };
    ISA.store(eff as u8, Ordering::Relaxed);
    prev
}

/// Human-readable name of the effective ISA (`"avx512"`, `"avx2"`,
/// `"neon"`, or `"scalar"`). Recorded as a metric in every
/// `BENCH_*.json` so perf numbers are comparable across machines.
pub fn active_isa() -> &'static str {
    match isa() {
        Isa::Scalar => "scalar",
        Isa::Neon => "neon",
        Isa::Avx2 => "avx2",
        Isa::Avx512 => "avx512",
    }
}

// ------------------------------------------------------------ dispatch
//
// One function per microkernel. Each checks the cached ISA and
// early-returns into the widest bitwise-identical implementation; the
// portable fallback is always the final arm, so the default build runs on
// any target with zero `std::arch` requirements.

/// Dot product, frozen `dot_unrolled` fold (four f64 accumulators).
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::dot_f64(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot_f64(a, b) };
    }
    fallback::dot_f64(a, b)
}

/// Dot product, frozen `dot32` fold (eight f32 accumulators).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::dot_f32(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot_f32(a, b) };
    }
    fallback::dot_f32(a, b)
}

/// Four dot products against a shared left operand (each the `dot_f64`
/// fold — bitwise-equal to four separate dots).
pub fn dot4_f64(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::dot4_f64(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot4_f64(a, b) };
    }
    fallback::dot4_f64(a, b)
}

/// Four dot products against a shared left operand (`dot_f32` fold).
pub fn dot4_f32(a: &[f32], b: [&[f32]; 4]) -> [f32; 4] {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::dot4_f32(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot4_f32(a, b) };
    }
    fallback::dot4_f32(a, b)
}

/// `out[j] += a * x[j]` (tiled-matmul row update).
pub fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    if matches!(isa(), Isa::Avx512) {
        // SAFETY: Avx512 is effective only after avx512f detection.
        return unsafe { avx512::axpy_f64(out, a, x) };
    }
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::axpy_f64(out, a, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::axpy_f64(out, a, x) };
    }
    fallback::axpy_f64(out, a, x)
}

/// `out[j] += a * x[j]` (single-precision).
pub fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    if matches!(isa(), Isa::Avx512) {
        // SAFETY: Avx512 is effective only after avx512f detection.
        return unsafe { avx512::axpy_f32(out, a, x) };
    }
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::axpy_f32(out, a, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::axpy_f32(out, a, x) };
    }
    fallback::axpy_f32(out, a, x)
}

/// Register-blocked 4-column row update (ascending operand order per
/// element — bitwise-equal to four consecutive `axpy_f64` calls).
pub fn axpy4_f64(out: &mut [f64], a: [f64; 4], x: [&[f64]; 4]) {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    if matches!(isa(), Isa::Avx512) {
        // SAFETY: Avx512 is effective only after avx512f detection.
        return unsafe { avx512::axpy4_f64(out, a, x) };
    }
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::axpy4_f64(out, a, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::axpy4_f64(out, a, x) };
    }
    fallback::axpy4_f64(out, a, x)
}

/// Register-blocked 4-column row update (single-precision).
pub fn axpy4_f32(out: &mut [f32], a: [f32; 4], x: [&[f32]; 4]) {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    if matches!(isa(), Isa::Avx512) {
        // SAFETY: Avx512 is effective only after avx512f detection.
        return unsafe { avx512::axpy4_f32(out, a, x) };
    }
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::axpy4_f32(out, a, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::axpy4_f32(out, a, x) };
    }
    fallback::axpy4_f32(out, a, x)
}

/// `out[j] += row[j]` (one `col_sums` row step).
pub fn accum_row_f64(out: &mut [f64], row: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::accum_row_f64(out, row) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::accum_row_f64(out, row) };
    }
    fallback::accum_row_f64(out, row)
}

/// `out[j] += row[j] as f64` (widening `col_sums` row step).
pub fn accum_row_f32(out: &mut [f64], row: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::accum_row_f32(out, row) };
    }
    #[cfg(target_arch = "aarch64")]
    if matches!(isa(), Isa::Neon) {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::accum_row_f32(out, row) };
    }
    fallback::accum_row_f32(out, row)
}

/// Strictly sequential dot (`matvec_accum` denominator fold). Always the
/// fallback: the contract is one running `f64` sum in ascending index
/// order, and for f64 inputs there is no widen/multiply stage left to
/// vectorize without changing the fold association.
pub fn dot_seq_f64(a: &[f64], b: &[f64]) -> f64 {
    fallback::dot_seq_f64(a, b)
}

/// Strictly sequential widening dot. On AVX2 the widen+multiply stage is
/// vectorized; the fold itself stays in ascending index order (bitwise).
pub fn dot_seq_f32(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::dot_seq_f32(a, b) };
    }
    fallback::dot_seq_f32(a, b)
}

/// Feature-map finish `row[j] = exp(row[j] - a) * sqrt_w[j]`. Always the
/// fallback for f64 storage: `exp` must stay the scalar libm call to
/// remain bitwise, and with no precision conversions the surrounding
/// subtract/multiply are already single scalar ops per element.
pub fn feature_finish_f64(row: &mut [f64], a: f64, sqrt_w: &[f64]) {
    fallback::feature_finish_f64(row, a, sqrt_w)
}

/// Feature-map finish on f32 storage. On AVX2 the widen/subtract/scale/
/// narrow stages are vectorized around the scalar libm `exp` (bitwise).
pub fn feature_finish_f32(row: &mut [f32], a: f64, sqrt_w: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa(), Isa::Avx2 | Isa::Avx512) {
        // SAFETY: isa() reports Avx2/Avx512 only after runtime detection.
        return unsafe { x86::feature_finish_f32(row, a, sqrt_w) };
    }
    fallback::feature_finish_f32(row, a, sqrt_w)
}
