//! NEON microkernels (aarch64).
//!
//! NEON registers are 128-bit, so the frozen fold shapes map onto register
//! *pairs*: the `f64` dot keeps two `float64x2_t` accumulators whose four
//! lanes are the four scalar accumulators of `fallback::dot_f64`, and the
//! `f32` dot keeps two `float32x4_t` accumulators covering the eight
//! accumulators of `fallback::dot_f32`. Reductions extract lanes and
//! combine in the exact scalar order, multiplies and adds stay separate
//! (`vmulq` + `vaddq`, never `vfmaq` — fusing changes rounding), so every
//! kernel is bitwise-identical to its [`super::fallback`] reference.
//!
//! The sequential-fold (`dot_seq_*`) and feature-finish kernels stay on
//! the fallback on NEON: the fold order is contractual and the `exp` call
//! dominates, so there is little to vectorize — see the dispatcher in
//! [`super`].
//!
//! NEON is a baseline feature of aarch64, but the kernels keep the same
//! `unsafe fn` + `#[target_feature]` shape as the x86 file so the
//! dispatcher treats every ISA module uniformly.

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

/// Bitwise-identical NEON form of [`super::fallback::dot_f64`].
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let body = n / 4 * 4;
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    let mut i = 0;
    while i < body {
        let a01 = vld1q_f64(a.as_ptr().add(i));
        let b01 = vld1q_f64(b.as_ptr().add(i));
        acc01 = vaddq_f64(acc01, vmulq_f64(a01, b01));
        let a23 = vld1q_f64(a.as_ptr().add(i + 2));
        let b23 = vld1q_f64(b.as_ptr().add(i + 2));
        acc23 = vaddq_f64(acc23, vmulq_f64(a23, b23));
        i += 4;
    }
    let mut tail = 0.0;
    for j in body..n {
        tail += a[j] * b[j];
    }
    let l0 = vgetq_lane_f64::<0>(acc01);
    let l1 = vgetq_lane_f64::<1>(acc01);
    let l2 = vgetq_lane_f64::<0>(acc23);
    let l3 = vgetq_lane_f64::<1>(acc23);
    (l0 + l1) + (l2 + l3) + tail
}

/// Bitwise-identical NEON form of [`super::fallback::dot_f32`].
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let body = n / 8 * 8;
    let mut lo = vdupq_n_f32(0.0);
    let mut hi = vdupq_n_f32(0.0);
    let mut i = 0;
    while i < body {
        let a_lo = vld1q_f32(a.as_ptr().add(i));
        let b_lo = vld1q_f32(b.as_ptr().add(i));
        lo = vaddq_f32(lo, vmulq_f32(a_lo, b_lo));
        let a_hi = vld1q_f32(a.as_ptr().add(i + 4));
        let b_hi = vld1q_f32(b.as_ptr().add(i + 4));
        hi = vaddq_f32(hi, vmulq_f32(a_hi, b_hi));
        i += 8;
    }
    let mut tail = 0.0;
    for j in body..n {
        tail += a[j] * b[j];
    }
    let l0 = vgetq_lane_f32::<0>(lo);
    let l1 = vgetq_lane_f32::<1>(lo);
    let l2 = vgetq_lane_f32::<2>(lo);
    let l3 = vgetq_lane_f32::<3>(lo);
    let l4 = vgetq_lane_f32::<0>(hi);
    let l5 = vgetq_lane_f32::<1>(hi);
    let l6 = vgetq_lane_f32::<2>(hi);
    let l7 = vgetq_lane_f32::<3>(hi);
    ((l0 + l1) + (l2 + l3)) + ((l4 + l5) + (l6 + l7)) + tail
}

/// Four dot products against a shared left operand; each is the plain
/// [`dot_f64`] fold (= [`super::fallback::dot4_f64`]).
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn dot4_f64(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    [
        dot_f64(a, b[0]),
        dot_f64(a, b[1]),
        dot_f64(a, b[2]),
        dot_f64(a, b[3]),
    ]
}

/// Four dot products against a shared left operand ([`dot_f32`] fold).
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn dot4_f32(a: &[f32], b: [&[f32]; 4]) -> [f32; 4] {
    [
        dot_f32(a, b[0]),
        dot_f32(a, b[1]),
        dot_f32(a, b[2]),
        dot_f32(a, b[3]),
    ]
}

/// `out[j] += a * x[j]` — elementwise, bitwise at any lane width.
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 2 * 2;
    let av = vdupq_n_f64(a);
    let mut i = 0;
    while i < body {
        let o = vld1q_f64(out.as_ptr().add(i));
        let v = vld1q_f64(x.as_ptr().add(i));
        vst1q_f64(out.as_mut_ptr().add(i), vaddq_f64(o, vmulq_f64(av, v)));
        i += 2;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// `out[j] += a * x[j]` (single-precision, elementwise).
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 4 * 4;
    let av = vdupq_n_f32(a);
    let mut i = 0;
    while i < body {
        let o = vld1q_f32(out.as_ptr().add(i));
        let v = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(o, vmulq_f32(av, v)));
        i += 4;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// Register-blocked 4-column update; per element the four `mul`+`add`
/// pairs apply in ascending operand order ([`super::fallback::axpy4_f64`]).
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn axpy4_f64(out: &mut [f64], a: [f64; 4], x: [&[f64]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let a0 = vdupq_n_f64(a[0]);
    let a1 = vdupq_n_f64(a[1]);
    let a2 = vdupq_n_f64(a[2]);
    let a3 = vdupq_n_f64(a[3]);
    let body = n / 2 * 2;
    let mut i = 0;
    while i < body {
        let mut o = vld1q_f64(out.as_ptr().add(i));
        o = vaddq_f64(o, vmulq_f64(a0, vld1q_f64(x[0].as_ptr().add(i))));
        o = vaddq_f64(o, vmulq_f64(a1, vld1q_f64(x[1].as_ptr().add(i))));
        o = vaddq_f64(o, vmulq_f64(a2, vld1q_f64(x[2].as_ptr().add(i))));
        o = vaddq_f64(o, vmulq_f64(a3, vld1q_f64(x[3].as_ptr().add(i))));
        vst1q_f64(out.as_mut_ptr().add(i), o);
        i += 2;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// Register-blocked 4-column update (single-precision).
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn axpy4_f32(out: &mut [f32], a: [f32; 4], x: [&[f32]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let a0 = vdupq_n_f32(a[0]);
    let a1 = vdupq_n_f32(a[1]);
    let a2 = vdupq_n_f32(a[2]);
    let a3 = vdupq_n_f32(a[3]);
    let body = n / 4 * 4;
    let mut i = 0;
    while i < body {
        let mut o = vld1q_f32(out.as_ptr().add(i));
        o = vaddq_f32(o, vmulq_f32(a0, vld1q_f32(x[0].as_ptr().add(i))));
        o = vaddq_f32(o, vmulq_f32(a1, vld1q_f32(x[1].as_ptr().add(i))));
        o = vaddq_f32(o, vmulq_f32(a2, vld1q_f32(x[2].as_ptr().add(i))));
        o = vaddq_f32(o, vmulq_f32(a3, vld1q_f32(x[3].as_ptr().add(i))));
        vst1q_f32(out.as_mut_ptr().add(i), o);
        i += 4;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// `out[j] += row[j]` — elementwise, bitwise at any lane width.
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn accum_row_f64(out: &mut [f64], row: &[f64]) {
    debug_assert_eq!(out.len(), row.len());
    let n = out.len();
    let body = n / 2 * 2;
    let mut i = 0;
    while i < body {
        let o = vld1q_f64(out.as_ptr().add(i));
        let v = vld1q_f64(row.as_ptr().add(i));
        vst1q_f64(out.as_mut_ptr().add(i), vaddq_f64(o, v));
        i += 2;
    }
    for j in body..n {
        out[j] += row[j];
    }
}

/// `out[j] += row[j] as f64` — `vcvt_f64_f32` widens exactly like the
/// scalar `as f64` cast (f32→f64 is lossless), so this stays bitwise.
///
/// # Safety
/// Caller must ensure the CPU supports NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn accum_row_f32(out: &mut [f64], row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    let n = out.len();
    let body = n / 2 * 2;
    let mut i = 0;
    while i < body {
        let o = vld1q_f64(out.as_ptr().add(i));
        let v = vcvt_f64_f32(vld1_f32(row.as_ptr().add(i)));
        vst1q_f64(out.as_mut_ptr().add(i), vaddq_f64(o, v));
        i += 2;
    }
    for j in body..n {
        out[j] += row[j] as f64;
    }
}
