//! AVX2 microkernels (x86-64).
//!
//! Every kernel here is bitwise-identical to its [`super::fallback`]
//! reference — see the module docs there for the frozen fold shapes. The
//! lane discipline that makes this possible:
//!
//! - multiplies and adds stay separate (`_mm256_mul_pd` + `_mm256_add_pd`,
//!   never FMA — fusing changes the rounding of every partial product);
//! - the `f64` dot keeps ONE 256-bit accumulator whose four lanes *are*
//!   the four scalar accumulators of `fallback::dot_f64`, reduced in the
//!   exact scalar order `(l0 + l1) + (l2 + l3) + tail`;
//! - the `f32` dot keeps ONE 8-lane accumulator matching
//!   `fallback::dot_f32`, reduced as `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`;
//! - widening/narrowing conversions use `_mm256_cvtps_pd` /
//!   `_mm256_cvtpd_ps`, which round exactly like Rust `as` casts
//!   (round-to-nearest-even, overflow to infinity);
//! - all loads/stores are unaligned (`loadu`/`storeu`) — `Mat<T>` rows can
//!   start at any offset;
//! - `dot_seq_*` sequential folds are vectorized only in the widen+multiply
//!   stage; the running sum still adds lane products in ascending index
//!   order (`dot_seq_f64` has no such stage and stays on the fallback).
//!
//! All functions are `unsafe fn` with `#[target_feature(enable = "avx2")]`:
//! the caller (the dispatcher in [`super`]) must have verified AVX2 support.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

/// Reduce a 256-bit accumulator in the frozen `dot_unrolled` order.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce4(acc: __m256d) -> f64 {
    let mut l = [0.0f64; 4];
    _mm256_storeu_pd(l.as_mut_ptr(), acc);
    (l[0] + l[1]) + (l[2] + l[3])
}

/// Reduce an 8-lane accumulator in the frozen `dot32` order.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce8(acc: __m256) -> f32 {
    let mut l = [0.0f32; 8];
    _mm256_storeu_ps(l.as_mut_ptr(), acc);
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Bitwise-identical AVX2 form of [`super::fallback::dot_f64`].
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let body = n / 4 * 4;
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < body {
        let va = _mm256_loadu_pd(a.as_ptr().add(i));
        let vb = _mm256_loadu_pd(b.as_ptr().add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
        i += 4;
    }
    let mut tail = 0.0;
    for j in body..n {
        tail += a[j] * b[j];
    }
    reduce4(acc) + tail
}

/// Bitwise-identical AVX2 form of [`super::fallback::dot_f32`].
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let body = n / 8 * 8;
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i < body {
        let va = _mm256_loadu_ps(a.as_ptr().add(i));
        let vb = _mm256_loadu_ps(b.as_ptr().add(i));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        i += 8;
    }
    let mut tail = 0.0;
    for j in body..n {
        tail += a[j] * b[j];
    }
    reduce8(acc) + tail
}

/// Four dots sharing each left-operand load; each accumulator follows the
/// [`dot_f64`] fold independently, so the result is bitwise-equal to four
/// separate dots (= [`super::fallback::dot4_f64`]).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot4_f64(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    let n = a.len();
    debug_assert!(b.iter().all(|bi| bi.len() == n));
    let body = n / 4 * 4;
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut acc2 = _mm256_setzero_pd();
    let mut acc3 = _mm256_setzero_pd();
    let mut i = 0;
    while i < body {
        let va = _mm256_loadu_pd(a.as_ptr().add(i));
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(va, _mm256_loadu_pd(b[0].as_ptr().add(i))));
        acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(va, _mm256_loadu_pd(b[1].as_ptr().add(i))));
        acc2 = _mm256_add_pd(acc2, _mm256_mul_pd(va, _mm256_loadu_pd(b[2].as_ptr().add(i))));
        acc3 = _mm256_add_pd(acc3, _mm256_mul_pd(va, _mm256_loadu_pd(b[3].as_ptr().add(i))));
        i += 4;
    }
    let mut out = [reduce4(acc0), reduce4(acc1), reduce4(acc2), reduce4(acc3)];
    for (k, o) in out.iter_mut().enumerate() {
        let mut tail = 0.0;
        for j in body..n {
            tail += a[j] * b[k][j];
        }
        *o += tail;
    }
    out
}

/// Four dots sharing each left-operand load ([`dot_f32`] fold per lane
/// group; bitwise-equal to [`super::fallback::dot4_f32`]).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot4_f32(a: &[f32], b: [&[f32]; 4]) -> [f32; 4] {
    let n = a.len();
    debug_assert!(b.iter().all(|bi| bi.len() == n));
    let body = n / 8 * 8;
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0;
    while i < body {
        let va = _mm256_loadu_ps(a.as_ptr().add(i));
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(va, _mm256_loadu_ps(b[0].as_ptr().add(i))));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(va, _mm256_loadu_ps(b[1].as_ptr().add(i))));
        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(va, _mm256_loadu_ps(b[2].as_ptr().add(i))));
        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(va, _mm256_loadu_ps(b[3].as_ptr().add(i))));
        i += 8;
    }
    let mut out = [reduce8(acc0), reduce8(acc1), reduce8(acc2), reduce8(acc3)];
    for (k, o) in out.iter_mut().enumerate() {
        let mut tail = 0.0;
        for j in body..n {
            tail += a[j] * b[k][j];
        }
        *o += tail;
    }
    out
}

/// `out[j] += a * x[j]` — elementwise, so bitwise at any lane width.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 4 * 4;
    let av = _mm256_set1_pd(a);
    let mut i = 0;
    while i < body {
        let o = _mm256_loadu_pd(out.as_ptr().add(i));
        let v = _mm256_loadu_pd(x.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_add_pd(o, _mm256_mul_pd(av, v)));
        i += 4;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// `out[j] += a * x[j]` (single-precision, elementwise).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let body = n / 8 * 8;
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i < body {
        let o = _mm256_loadu_ps(out.as_ptr().add(i));
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(o, _mm256_mul_ps(av, v)));
        i += 8;
    }
    for j in body..n {
        out[j] += a * x[j];
    }
}

/// Register-blocked 4-column update: per element the four `mul`+`add`
/// pairs apply in ascending operand order, exactly as in
/// [`super::fallback::axpy4_f64`].
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy4_f64(out: &mut [f64], a: [f64; 4], x: [&[f64]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let body = n / 4 * 4;
    let a0 = _mm256_set1_pd(a[0]);
    let a1 = _mm256_set1_pd(a[1]);
    let a2 = _mm256_set1_pd(a[2]);
    let a3 = _mm256_set1_pd(a[3]);
    let mut i = 0;
    while i < body {
        let mut o = _mm256_loadu_pd(out.as_ptr().add(i));
        o = _mm256_add_pd(o, _mm256_mul_pd(a0, _mm256_loadu_pd(x[0].as_ptr().add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(a1, _mm256_loadu_pd(x[1].as_ptr().add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(a2, _mm256_loadu_pd(x[2].as_ptr().add(i))));
        o = _mm256_add_pd(o, _mm256_mul_pd(a3, _mm256_loadu_pd(x[3].as_ptr().add(i))));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), o);
        i += 4;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// Register-blocked 4-column update (single-precision).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy4_f32(out: &mut [f32], a: [f32; 4], x: [&[f32]; 4]) {
    let n = out.len();
    debug_assert!(x.iter().all(|xi| xi.len() == n));
    let body = n / 8 * 8;
    let a0 = _mm256_set1_ps(a[0]);
    let a1 = _mm256_set1_ps(a[1]);
    let a2 = _mm256_set1_ps(a[2]);
    let a3 = _mm256_set1_ps(a[3]);
    let mut i = 0;
    while i < body {
        let mut o = _mm256_loadu_ps(out.as_ptr().add(i));
        o = _mm256_add_ps(o, _mm256_mul_ps(a0, _mm256_loadu_ps(x[0].as_ptr().add(i))));
        o = _mm256_add_ps(o, _mm256_mul_ps(a1, _mm256_loadu_ps(x[1].as_ptr().add(i))));
        o = _mm256_add_ps(o, _mm256_mul_ps(a2, _mm256_loadu_ps(x[2].as_ptr().add(i))));
        o = _mm256_add_ps(o, _mm256_mul_ps(a3, _mm256_loadu_ps(x[3].as_ptr().add(i))));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), o);
        i += 8;
    }
    for j in body..n {
        let o = &mut out[j];
        *o += a[0] * x[0][j];
        *o += a[1] * x[1][j];
        *o += a[2] * x[2][j];
        *o += a[3] * x[3][j];
    }
}

/// `out[j] += row[j]` — elementwise, bitwise at any lane width.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn accum_row_f64(out: &mut [f64], row: &[f64]) {
    debug_assert_eq!(out.len(), row.len());
    let n = out.len();
    let body = n / 4 * 4;
    let mut i = 0;
    while i < body {
        let o = _mm256_loadu_pd(out.as_ptr().add(i));
        let v = _mm256_loadu_pd(row.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_add_pd(o, v));
        i += 4;
    }
    for j in body..n {
        out[j] += row[j];
    }
}

/// `out[j] += row[j] as f64` — `_mm256_cvtps_pd` widens exactly like the
/// scalar `as f64` cast (f32→f64 is lossless), so this stays bitwise.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn accum_row_f32(out: &mut [f64], row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    let n = out.len();
    let body = n / 4 * 4;
    let mut i = 0;
    while i < body {
        let o = _mm256_loadu_pd(out.as_ptr().add(i));
        let v = _mm256_cvtps_pd(_mm_loadu_ps(row.as_ptr().add(i)));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_add_pd(o, v));
        i += 4;
    }
    for j in body..n {
        out[j] += row[j] as f64;
    }
}

/// Sequential widening dot: the widen+multiply stage is vectorized (four
/// exact `f64` products per step), but the running sum adds the lane
/// products in ascending index order — bitwise-identical to
/// [`super::fallback::dot_seq_f32`], preserving the denominator contract.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_seq_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let body = n / 4 * 4;
    let mut acc = 0.0f64;
    let mut prod = [0.0f64; 4];
    let mut i = 0;
    while i < body {
        let va = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i)));
        let vb = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i)));
        _mm256_storeu_pd(prod.as_mut_ptr(), _mm256_mul_pd(va, vb));
        acc += prod[0];
        acc += prod[1];
        acc += prod[2];
        acc += prod[3];
        i += 4;
    }
    for j in body..n {
        acc += a[j] as f64 * b[j] as f64;
    }
    acc
}

/// Feature-map finish on `f32` storage. The widen, subtract, scale, and
/// narrow stages are vectorized in `f64`; `exp` itself stays the scalar
/// libm call per lane (a vector polynomial `exp` could not match libm
/// bitwise). `_mm256_cvtpd_ps` narrows exactly like `as f32`.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn feature_finish_f32(row: &mut [f32], a: f64, sqrt_w: &[f64]) {
    debug_assert_eq!(row.len(), sqrt_w.len());
    let n = row.len();
    let body = n / 4 * 4;
    let av = _mm256_set1_pd(a);
    let mut tmp = [0.0f64; 4];
    let mut i = 0;
    while i < body {
        let v = _mm256_cvtps_pd(_mm_loadu_ps(row.as_ptr().add(i)));
        _mm256_storeu_pd(tmp.as_mut_ptr(), _mm256_sub_pd(v, av));
        tmp[0] = tmp[0].exp();
        tmp[1] = tmp[1].exp();
        tmp[2] = tmp[2].exp();
        tmp[3] = tmp[3].exp();
        let e = _mm256_loadu_pd(tmp.as_ptr());
        let w = _mm256_loadu_pd(sqrt_w.as_ptr().add(i));
        let narrowed = _mm256_cvtpd_ps(_mm256_mul_pd(e, w));
        _mm_storeu_ps(row.as_mut_ptr().add(i), narrowed);
        i += 4;
    }
    for j in body..n {
        row[j] = ((row[j] as f64 - a).exp() * sqrt_w[j]) as f32;
    }
}
