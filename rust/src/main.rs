//! `darkformer` — launcher CLI for the DARKFormer reproduction.
//!
//! Commands (see README for a walkthrough):
//!
//! ```text
//! darkformer train      [--config cfg.toml] [--variant V] [--steps N] ...
//! darkformer eval       --ckpt path [--variant V] ...
//! darkformer exp fig1|fig2|fig3|fig4|fig5|variance|approx|sigma [...]
//! darkformer data corpus|tokenizer [...]
//! darkformer info       [--artifacts DIR]
//! ```
//!
//! Python never runs here: all compute comes from `artifacts/*.hlo.txt`
//! (built once by `make artifacts`) executed through PJRT.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use darkformer::cli::Args;
use darkformer::config::{ExperimentConfig, TrainMode};
use darkformer::coordinator::experiments::{self, ExpContext};
use darkformer::coordinator::{Trainer, Workbench};
use darkformer::data::{CorpusGenerator, CorpusSpec};
use darkformer::runtime::{Manifest, ModelMeta};
use darkformer::tokenizer::BpeTrainer;

const USAGE: &str = "\
darkformer — Data-Aware Random Feature Kernel transformer (paper reproduction)

USAGE:
  darkformer train   [--config FILE] [--model CFG] [--variant V] [--steps N]
                     [--lr F] [--clip F] [--mode full|qkv] [--seed N]
                     [--ckpt FILE] [--out DIR] [--eval-every N] [--docs N]
  darkformer eval    --ckpt FILE [--model CFG] [--variant V] [--out DIR]
  darkformer exp     fig1|fig2|fig3|fig4|fig5|variance|approx|sigma  [options]
  darkformer data    corpus --out FILE [--docs N] [--seed N]
  darkformer data    tokenizer --corpus FILE --out FILE [--vocab N]
  darkformer info    [--artifacts DIR] [--model CFG]

Common exp options: --model CFG --artifacts DIR --out DIR --seed N
  fig2:   --phase pretrain|finetune|both --steps N --pretrain-steps N --lr F
  fig3/4: --steps N --pretrain-steps N --lr F
  fig5:   --steps N --pretrain-steps N --lrs a,b,c,...
  fig1:   --seq-lens a,b,c --reps N
  variance: --dim N --m N --eps-grid a,b,c
  approx:   --dim N --m-grid a,b,c --eps F
  sigma:    --ckpt FILE   (learned Sigma geometry of a DARKFormer ckpt)
";

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        eprintln!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    match dispatch(raw) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let command = raw[0].clone();
    let rest = raw[1..].to_vec();
    match command.as_str() {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "exp" => cmd_exp(rest),
        "data" => cmd_data(rest),
        "info" => cmd_info(rest),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

const TRAIN_FLAGS: &[&str] = &[
    "config", "model", "variant", "steps", "lr", "clip", "mode", "seed",
    "ckpt", "out", "eval-every", "ckpt-every", "docs", "artifacts",
];

fn train_config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(&PathBuf::from(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get("model") {
        cfg.model_config = v.into();
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = v.into();
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    cfg.steps = args.u64_or("steps", cfg.steps)?;
    cfg.base_lr = args.f64_or("lr", cfg.base_lr)?;
    cfg.clip = args.f64_or("clip", cfg.clip)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every)?;
    cfg.checkpoint_every = args.u64_or("ckpt-every", cfg.checkpoint_every)?;
    cfg.corpus_docs = args.usize_or("docs", cfg.corpus_docs)?;
    if let Some(v) = args.get("mode") {
        cfg.mode = match v {
            "full" => TrainMode::Full,
            "qkv" => TrainMode::QkvOnly,
            _ => bail!("--mode must be full or qkv"),
        };
    }
    if let Some(v) = args.get("ckpt") {
        cfg.init_checkpoint = Some(v.into());
    }
    if let Some(v) = args.get("out") {
        cfg.out_dir = v.into();
    }
    Ok(cfg)
}

fn cmd_train(rest: Vec<String>) -> Result<()> {
    let args = Args::parse(rest, TRAIN_FLAGS)?;
    let cfg = train_config_from_args(&args)?;
    let wb = Workbench::prepare(
        &cfg.artifacts_dir,
        &cfg.model_config,
        cfg.corpus_docs,
        cfg.seed,
        &cfg.out_dir.join("_cache"),
    )?;
    let trainer = Trainer::new(cfg.clone(), &wb)?;
    eprintln!(
        "platform={} model={} variant={} steps={}",
        trainer.platform(),
        cfg.model_config,
        cfg.variant,
        cfg.steps
    );
    let report = trainer.run()?;
    println!(
        "final: loss={:.4} acc={:.4} tail_acc={:.4} spikes={} ms/step={:.1}",
        report.final_loss,
        report.final_acc,
        report.tail_acc,
        report.spike_events,
        report.mean_step_ms
    );
    println!("metrics: {}", report.metrics_path.display());
    println!("checkpoint: {}", report.checkpoint_path.display());
    Ok(())
}

fn cmd_eval(rest: Vec<String>) -> Result<()> {
    let args = Args::parse(rest, TRAIN_FLAGS)?;
    let mut cfg = train_config_from_args(&args)?;
    let ckpt = cfg
        .init_checkpoint
        .clone()
        .context("eval requires --ckpt")?;
    cfg.out_dir = args.str_or("out", "runs/eval").into();
    let wb = Workbench::prepare(
        &cfg.artifacts_dir,
        &cfg.model_config,
        cfg.corpus_docs,
        cfg.seed,
        &cfg.out_dir.join("_cache"),
    )?;
    let trainer = Trainer::new(cfg.clone(), &wb)?;
    let state = trainer.initial_state()?;
    let (loss, acc) = trainer.evaluate(&state, 16)?;
    println!(
        "eval {} ({}): loss={loss:.4} acc={acc:.4}",
        ckpt.display(),
        cfg.variant
    );
    Ok(())
}

const EXP_FLAGS: &[&str] = &[
    "model", "artifacts", "out", "seed", "docs", "steps", "pretrain-steps",
    "lr", "lrs", "phase", "variants", "dim", "m", "m-grid", "eps",
    "eps-grid", "seq-lens", "reps", "ckpt",
];

fn cmd_exp(rest: Vec<String>) -> Result<()> {
    if rest.is_empty() {
        bail!("exp requires a figure id\n\n{USAGE}");
    }
    let which = rest[0].clone();
    let args = Args::parse(rest[1..].to_vec(), EXP_FLAGS)?;
    let ctx = ExpContext {
        artifacts_dir: args.str_or("artifacts", "artifacts").into(),
        model_config: args.str_or("model", "small"),
        out_root: args.str_or("out", "runs/exp").into(),
        seed: args.u64_or("seed", 42)?,
        corpus_docs: args.usize_or("docs", 2000)?,
    };
    match which.as_str() {
        "fig1" => {
            let seq_lens: Vec<usize> = args
                .f64_list_or("seq-lens", &[64.0, 128.0, 256.0, 512.0])?
                .into_iter()
                .map(|v| v as usize)
                .collect();
            let reps = args.usize_or("reps", 5)?;
            experiments::fig1_scaling(&ctx, &seq_lens, reps)?;
        }
        "fig2" => {
            let phase = args.str_or("phase", "both");
            let steps = args.u64_or("steps", 200)?;
            let pre = args.u64_or("pretrain-steps", 300)?;
            let lr = args.f64_or("lr", 1e-3)?;
            let variant_names =
                args.str_list_or("variants", experiments::FIG2_VARIANTS);
            let variants: Vec<&str> =
                variant_names.iter().map(String::as_str).collect();
            if phase == "pretrain" || phase == "both" {
                experiments::fig2_pretrain(&ctx, &variants, steps, 3e-3)?;
            }
            if phase == "finetune" || phase == "both" {
                experiments::fig2_finetune(&ctx, &variants, pre, steps, lr)?;
            }
        }
        "fig3" => {
            let steps = args.u64_or("steps", 600)?;
            let pre = args.u64_or("pretrain-steps", 300)?;
            let lr = args.f64_or("lr", 1e-3)?;
            experiments::fig3_long_finetune(&ctx, pre, steps, lr)?;
        }
        "fig4" => {
            let steps = args.u64_or("steps", 400)?;
            let pre = args.u64_or("pretrain-steps", 300)?;
            let lr = args.f64_or("lr", 1e-3)?;
            experiments::fig4_qkv_finetune(&ctx, pre, steps, lr)?;
        }
        "fig5" => {
            let steps = args.u64_or("steps", 120)?;
            let pre = args.u64_or("pretrain-steps", 300)?;
            let lrs = args.f64_list_or(
                "lrs",
                &[1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1],
            )?;
            experiments::fig5_lr_sweep(&ctx, pre, steps, &lrs)?;
        }
        "variance" => {
            let d = args.usize_or("dim", 8)?;
            let m = args.usize_or("m", 16)?;
            let eps =
                args.f64_list_or("eps-grid", &[0.0, 0.2, 0.4, 0.6, 0.8])?;
            let (diag_err, off_err) =
                experiments::sigma_star_isotropy_check(d);
            eprintln!(
                "Sigma* isotropy check (Thm 3.2.1): diag err {diag_err:.2e}, off-diag err {off_err:.2e}"
            );
            experiments::variance_table(&ctx.out_root, d, m, &eps, ctx.seed)?;
        }
        "approx" => {
            let d = args.usize_or("dim", 8)?;
            let m_grid: Vec<usize> = args
                .f64_list_or("m-grid", &[4.0, 8.0, 16.0, 32.0, 64.0, 128.0])?
                .into_iter()
                .map(|v| v as usize)
                .collect();
            let eps = args.f64_or("eps", 0.8)?;
            experiments::approx_table(&ctx.out_root, d, &m_grid, eps, ctx.seed)?;
        }
        "sigma" => {
            let ckpt = args
                .get("ckpt")
                .context("exp sigma requires --ckpt <darkformer checkpoint>")?;
            experiments::sigma_report(
                std::path::Path::new(ckpt),
                Some(&ctx.out_root.join("sigma.csv")),
            )?;
        }
        other => bail!("unknown experiment {other:?}\n\n{USAGE}"),
    }
    Ok(())
}

fn cmd_data(rest: Vec<String>) -> Result<()> {
    if rest.is_empty() {
        bail!("data requires corpus|tokenizer\n\n{USAGE}");
    }
    let which = rest[0].clone();
    let args = Args::parse(
        rest[1..].to_vec(),
        &["out", "docs", "seed", "corpus", "vocab"],
    )?;
    match which.as_str() {
        "corpus" => {
            let out = PathBuf::from(
                args.get("out").context("corpus requires --out")?,
            );
            let docs = args.usize_or("docs", 2000)?;
            let seed = args.u64_or("seed", 42)?;
            let mut gen = CorpusGenerator::new(CorpusSpec::default(), seed);
            let text = gen.documents(docs);
            if let Some(p) = out.parent() {
                std::fs::create_dir_all(p)?;
            }
            std::fs::write(&out, &text)?;
            println!(
                "wrote {docs} documents ({} bytes) to {}",
                text.len(),
                out.display()
            );
        }
        "tokenizer" => {
            let corpus = PathBuf::from(
                args.get("corpus").context("tokenizer requires --corpus")?,
            );
            let out = PathBuf::from(
                args.get("out").context("tokenizer requires --out")?,
            );
            let vocab = args.usize_or("vocab", 1024)?;
            let text = std::fs::read_to_string(&corpus)?;
            let bpe = BpeTrainer::new(vocab).train(text.as_bytes())?;
            bpe.save(&out)?;
            println!(
                "trained BPE vocab {} (requested {vocab}) -> {}",
                bpe.vocab_size(),
                out.display()
            );
        }
        other => bail!("unknown data subcommand {other:?}"),
    }
    Ok(())
}

fn cmd_info(rest: Vec<String>) -> Result<()> {
    let args = Args::parse(rest, &["artifacts", "model"])?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let model = args.str_or("model", "tiny");
    let meta = ModelMeta::load(&artifacts.join(&model).join("meta.json"))?;
    println!(
        "model {}: vocab={} d_model={} layers={} heads={} head_dim={} seq={} batch={} m={} r={}",
        meta.name,
        meta.vocab_size,
        meta.d_model,
        meta.n_layers,
        meta.n_heads,
        meta.head_dim,
        meta.seq_len,
        meta.batch_size,
        meta.m_features,
        meta.r_proj
    );
    for variant in &meta.variants {
        let dir = artifacts.join(&model).join(variant);
        match Manifest::load(&dir.join("manifest.json")) {
            Ok(m) => println!(
                "  {variant:<12} params={} ({} elements) programs={:?}",
                m.n_params(),
                m.total_elements(),
                m.programs
            ),
            Err(_) => println!("  {variant:<12} (not built)"),
        }
    }
    Ok(())
}
