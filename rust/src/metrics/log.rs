//! JSONL metric logging: one object per training step, append-only, so
//! experiment harnesses can re-plot curves without re-running.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::ser::{parse, Json};

/// One training-step record.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub acc: f64,
    pub lr: f64,
    pub grad_norm: f64,
    pub wall_ms: f64,
}

impl StepRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\"step\":{},\"loss\":{},\"acc\":{},\"lr\":{},\"grad_norm\":{},\"wall_ms\":{}}}",
            self.step,
            fmt_f64(self.loss),
            fmt_f64(self.acc),
            fmt_f64(self.lr),
            fmt_f64(self.grad_norm),
            fmt_f64(self.wall_ms)
        )
    }

    fn from_json(v: &Json) -> Option<Self> {
        Some(Self {
            step: v.field("step")?.as_f64()? as u64,
            loss: v.field("loss")?.as_f64()?,
            acc: v.field("acc")?.as_f64()?,
            lr: v.field("lr")?.as_f64()?,
            grad_norm: v.field("grad_norm")?.as_f64()?,
            wall_ms: v.field("wall_ms")?.as_f64()?,
        })
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "\"nan\"".to_string()
    } else if v > 0.0 {
        "\"inf\"".to_string()
    } else {
        "\"-inf\"".to_string()
    }
}

fn parse_f64(v: &Json) -> Option<f64> {
    match v {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "nan" => Some(f64::NAN),
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            _ => None,
        },
        _ => None,
    }
}

/// Append-only JSONL writer for step records.
pub struct MetricLogger {
    writer: BufWriter<std::fs::File>,
}

impl MetricLogger {
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(Self { writer: BufWriter::new(file) })
    }

    pub fn log(&mut self, record: &StepRecord) -> Result<()> {
        writeln!(self.writer, "{}", record.to_json())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }

    /// Read all records back from a JSONL file. Non-finite values encoded
    /// as strings ("nan"/"inf") are restored.
    pub fn read_all(path: &Path) -> Result<Vec<StepRecord>> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut out = Vec::new();
        for line in BufReader::new(file).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let v = parse(&line)
                .with_context(|| format!("bad metric line: {line}"))?;
            let rec = StepRecord {
                step: v.field("step").and_then(|x| x.as_f64()).unwrap_or(0.0)
                    as u64,
                loss: v.field("loss").and_then(parse_f64).unwrap_or(f64::NAN),
                acc: v.field("acc").and_then(parse_f64).unwrap_or(f64::NAN),
                lr: v.field("lr").and_then(parse_f64).unwrap_or(0.0),
                grad_norm: v
                    .field("grad_norm")
                    .and_then(parse_f64)
                    .unwrap_or(f64::NAN),
                wall_ms: v.field("wall_ms").and_then(parse_f64).unwrap_or(0.0),
            };
            out.push(rec);
        }
        Ok(out)
    }
}

// Suppress unused warning for the structured parse helper used in tests.
#[allow(dead_code)]
fn _from_json_used(v: &Json) -> Option<StepRecord> {
    StepRecord::from_json(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dkf_metrics_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn log_and_read_round_trip() {
        let path = tmp("rt.jsonl");
        let mut logger = MetricLogger::create(&path).unwrap();
        let recs: Vec<StepRecord> = (0..5)
            .map(|i| StepRecord {
                step: i,
                loss: 5.0 - i as f64 * 0.3,
                acc: 0.1 * i as f64,
                lr: 1e-3,
                grad_norm: 1.5,
                wall_ms: 12.5,
            })
            .collect();
        for r in &recs {
            logger.log(r).unwrap();
        }
        logger.flush().unwrap();
        let loaded = MetricLogger::read_all(&path).unwrap();
        assert_eq!(loaded, recs);
    }

    #[test]
    fn non_finite_losses_survive() {
        let path = tmp("nan.jsonl");
        let mut logger = MetricLogger::create(&path).unwrap();
        logger
            .log(&StepRecord {
                step: 1,
                loss: f64::NAN,
                acc: 0.0,
                lr: 1.0,
                grad_norm: f64::INFINITY,
                wall_ms: 1.0,
            })
            .unwrap();
        logger.flush().unwrap();
        let loaded = MetricLogger::read_all(&path).unwrap();
        assert!(loaded[0].loss.is_nan());
        assert!(loaded[0].grad_norm.is_infinite());
    }

    #[test]
    fn skips_blank_lines() {
        let path = tmp("blank.jsonl");
        std::fs::write(
            &path,
            "\n{\"step\":1,\"loss\":2.0,\"acc\":0.5,\"lr\":0.1,\"grad_norm\":1.0,\"wall_ms\":3.0}\n\n",
        )
        .unwrap();
        let loaded = MetricLogger::read_all(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].step, 1);
    }
}
