//! Metrics substrate: JSONL step logs, moving statistics, and the
//! loss-spike detector behind the Fig. 5 stability analysis.

mod log;
mod stats;

pub use log::{MetricLogger, StepRecord};
pub use stats::{Ema, SpikeDetector, Summary};
