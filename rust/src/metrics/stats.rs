//! Streaming statistics: EMA, summary moments, and the loss-spike
//! detector that quantifies Fig. 5's training-stability comparison.

/// Exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// `alpha` is the update weight of the *new* observation.
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

/// Welford online mean/variance + extremes.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn update(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Loss-spike detector.
///
/// A *spike* is a step whose loss exceeds the trailing EMA by more than
/// `threshold` (relative), or is non-finite — the paper's Fig. 5
/// "instability phases". Consecutive spiking steps count as one event.
#[derive(Debug, Clone)]
pub struct SpikeDetector {
    ema: Ema,
    threshold: f64,
    in_spike: bool,
    events: usize,
    spiking_steps: usize,
    total_steps: usize,
}

impl SpikeDetector {
    pub fn new(ema_alpha: f64, threshold: f64) -> Self {
        Self {
            ema: Ema::new(ema_alpha),
            threshold,
            in_spike: false,
            events: 0,
            spiking_steps: 0,
            total_steps: 0,
        }
    }

    /// Feed one loss value; returns whether this step is spiking.
    pub fn observe(&mut self, loss: f64) -> bool {
        self.total_steps += 1;
        let baseline = self.ema.value();
        let spiking = match baseline {
            _ if !loss.is_finite() => true,
            None => false,
            Some(b) => loss > b * (1.0 + self.threshold),
        };
        if spiking {
            self.spiking_steps += 1;
            if !self.in_spike {
                self.events += 1;
            }
        } else {
            // Only track baseline on non-spiking steps so a long spike
            // does not get absorbed into the baseline.
            if loss.is_finite() {
                self.ema.update(loss);
            }
        }
        self.in_spike = spiking;
        spiking
    }

    /// Number of distinct spike events.
    pub fn events(&self) -> usize {
        self.events
    }

    /// Total steps flagged as spiking.
    pub fn spiking_steps(&self) -> usize {
        self.spiking_steps
    }

    /// Fraction of steps spent in spikes.
    pub fn spike_fraction(&self) -> f64 {
        if self.total_steps == 0 {
            0.0
        } else {
            self.spiking_steps as f64 / self.total_steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges_to_constant() {
        let mut ema = Ema::new(0.2);
        for _ in 0..200 {
            ema.update(3.0);
        }
        assert!((ema.value().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ema_first_value_passthrough() {
        let mut ema = Ema::new(0.1);
        assert_eq!(ema.update(5.0), 5.0);
    }

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.update(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 16.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn detects_single_spike_event() {
        let mut det = SpikeDetector::new(0.3, 0.5);
        for _ in 0..20 {
            det.observe(2.0);
        }
        det.observe(10.0);
        det.observe(9.0);
        for _ in 0..10 {
            det.observe(2.0);
        }
        assert_eq!(det.events(), 1);
        assert_eq!(det.spiking_steps(), 2);
    }

    #[test]
    fn counts_separate_events() {
        let mut det = SpikeDetector::new(0.3, 0.5);
        for _ in 0..10 {
            det.observe(1.0);
        }
        det.observe(5.0);
        for _ in 0..5 {
            det.observe(1.0);
        }
        det.observe(6.0);
        for _ in 0..5 {
            det.observe(1.0);
        }
        assert_eq!(det.events(), 2);
    }

    #[test]
    fn nan_counts_as_spike() {
        let mut det = SpikeDetector::new(0.3, 0.5);
        for _ in 0..5 {
            det.observe(1.0);
        }
        assert!(det.observe(f64::NAN));
        assert_eq!(det.events(), 1);
    }

    #[test]
    fn smooth_decreasing_loss_never_spikes() {
        let mut det = SpikeDetector::new(0.2, 0.5);
        let mut loss = 6.0;
        for _ in 0..500 {
            assert!(!det.observe(loss));
            loss *= 0.995;
        }
        assert_eq!(det.events(), 0);
        assert_eq!(det.spike_fraction(), 0.0);
    }
}
