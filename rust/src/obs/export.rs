//! Exporters: Prometheus text exposition and a flat JSON metric
//! snapshot matching the `BENCH_*.json` conventions.
//!
//! Both walk the [`Registry`] in registration order, so for a fixed
//! metric vocabulary the output is byte-stable — the exporter golden
//! test in `rust/tests/rfa_obs.rs` pins the exact format.

use crate::ser::{Json, JsonObj};

use super::registry::Registry;

/// Render `v` the way Prometheus text exposition expects: shortest
/// round-trip decimal (Rust's `Display` for f64), `+Inf`/`-Inf`/`NaN`
/// spelled out.
fn num(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Prometheus text exposition (format version 0.0.4) of every metric in
/// the registry: counters, then gauges (label families kept contiguous),
/// then histograms with cumulative `_bucket{le=…}` series plus `_sum`
/// and `_count`.
pub fn prometheus_text(registry: &Registry) -> String {
    let mut out = String::new();
    for c in registry.counters() {
        out.push_str(&format!("# HELP {} {}\n", c.name(), c.help()));
        out.push_str(&format!("# TYPE {} counter\n", c.name()));
        out.push_str(&format!("{} {}\n", c.name(), c.get()));
    }
    // Gauges of one family must be contiguous in the exposition; emit
    // each family at its first appearance in registration order.
    let gauges = registry.gauges();
    let mut emitted: Vec<&str> = Vec::new();
    for g in &gauges {
        if emitted.contains(&g.name()) {
            continue;
        }
        emitted.push(g.name());
        out.push_str(&format!("# HELP {} {}\n", g.name(), g.help()));
        out.push_str(&format!("# TYPE {} gauge\n", g.name()));
        for member in gauges.iter().filter(|m| m.name() == g.name()) {
            if member.labels().is_empty() {
                out.push_str(&format!(
                    "{} {}\n",
                    member.name(),
                    num(member.get())
                ));
            } else {
                out.push_str(&format!(
                    "{}{{{}}} {}\n",
                    member.name(),
                    member.labels(),
                    num(member.get())
                ));
            }
        }
    }
    for h in registry.histograms() {
        out.push_str(&format!("# HELP {} {}\n", h.name(), h.help()));
        out.push_str(&format!("# TYPE {} histogram\n", h.name()));
        let counts = h.bucket_counts();
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            let le = if i < h.bounds().len() {
                num(h.bounds()[i])
            } else {
                "+Inf".to_string()
            };
            out.push_str(&format!(
                "{}_bucket{{le=\"{le}\"}} {cum}\n",
                h.name()
            ));
        }
        out.push_str(&format!("{}_sum {}\n", h.name(), num(h.sum())));
        out.push_str(&format!("{}_count {}\n", h.name(), h.count()));
    }
    out
}

/// Flat metric map in the `BENCH_*.json` convention:
/// `{"suite": <name>, "metrics": {<key>: <number>, …}}`. Counters and
/// gauges export under their metric name (labeled gauges as
/// `name{labels}`); each histogram contributes `_count`, `_sum`,
/// `_p50` and `_p99` entries.
pub fn json_snapshot(suite: &str, registry: &Registry) -> Json {
    let mut metrics = JsonObj::new();
    for c in registry.counters() {
        metrics.insert(c.name(), Json::Num(c.get() as f64));
    }
    for g in registry.gauges() {
        let key = if g.labels().is_empty() {
            g.name().to_string()
        } else {
            format!("{}{{{}}}", g.name(), g.labels())
        };
        metrics.insert(key, Json::Num(g.get()));
    }
    for h in registry.histograms() {
        metrics
            .insert(format!("{}_count", h.name()), Json::Num(h.count() as f64));
        metrics.insert(format!("{}_sum", h.name()), Json::Num(h.sum()));
        metrics
            .insert(format!("{}_p50", h.name()), Json::Num(h.quantile(0.5)));
        metrics
            .insert(format!("{}_p99", h.name()), Json::Num(h.quantile(0.99)));
    }
    let mut root = JsonObj::new();
    root.insert("suite", Json::Str(suite.to_string()));
    root.insert("metrics", Json::Obj(metrics));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_snapshot_shape() {
        let reg = Registry::new();
        reg.counter("a_total", "a").add(2);
        reg.gauge_labeled("g", "k=\"1\"", "g").set(0.5);
        reg.histogram("h_ms", "h", &[1.0]).observe(0.25);
        let json = json_snapshot("obs", &reg);
        let metrics = json.field("metrics").unwrap();
        assert_eq!(metrics.field("a_total").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            metrics.field("g{k=\"1\"}").unwrap().as_f64(),
            Some(0.5)
        );
        assert_eq!(metrics.field("h_ms_count").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.field("suite").unwrap().as_str(), Some("obs"));
    }
}
