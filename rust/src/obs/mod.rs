//! Zero-dependency observability for the serving stack: counters,
//! gauges, fixed-bucket histograms, scoped span timers, a bounded
//! structured event ring, and Prometheus/JSON exporters.
//!
//! The paper's core claim is statistical — data-aligned importance
//! sampling cuts Monte-Carlo variance when queries/keys are anisotropic
//! — and this module is how a running server *sees* it: per-head
//! effective sample size of the importance weights, a Σ̂ anisotropy
//! proxy, resample-epoch cadence ([`serve::ServeObs`]), alongside the
//! latency and fault signals (tick/forward/snapshot-IO spans,
//! eviction/restore churn, quarantine transitions) a deployment needs.
//!
//! # The write-only rule
//!
//! Observability is **write-only from the hot path**:
//!
//! * no control flow anywhere reads a metric, gauge, or the event ring
//!   — telemetry influences nothing;
//! * wall-clock time appears only *inside* telemetry values (span
//!   timers), never in any decision;
//! * a run with obs at maximum verbosity is bitwise-identical in its
//!   outputs to a run with obs disabled.
//!
//! This extends the `rfa::serve` determinism contract; see
//! "Observability and the determinism contract" in
//! [`crate::rfa::serve`] and the pins in `rust/tests/rfa_obs.rs`.
//!
//! # Verbosity levels
//!
//! [`ObsLevel`] has three settings, read from `RFA_OBS` by default:
//!
//! * `Off` — counters only (they back [`crate::rfa::serve`]'s
//!   `PoolStats`/`HealthReport` views and cost one relaxed `fetch_add`
//!   per event); no clock reads, no histograms, no gauges, no ring.
//! * `Basic` (default) — adds span timers, histograms, and the
//!   pool/kernel-quality gauges.
//! * `Full` — adds the structured [`ring::EventRing`].
//!
//! Events, gauge updates and registrations happen only on serial
//! pool/scheduler paths; worker threads touch nothing but sharded
//! counter cells — that is what makes every exported artifact
//! thread-count-invariant for deterministic quantities.

pub mod export;
pub mod registry;
pub mod ring;
pub mod serve;

pub use export::{json_snapshot, prometheus_text};
pub use registry::{Counter, Gauge, Histogram, Registry, Span};
pub use ring::{Event, EventKind, EventRing};
pub use serve::ServeObs;

/// Verbosity of the observability layer. Ordered: each level is a
/// superset of the previous one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsLevel {
    /// Counters only — the always-on substrate behind `PoolStats` and
    /// `HealthReport`. No clock reads.
    Off,
    /// Plus span timers, histograms and gauges.
    Basic,
    /// Plus the structured event ring.
    Full,
}

impl ObsLevel {
    /// Parse the `RFA_OBS` environment variable:
    /// `off`/`0`/`none` → `Off`, `full`/`2` → `Full`, anything else
    /// (including unset) → `Basic`.
    pub fn from_env() -> Self {
        match std::env::var("RFA_OBS") {
            Ok(v) => match v.to_ascii_lowercase().as_str() {
                "off" | "0" | "none" => ObsLevel::Off,
                "full" | "2" => ObsLevel::Full,
                _ => ObsLevel::Basic,
            },
            Err(_) => ObsLevel::Basic,
        }
    }
}

/// Observability configuration, fixed at pool construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsConfig {
    pub level: ObsLevel,
    /// Event-ring capacity (drop-oldest beyond it); only allocated at
    /// [`ObsLevel::Full`].
    pub ring_capacity: usize,
}

impl ObsConfig {
    pub const DEFAULT_RING_CAPACITY: usize = 1024;

    /// Level from `RFA_OBS`, default ring capacity — what
    /// `SessionPool::new`/`with_store` use.
    pub fn from_env() -> Self {
        Self::at(ObsLevel::from_env())
    }

    pub fn at(level: ObsLevel) -> Self {
        Self { level, ring_capacity: Self::DEFAULT_RING_CAPACITY }
    }

    /// Counters-only mode (the disabled arm of the bitwise tests).
    pub fn off() -> Self {
        Self::at(ObsLevel::Off)
    }

    /// Maximum verbosity: timers, histograms, gauges and the event ring.
    pub fn full() -> Self {
        Self::at(ObsLevel::Full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(ObsLevel::Off < ObsLevel::Basic);
        assert!(ObsLevel::Basic < ObsLevel::Full);
    }

    #[test]
    fn config_constructors() {
        assert_eq!(ObsConfig::off().level, ObsLevel::Off);
        assert_eq!(ObsConfig::full().level, ObsLevel::Full);
        assert_eq!(
            ObsConfig::full().ring_capacity,
            ObsConfig::DEFAULT_RING_CAPACITY
        );
    }
}
