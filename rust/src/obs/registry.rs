//! Metric primitives and the registry that owns them.
//!
//! Three metric kinds, all lock-free on the write path:
//!
//! * [`Counter`] — monotone u64, sharded across cache-line-padded atomic
//!   cells so concurrent workers never contend on one line; one relaxed
//!   `fetch_add` per event.
//! * [`Gauge`] — a single f64 stored as atomic bits; last write wins.
//! * [`Histogram`] — fixed bucket bounds chosen at registration, one
//!   atomic bucket increment plus a count/sum update per observation,
//!   and bucket-interpolated quantiles ([`Histogram::quantile`]) for
//!   p50/p99 readouts.
//!
//! The [`Registry`] hands out `Arc` handles, deduplicated by name (and
//! labels, for gauges), and remembers registration order — exporters
//! iterate that order, so two runs that register metrics in the same
//! order export byte-identical text. Registration takes a mutex and is
//! meant for setup/serial paths; the hot path only touches the handles.
//!
//! [`Span`] is the scoped wall-clock timer: it reads `Instant::now()`
//! only when constructed enabled, and records elapsed milliseconds into
//! its histogram on drop. Wall-clock therefore appears *inside* metric
//! values and nowhere else — the write-only rule of the serve-layer
//! determinism contract.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Counter shard count; power of two so the thread-id fold is a mask.
const SHARDS: usize = 8;

/// One counter cell on its own cache line (no false sharing between
/// shards of the same counter or neighbouring counters).
#[repr(align(64))]
struct CacheCell(AtomicU64);

impl CacheCell {
    fn zero() -> Self {
        Self(AtomicU64::new(0))
    }
}

static NEXT_THREAD_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// This thread's counter shard: assigned round-robin on first use so
/// worker pools spread across shards regardless of OS thread ids.
fn shard_index() -> usize {
    THREAD_SHARD.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT_THREAD_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
        s.set(v);
        v
    })
}

/// Add `v` to an f64 stored as atomic bits (CAS loop; used for histogram
/// sums, which are observed at tick rate, not per-row rate).
fn add_f64_bits(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(
            cur,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Monotone event counter, sharded per thread.
pub struct Counter {
    name: String,
    help: String,
    shards: [CacheCell; SHARDS],
}

impl Counter {
    fn new(name: String, help: String) -> Self {
        Self {
            name,
            help,
            shards: std::array::from_fn(|_| CacheCell::zero()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn help(&self) -> &str {
        &self.help
    }

    /// One relaxed `fetch_add` on this thread's shard.
    pub fn add(&self, n: u64) {
        self.shards[shard_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Sum across shards (reader-side; not a hot path).
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

/// Last-write-wins f64 gauge, optionally labeled
/// (`name{labels}` in the Prometheus exposition).
pub struct Gauge {
    name: String,
    labels: String,
    help: String,
    bits: AtomicU64,
}

impl Gauge {
    fn new(name: String, labels: String, help: String) -> Self {
        Self { name, labels, help, bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Label pairs as rendered between braces (empty = unlabeled).
    pub fn labels(&self) -> &str {
        &self.labels
    }

    pub fn help(&self) -> &str {
        &self.help
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: `bounds` are ascending inclusive upper bounds
/// with an implicit final +Inf bucket.
pub struct Histogram {
    name: String,
    help: String,
    bounds: Vec<f64>,
    /// `bounds.len() + 1` buckets; the last one is +Inf.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(name: String, help: String, bounds: &[f64]) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Self {
            name,
            help,
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn help(&self) -> &str {
        &self.help
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn observe(&self, v: f64) {
        let idx = self.bounds.partition_point(|&b| v > b);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        add_f64_bits(&self.sum_bits, v);
    }

    /// Per-bucket counts (not cumulative), +Inf bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Bucket-interpolated quantile `q ∈ [0, 1]`: find the bucket holding
    /// the q-th observation and interpolate linearly inside it. The +Inf
    /// bucket reports its lower bound (there is nothing to interpolate
    /// toward). Returns 0 when the histogram is empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil()).max(1.0);
        let target = (target as u64).min(total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= target {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                if i >= self.bounds.len() {
                    return lo; // +Inf bucket
                }
                let hi = self.bounds[i];
                let into = (target - (cum - c)) as f64 / c as f64;
                return lo + (hi - lo) * into;
            }
        }
        // Unreachable: cum reaches total >= target.
        self.bounds.last().copied().unwrap_or(0.0)
    }
}

/// Scoped wall-clock timer: created enabled it records elapsed
/// milliseconds into its histogram on drop; created disabled it never
/// touches the clock. See [`Registry`] module docs for the write-only
/// rule this upholds.
pub struct Span {
    armed: Option<(Arc<Histogram>, Instant)>,
}

impl Span {
    /// An armed span: reads the clock now, records on drop.
    pub fn start(hist: &Arc<Histogram>) -> Self {
        Self { armed: Some((Arc::clone(hist), Instant::now())) }
    }

    /// A disarmed span: no clock read, no record — the disabled mode's
    /// zero-cost stand-in.
    pub fn disabled() -> Self {
        Self { armed: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((hist, start)) = self.armed.take() {
            hist.observe(start.elapsed().as_secs_f64() * 1e3);
        }
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: Vec<Arc<Counter>>,
    gauges: Vec<Arc<Gauge>>,
    histograms: Vec<Arc<Histogram>>,
}

/// Owns every metric of one serving stack, in registration order.
/// Handles are deduplicated by name (gauges by name + labels), so
/// re-registration returns the existing metric — restores and re-created
/// vocabularies cannot double-count.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

fn lock(m: &Mutex<RegistryInner>) -> MutexGuard<'_, RegistryInner> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(
        &self,
        name: impl Into<String>,
        help: impl Into<String>,
    ) -> Arc<Counter> {
        let name = name.into();
        let mut inner = lock(&self.inner);
        if let Some(c) = inner.counters.iter().find(|c| c.name == name) {
            return Arc::clone(c);
        }
        let c = Arc::new(Counter::new(name, help.into()));
        inner.counters.push(Arc::clone(&c));
        c
    }

    pub fn gauge(
        &self,
        name: impl Into<String>,
        help: impl Into<String>,
    ) -> Arc<Gauge> {
        self.gauge_labeled(name, "", help)
    }

    /// A labeled gauge: `labels` is the rendered pair list, e.g.
    /// `session="3",head="0"` (empty for an unlabeled gauge).
    pub fn gauge_labeled(
        &self,
        name: impl Into<String>,
        labels: impl Into<String>,
        help: impl Into<String>,
    ) -> Arc<Gauge> {
        let (name, labels) = (name.into(), labels.into());
        let mut inner = lock(&self.inner);
        if let Some(g) = inner
            .gauges
            .iter()
            .find(|g| g.name == name && g.labels == labels)
        {
            return Arc::clone(g);
        }
        let g = Arc::new(Gauge::new(name, labels, help.into()));
        inner.gauges.push(Arc::clone(&g));
        g
    }

    pub fn histogram(
        &self,
        name: impl Into<String>,
        help: impl Into<String>,
        bounds: &[f64],
    ) -> Arc<Histogram> {
        let name = name.into();
        let mut inner = lock(&self.inner);
        if let Some(h) = inner.histograms.iter().find(|h| h.name == name) {
            return Arc::clone(h);
        }
        let h = Arc::new(Histogram::new(name, help.into(), bounds));
        inner.histograms.push(Arc::clone(&h));
        h
    }

    /// Every counter, in registration order.
    pub fn counters(&self) -> Vec<Arc<Counter>> {
        lock(&self.inner).counters.clone()
    }

    /// Every gauge, in registration order.
    pub fn gauges(&self) -> Vec<Arc<Gauge>> {
        lock(&self.inner).gauges.clone()
    }

    /// Every histogram, in registration order.
    pub fn histograms(&self) -> Vec<Arc<Histogram>> {
        lock(&self.inner).histograms.clone()
    }

    /// Current values of every gauge in a family (e.g. all
    /// `rfa_head_ess{…}` gauges), in registration order.
    pub fn gauge_family_values(&self, name: &str) -> Vec<f64> {
        lock(&self.inner)
            .gauges
            .iter()
            .filter(|g| g.name == name)
            .map(|g| g.get())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_shards() {
        let reg = Registry::new();
        let c = reg.counter("test_total", "t");
        c.add(3);
        c.inc();
        assert_eq!(c.get(), 4);
        // Re-registration returns the same counter.
        assert_eq!(reg.counter("test_total", "t").get(), 4);
        assert_eq!(reg.counters().len(), 1);
    }

    #[test]
    fn counter_concurrent_adds_are_exact() {
        let reg = Registry::new();
        let c = reg.counter("conc_total", "t");
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let reg = Registry::new();
        let h = reg.histogram("lat_ms", "t", &[1.0, 2.0, 4.0]);
        for v in [0.5, 0.5, 1.5, 3.0, 10.0] {
            h.observe(v);
        }
        assert_eq!(h.bucket_counts(), vec![2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 15.5).abs() < 1e-12);
        // p50 = 3rd of 5 observations -> the (1, 2] bucket, fully through.
        assert!((h.quantile(0.5) - 2.0).abs() < 1e-12);
        // p100 lands in the +Inf bucket -> reports its lower bound.
        assert!((h.quantile(1.0) - 4.0).abs() < 1e-12);
        assert_eq!(Registry::new().histogram("e", "t", &[1.0]).quantile(0.5), 0.0);
    }

    #[test]
    fn gauge_last_write_wins() {
        let reg = Registry::new();
        let g = reg.gauge_labeled("ess", "head=\"0\"", "t");
        g.set(12.5);
        g.set(3.25);
        assert_eq!(g.get(), 3.25);
        assert_eq!(reg.gauge_family_values("ess"), vec![3.25]);
    }

    #[test]
    fn disabled_span_records_nothing() {
        let reg = Registry::new();
        let h = reg.histogram("span_ms", "t", &[1.0]);
        {
            let _s = Span::disabled();
        }
        assert_eq!(h.count(), 0);
        {
            let _s = Span::start(&h);
        }
        assert_eq!(h.count(), 1);
    }
}
