//! Bounded structured event ring for the serving stack.
//!
//! Every state transition an operator would want on a dashboard —
//! eviction, restore, resample epoch, store fault, quarantine,
//! degraded-mode edges, orphan-unlink retries — is pushed as a typed
//! [`Event`] onto a bounded ring. The ring drops oldest on overflow
//! (counting drops, never blocking a serving path) and is drained
//! wholesale by exporters, dashboards and the determinism tests.
//!
//! Events carry **no timestamps**: they are pushed only from serial
//! scheduler/pool paths, so for a fixed workload and fault schedule the
//! drained sequence is identical across thread counts — the property
//! `rust/tests/rfa_obs.rs` pins. (`seq` is a per-ring push index, not a
//! clock.)

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Mutex, MutexGuard};

/// What happened. Payloads are the quantities an operator would filter
/// or alert on; paths are stringified store paths (pool-unique prefixes
/// and all — tests normalize them, dashboards show them raw).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// A session was written out to its snapshot to stay under budget.
    Eviction { session: u64, bytes: u64 },
    /// A session was faulted back in from its snapshot.
    Restore { session: u64, bytes: u64 },
    /// Head `head` of `session` crossed resample-epoch boundary `epoch`
    /// (froze its triple and redrew its bank).
    ResampleEpoch { session: u64, head: usize, epoch: u64 },
    /// A snapshot-store operation failed (injected or real IO error).
    StoreFault { op: &'static str, path: String },
    /// The retry policy gave up on a session after `failures`
    /// consecutive snapshot failures.
    Quarantine { session: u64, failures: u32 },
    /// An operator lifted a session's quarantine.
    Unquarantine { session: u64 },
    /// A snapshot write failed with no success since: eviction is
    /// suspended, admission control tightens.
    DegradedEnter,
    /// A snapshot write succeeded again; normal budget behavior resumes.
    DegradedExit,
    /// A previously failed snapshot unlink was retried.
    OrphanRetry { path: String, recovered: bool },
    /// Head `head` of `session` merged its oldest frozen epoch into the
    /// successor (`merges` is the head's cumulative merge count).
    Compaction { session: u64, head: usize, merges: u64 },
}

impl fmt::Display for EventKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventKind::Eviction { session, bytes } => {
                write!(f, "eviction session={session} bytes={bytes}")
            }
            EventKind::Restore { session, bytes } => {
                write!(f, "restore session={session} bytes={bytes}")
            }
            EventKind::ResampleEpoch { session, head, epoch } => write!(
                f,
                "resample-epoch session={session} head={head} epoch={epoch}"
            ),
            EventKind::StoreFault { op, path } => {
                write!(f, "store-fault op={op} path={path}")
            }
            EventKind::Quarantine { session, failures } => write!(
                f,
                "quarantine session={session} failures={failures}"
            ),
            EventKind::Unquarantine { session } => {
                write!(f, "unquarantine session={session}")
            }
            EventKind::DegradedEnter => write!(f, "degraded-enter"),
            EventKind::DegradedExit => write!(f, "degraded-exit"),
            EventKind::OrphanRetry { path, recovered } => {
                write!(f, "orphan-retry recovered={recovered} path={path}")
            }
            EventKind::Compaction { session, head, merges } => write!(
                f,
                "compaction session={session} head={head} merges={merges}"
            ),
        }
    }
}

/// One ring entry: a push-order sequence number plus the typed payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Monotone push index (gaps never occur; dropped events were the
    /// *oldest*, so surviving seqs stay contiguous at the tail).
    pub seq: u64,
    pub kind: EventKind,
}

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{} {}", self.seq, self.kind)
    }
}

#[derive(Default)]
struct RingInner {
    next_seq: u64,
    dropped: u64,
    events: VecDeque<Event>,
}

/// Bounded drop-oldest event buffer. Push is a short mutex hold on
/// serial paths only; the worker-thread hot path never touches it.
pub struct EventRing {
    capacity: usize,
    inner: Mutex<RingInner>,
}

fn lock(m: &Mutex<RingInner>) -> MutexGuard<'_, RingInner> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl EventRing {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(RingInner::default()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn push(&self, kind: EventKind) {
        let mut inner = lock(&self.inner);
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.events.len() == self.capacity {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back(Event { seq, kind });
    }

    /// Remove and return every buffered event, oldest first.
    pub fn drain(&self) -> Vec<Event> {
        lock(&self.inner).events.drain(..).collect()
    }

    /// Copy of the buffered events without consuming them.
    pub fn snapshot(&self) -> Vec<Event> {
        lock(&self.inner).events.iter().cloned().collect()
    }

    /// Buffered event count.
    pub fn len(&self) -> usize {
        lock(&self.inner).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by overflow since creation.
    pub fn dropped(&self) -> u64 {
        lock(&self.inner).dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_and_counts() {
        let ring = EventRing::new(2);
        ring.push(EventKind::DegradedEnter);
        ring.push(EventKind::DegradedExit);
        ring.push(EventKind::Unquarantine { session: 7 });
        assert_eq!(ring.dropped(), 1);
        let events = ring.drain();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].seq, 1);
        assert_eq!(events[0].kind, EventKind::DegradedExit);
        assert_eq!(events[1].seq, 2);
        assert!(ring.is_empty());
        assert_eq!(ring.dropped(), 1);
    }

    #[test]
    fn event_display_is_compact() {
        let ring = EventRing::new(4);
        ring.push(EventKind::ResampleEpoch { session: 3, head: 1, epoch: 2 });
        let shown = format!("{}", ring.snapshot()[0]);
        assert_eq!(shown, "#0 resample-epoch session=3 head=1 epoch=2");
    }
}
