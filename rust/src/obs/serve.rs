//! The serving stack's metric vocabulary, pre-registered so hot paths
//! touch handles, never name lookups — plus the kernel-quality helpers
//! tied to the paper (importance-weight ESS, Σ̂ anisotropy).
//!
//! One [`ServeObs`] is shared (via `Arc`) by a `SessionPool`, its
//! `BatchScheduler`, and every `Session` the pool owns; `pool.obs()` /
//! `scheduler.obs()` hand it out for export. All counters are live at
//! every [`ObsLevel`]; histograms/gauges require `Basic`, the event ring
//! `Full` — see the [`super`] module docs for the write-only rule all of
//! it obeys.

use std::sync::Arc;

use crate::rfa::features::FeatureBank;

use super::registry::{Counter, Gauge, Histogram, Registry, Span};
use super::ring::{Event, EventKind, EventRing};
use super::{ObsConfig, ObsLevel};

/// Latency histogram bounds in milliseconds: sub-100µs ticks through
/// multi-second outliers.
const LATENCY_BOUNDS_MS: [f64; 12] =
    [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0];

/// Batch-size histogram bounds (sessions per tick) — powers of two.
const BATCH_BOUNDS: [f64; 8] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// Request-size histogram bounds (rows per request).
const ROW_BOUNDS: [f64; 8] =
    [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 4096.0];

/// Pre-registered metric vocabulary of one serving stack.
///
/// Counter fields are public: call sites do `obs.evictions.inc()` — one
/// relaxed `fetch_add`, no map lookup. Everything level-gated goes
/// through the helper methods so the gating logic lives in one place.
pub struct ServeObs {
    level: ObsLevel,
    registry: Arc<Registry>,
    ring: EventRing,

    // --- counters (always live; back PoolStats/HealthReport) --------
    /// Sessions written out to snapshots to stay under the budget.
    pub evictions: Arc<Counter>,
    /// Sessions faulted back in from snapshots.
    pub restores: Arc<Counter>,
    /// Bytes of snapshot payload successfully written through the store.
    pub snapshot_bytes_written: Arc<Counter>,
    /// Bytes of snapshot payload successfully read through the store.
    pub snapshot_bytes_read: Arc<Counter>,
    /// Failed snapshot-store operations (plus decode failures at
    /// fault-in) — the `HealthReport::snapshot_failures` source.
    pub snapshot_failures: Arc<Counter>,
    /// Sessions the retry policy quarantined.
    pub quarantines: Arc<Counter>,
    /// Operator unquarantine calls that lifted a quarantine.
    pub unquarantines: Arc<Counter>,
    /// Retries of previously failed snapshot unlinks.
    pub orphan_retries: Arc<Counter>,
    /// Healthy→degraded transitions of the snapshot store.
    pub degraded_transitions: Arc<Counter>,
    /// Requests completed by scheduler ticks.
    pub requests_completed: Arc<Counter>,
    /// Stream rows (positions) served by scheduler ticks.
    pub rows_served: Arc<Counter>,
    /// Scheduler ticks run.
    pub ticks: Arc<Counter>,
    /// Resample-epoch boundaries crossed (bank redraws), across all
    /// sessions and heads.
    pub resample_epochs: Arc<Counter>,
    /// Rank-1 Cholesky updates folded into maintained Σ̂ factors, across
    /// all sessions and heads (one per key observation while a factor is
    /// live) — the O(d²) work that replaces per-boundary O(d³).
    pub chol_rank1_updates: Arc<Counter>,
    /// From-scratch refreshes of maintained Σ̂ factors (first boundary
    /// plus doubling-rule refactorizations).
    pub chol_refreshes: Arc<Counter>,
    /// Frozen-epoch compaction merges (oldest epoch folded into its
    /// successor), across all sessions and heads.
    pub compactions: Arc<Counter>,

    // --- gauges (Basic+) ---------------------------------------------
    pub resident_sessions: Arc<Gauge>,
    pub evicted_sessions: Arc<Gauge>,
    pub resident_bytes: Arc<Gauge>,
    pub quarantined_sessions: Arc<Gauge>,
    /// 1 while the snapshot store is degraded, else 0.
    pub degraded: Arc<Gauge>,
    pub orphaned_snapshots: Arc<Gauge>,

    // --- histograms (Basic+) ------------------------------------------
    /// Wall-clock per scheduler tick (ms).
    pub tick_ms: Arc<Histogram>,
    /// Wall-clock per tick's threaded forward fan-out (ms).
    pub forward_ms: Arc<Histogram>,
    /// Wall-clock per snapshot-store write/read (ms).
    pub snapshot_io_ms: Arc<Histogram>,
    /// Wall-clock per post-epoch kernel-quality recompute (ms) — the
    /// serial telemetry half of the resample phase (the redraw itself
    /// runs on workers, inside the forward span).
    pub resample_ms: Arc<Histogram>,
    /// Requests per tick batch (deterministic values).
    pub batch_sessions: Arc<Histogram>,
    /// Rows per completed request (deterministic values).
    pub request_rows: Arc<Histogram>,
}

impl ServeObs {
    pub fn new(cfg: ObsConfig) -> Arc<Self> {
        let reg = Arc::new(Registry::new());
        let c = |name: &str, help: &str| reg.counter(name, help);
        let g = |name: &str, help: &str| reg.gauge(name, help);
        let h = |name: &str, help: &str, bounds: &[f64]| {
            reg.histogram(name, help, bounds)
        };
        Arc::new(Self {
            level: cfg.level,
            ring: EventRing::new(cfg.ring_capacity),
            evictions: c(
                "rfa_evictions_total",
                "Sessions snapshotted out to stay under the memory budget",
            ),
            restores: c(
                "rfa_restores_total",
                "Sessions faulted back in from snapshots",
            ),
            snapshot_bytes_written: c(
                "rfa_snapshot_bytes_written_total",
                "Snapshot bytes successfully written through the store",
            ),
            snapshot_bytes_read: c(
                "rfa_snapshot_bytes_read_total",
                "Snapshot bytes successfully read through the store",
            ),
            snapshot_failures: c(
                "rfa_snapshot_failures_total",
                "Failed snapshot-store operations (incl. decode failures)",
            ),
            quarantines: c(
                "rfa_quarantines_total",
                "Sessions quarantined by the retry policy",
            ),
            unquarantines: c(
                "rfa_unquarantines_total",
                "Quarantines lifted by operator retry",
            ),
            orphan_retries: c(
                "rfa_orphan_retries_total",
                "Retries of previously failed snapshot unlinks",
            ),
            degraded_transitions: c(
                "rfa_degraded_transitions_total",
                "Healthy-to-degraded transitions of the snapshot store",
            ),
            requests_completed: c(
                "rfa_requests_completed_total",
                "Step requests completed by scheduler ticks",
            ),
            rows_served: c(
                "rfa_rows_served_total",
                "Stream rows served by scheduler ticks",
            ),
            ticks: c("rfa_ticks_total", "Scheduler ticks run"),
            resample_epochs: c(
                "rfa_resample_epochs_total",
                "Resample-epoch boundaries crossed (bank redraws)",
            ),
            chol_rank1_updates: c(
                "rfa_chol_rank1_updates_total",
                "Rank-1 updates folded into maintained Cholesky factors",
            ),
            chol_refreshes: c(
                "rfa_chol_refreshes_total",
                "From-scratch refreshes of maintained Cholesky factors",
            ),
            compactions: c(
                "rfa_compactions_total",
                "Frozen-epoch compaction merges (oldest into successor)",
            ),
            resident_sessions: g(
                "rfa_resident_sessions",
                "Sessions currently resident in memory",
            ),
            evicted_sessions: g(
                "rfa_evicted_sessions",
                "Sessions currently living as snapshots",
            ),
            resident_bytes: g(
                "rfa_resident_bytes",
                "Resident session-state bytes (the budgeted quantity)",
            ),
            quarantined_sessions: g(
                "rfa_quarantined_sessions",
                "Sessions currently quarantined",
            ),
            degraded: g(
                "rfa_degraded",
                "1 while the snapshot store is degraded, else 0",
            ),
            orphaned_snapshots: g(
                "rfa_orphaned_snapshots",
                "Snapshot files whose unlink failed, awaiting retry",
            ),
            tick_ms: h(
                "rfa_tick_ms",
                "Scheduler tick wall-clock (ms)",
                &LATENCY_BOUNDS_MS,
            ),
            forward_ms: h(
                "rfa_forward_ms",
                "Threaded forward fan-out wall-clock per tick (ms)",
                &LATENCY_BOUNDS_MS,
            ),
            snapshot_io_ms: h(
                "rfa_snapshot_io_ms",
                "Snapshot-store write/read wall-clock (ms)",
                &LATENCY_BOUNDS_MS,
            ),
            resample_ms: h(
                "rfa_resample_ms",
                "Post-epoch kernel-quality recompute wall-clock (ms)",
                &LATENCY_BOUNDS_MS,
            ),
            batch_sessions: h(
                "rfa_batch_sessions",
                "Requests per tick batch",
                &BATCH_BOUNDS,
            ),
            request_rows: h(
                "rfa_request_rows",
                "Rows per completed request",
                &ROW_BOUNDS,
            ),
            registry: reg,
        })
    }

    pub fn level(&self) -> ObsLevel {
        self.level
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Span timers and value histograms are recorded at `Basic` and up.
    pub fn timing_enabled(&self) -> bool {
        self.level >= ObsLevel::Basic
    }

    /// Pool and kernel-quality gauges are maintained at `Basic` and up.
    pub fn gauges_enabled(&self) -> bool {
        self.level >= ObsLevel::Basic
    }

    /// The structured event ring is live only at `Full`.
    pub fn ring_enabled(&self) -> bool {
        self.level >= ObsLevel::Full
    }

    /// Push a structured event (no-op below `Full`). Serial paths only.
    pub fn event(&self, kind: EventKind) {
        if self.ring_enabled() {
            self.ring.push(kind);
        }
    }

    /// A scoped wall-clock timer over `hist` — armed (one
    /// `Instant::now`) only when timing is enabled.
    pub fn span(&self, hist: &Arc<Histogram>) -> Span {
        if self.timing_enabled() {
            Span::start(hist)
        } else {
            Span::disabled()
        }
    }

    /// Record a tick's batch size (requests scheduled together).
    pub fn observe_batch(&self, sessions: usize) {
        if self.timing_enabled() {
            self.batch_sessions.observe(sessions as f64);
        }
    }

    /// Record one completed request's row count.
    pub fn observe_rows(&self, rows: usize) {
        if self.timing_enabled() {
            self.request_rows.observe(rows as f64);
        }
    }

    /// Update the four per-head kernel-quality gauges of `(session,
    /// head)`: importance-weight ESS, Σ̂ anisotropy proxy, completed
    /// resample epochs, and frozen-epoch resident bytes. Registers the
    /// labeled gauges on first touch (serial paths only).
    pub fn set_head_gauges(
        &self,
        session: u64,
        head: usize,
        ess: f64,
        anisotropy: f64,
        epochs: u64,
        frozen_bytes: u64,
    ) {
        if !self.gauges_enabled() {
            return;
        }
        let labels = format!("session=\"{session}\",head=\"{head}\"");
        self.registry
            .gauge_labeled(
                "rfa_head_ess",
                labels.clone(),
                "Effective sample size of the head's importance weights",
            )
            .set(ess);
        self.registry
            .gauge_labeled(
                "rfa_head_sigma_anisotropy",
                labels.clone(),
                "Anisotropy proxy of the head's bank covariance: \
                 ln(trace/d) - logdet/d (0 = isotropic)",
            )
            .set(anisotropy);
        self.registry
            .gauge_labeled(
                "rfa_head_epochs",
                labels.clone(),
                "Completed resample epochs of the head",
            )
            .set(epochs as f64);
        self.registry
            .gauge_labeled(
                "rfa_head_frozen_bytes",
                labels,
                "Resident bytes of the head's retained frozen epochs",
            )
            .set(frozen_bytes as f64);
    }

    /// Mean of every per-head ESS gauge (0 when none registered) — the
    /// bench's `ess_mean` headline.
    pub fn ess_mean(&self) -> f64 {
        let values = self.registry.gauge_family_values("rfa_head_ess");
        if values.is_empty() {
            return 0.0;
        }
        values.iter().sum::<f64>() / values.len() as f64
    }

    /// Drain the event ring (oldest first).
    pub fn drain_events(&self) -> Vec<Event> {
        self.ring.drain()
    }

    /// Copy of the buffered events without consuming them.
    pub fn events_snapshot(&self) -> Vec<Event> {
        self.ring.snapshot()
    }

    /// Events lost to ring overflow.
    pub fn events_dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// Prometheus text exposition of every registered metric.
    pub fn prometheus_text(&self) -> String {
        super::export::prometheus_text(&self.registry)
    }

    /// Flat JSON metric snapshot (`BENCH_*.json` conventions).
    pub fn json_snapshot(&self) -> crate::ser::Json {
        super::export::json_snapshot("rfa_serve_obs", &self.registry)
    }
}

/// Anisotropy proxy of a bank's normalizer covariance Σ:
/// `ln(trace(Σ)/d) − logdet(Σ)/d`, the log of the arithmetic-to-
/// geometric mean ratio of Σ's eigenvalues — 0 iff Σ is a multiple of
/// the identity, growing as the spectrum spreads. Pays one O(d³)
/// `cholesky()` per call, so the serving layer only falls back to it for
/// static-bank heads — online heads read the same proxy in O(d) from
/// their maintained factor (`OnlineState::factor_anisotropy`) instead of
/// refactorizing on every serial gauge publish. Isotropic banks (no Σ)
/// report 0; a non-SPD Σ (never produced by the shrinkage path) reports
/// 0 rather than NaN.
pub fn bank_anisotropy(bank: &FeatureBank) -> f64 {
    let Some(sigma) = bank.norm_sigma() else {
        return 0.0;
    };
    let d = sigma.rows();
    let trace: f64 = (0..d).map(|i| sigma[(i, i)]).sum();
    let Some(chol) = sigma.cholesky() else {
        return 0.0;
    };
    if trace <= 0.0 {
        return 0.0;
    }
    let logdet: f64 = 2.0 * (0..d).map(|i| chol[(i, i)].ln()).sum::<f64>();
    let df = d as f64;
    ((trace / df).ln() - logdet / df).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rfa::estimators::{PrfEstimator, Sampling};
    use crate::rfa::gaussian::MultivariateGaussian;
    use crate::rng::Pcg64;

    #[test]
    fn isotropic_bank_has_zero_anisotropy_and_full_ess() {
        let est = PrfEstimator::new(4, 16, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut Pcg64::seed(7));
        assert_eq!(bank_anisotropy(&bank), 0.0);
        // Unweighted bank: all w_i = 1, so ESS = n.
        assert!((bank.effective_sample_size() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn anisotropy_grows_with_spectrum_spread() {
        let mk = |scale: f64| {
            let mut sigma = Matrix::identity(3);
            sigma[(0, 0)] = scale;
            let est = PrfEstimator::new(
                3,
                8,
                Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
            );
            FeatureBank::draw(&est, &mut Pcg64::seed(11))
        };
        let near_iso = bank_anisotropy(&mk(1.0));
        let spread = bank_anisotropy(&mk(9.0));
        assert!(near_iso.abs() < 1e-12, "identity Σ must read 0");
        assert!(spread > 0.1, "spread spectrum must read > 0, got {spread}");
    }

    #[test]
    fn level_gating() {
        let off = ServeObs::new(ObsConfig::off());
        off.evictions.inc(); // counters always live
        off.observe_batch(4);
        off.set_head_gauges(0, 0, 1.0, 0.0, 0, 0);
        off.event(EventKind::DegradedEnter);
        assert_eq!(off.evictions.get(), 1);
        assert_eq!(off.batch_sessions.count(), 0);
        assert!(off.registry.gauge_family_values("rfa_head_ess").is_empty());
        assert!(off.events_snapshot().is_empty());

        let full = ServeObs::new(ObsConfig::full());
        full.observe_batch(4);
        full.set_head_gauges(0, 1, 2.5, 0.0, 3, 64);
        full.event(EventKind::DegradedEnter);
        assert_eq!(full.batch_sessions.count(), 1);
        assert_eq!(
            full.registry.gauge_family_values("rfa_head_ess"),
            vec![2.5]
        );
        assert_eq!(full.events_snapshot().len(), 1);
        assert!((full.ess_mean() - 2.5).abs() < 1e-12);
    }
}
