//! Pure-Rust linear-attention forward over PRF feature maps (FAVOR+
//! structure), with an exact-softmax reference.
//!
//! Exact attention materializes the L×L score matrix: O(L²·d) time and
//! O(L²) memory. With a positive feature map `Φ` the same normalized
//! aggregation factorizes:
//!
//! ```text
//! out_l = Σ_j κ(q_l, k_j)·v_j / Σ_j κ(q_l, k_j)
//!       ≈ φ(q_l)ᵀ·(Σ_j φ(k_j)·v_jᵀ) / φ(q_l)ᵀ·(Σ_j φ(k_j))
//! ```
//!
//! which is O(L·n·d) time and O(n·d) state. The causal variant keeps the
//! running prefix sums `S_l = Σ_{j≤l} φ(k_j)·v_jᵀ` and `z_l = Σ_{j≤l}
//! φ(k_j)` — one pass over the sequence, constant state per position.
//!
//! Everything here estimates the *unnormalized-temperature* kernel
//! `κ(q,k) = exp(q·k)` (data-aware banks estimate `exp(qᵀΣk)`); callers
//! fold any `1/√d` temperature into Q before the feature map, matching
//! the convention of the [`super::estimators`] oracles.

use crate::linalg::Matrix;

use super::features::FeatureBank;

/// Exact softmax attention reference: `out = softmax(Q·Kᵀ)·V`, optionally
/// causally masked. O(L²·d) — the brute-force baseline the linear path is
/// validated against.
///
/// When `causal` only the lower triangle of the score matrix exists after
/// masking, so only those `L·(L+1)/2` dots are computed — the full-gram
/// shortcut would double the baseline's work and skew every "exact vs
/// linear" timing comparison.
pub fn softmax_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    causal: bool,
) -> Matrix {
    assert_eq!(q.cols(), k.cols(), "q/k dim mismatch");
    assert_eq!(k.rows(), v.rows(), "k/v length mismatch");
    let (lq, lk, dv) = (q.rows(), k.rows(), v.cols());
    let mut out = Matrix::zeros(lq, dv);
    // Non-causal: every score is live, one dense gram is optimal. Causal:
    // compute each row's surviving prefix of scores directly.
    let full_scores = if causal { None } else { Some(q.matmul_transb(k)) };
    let mut row_scores = Vec::new();
    for i in 0..lq {
        let limit = if causal { (i + 1).min(lk) } else { lk };
        let scores: &[f64] = match &full_scores {
            Some(s) => &s.row(i)[..limit],
            None => {
                let qrow = q.row(i);
                row_scores.clear();
                row_scores.extend(
                    (0..limit).map(|j| crate::linalg::dot(qrow, k.row(j))),
                );
                &row_scores
            }
        };
        // Stable softmax over the (masked) row.
        let max = scores.iter().fold(f64::NEG_INFINITY, |m, &s| m.max(s));
        let mut denom = 0.0;
        for (j, &s) in scores.iter().enumerate() {
            let w = (s - max).exp();
            denom += w;
            for c in 0..dv {
                out[(i, c)] += w * v[(j, c)];
            }
        }
        for c in 0..dv {
            out[(i, c)] /= denom;
        }
    }
    out
}

/// Non-causal linear attention from precomputed feature matrices:
/// `out = diag(Φq·z)⁻¹ · Φq · (Φkᵀ·V)` with `z = Φkᵀ·1`.
///
/// O(L·n·dv): the key/value summary `S = Φkᵀ·V` is one
/// [`Matrix::matmul_transa`] contraction, the readout a single `Φq·S`
/// matmul.
pub fn linear_attention(
    phi_q: &Matrix,
    phi_k: &Matrix,
    v: &Matrix,
) -> Matrix {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "k/v length mismatch");
    let dv = v.cols();
    // S = Φkᵀ·V and z = Φkᵀ·1, both streamed over contiguous rows.
    let s = phi_k.matmul_transa(v);
    let z = phi_k.col_sums();
    let mut out = phi_q.matmul(&s);
    let denom = phi_q.matvec(&z);
    for l in 0..out.rows() {
        let d = denom[l];
        for c in 0..dv {
            out[(l, c)] /= d;
        }
    }
    out
}

/// Causal linear attention (FAVOR+ running state): one pass with prefix
/// sums `S ∈ R^{n×dv}`, `z ∈ R^n` updated per position before readout.
///
/// O(L·n·dv) time, O(n·dv) state — the kernel the paper's Fig. 1 scaling
/// claim is about.
pub fn causal_linear_attention(
    phi_q: &Matrix,
    phi_k: &Matrix,
    v: &Matrix,
) -> Matrix {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_q.rows(), phi_k.rows(), "causal attention needs lq == lk");
    assert_eq!(phi_k.rows(), v.rows(), "k/v length mismatch");
    let (l, n, dv) = (phi_q.rows(), phi_q.cols(), v.cols());
    let mut s = vec![0.0; n * dv]; // S[i, c] row-major
    let mut z = vec![0.0; n];
    let mut out = Matrix::zeros(l, dv);
    for t in 0..l {
        // State update with (k_t, v_t).
        let krow = phi_k.row(t);
        let vrow = v.row(t);
        for (i, &phi) in krow.iter().enumerate() {
            z[i] += phi;
            let srow = &mut s[i * dv..(i + 1) * dv];
            for (sc, &vc) in srow.iter_mut().zip(vrow) {
                *sc += phi * vc;
            }
        }
        // Readout with q_t.
        let qrow = phi_q.row(t);
        let mut denom = 0.0;
        for (i, &phi) in qrow.iter().enumerate() {
            denom += phi * z[i];
            let srow = &s[i * dv..(i + 1) * dv];
            for c in 0..dv {
                out[(t, c)] += phi * srow[c];
            }
        }
        for c in 0..dv {
            out[(t, c)] /= denom;
        }
    }
    out
}

/// End-to-end PRF attention: map Q/K through the bank's feature map, then
/// run the linear forward. `q`/`k` are rows of length `bank.dim()`.
pub fn prf_attention(
    bank: &FeatureBank,
    q: &[Vec<f64>],
    k: &[Vec<f64>],
    v: &Matrix,
    causal: bool,
) -> Matrix {
    let phi_q = bank.feature_matrix(q);
    let phi_k = bank.feature_matrix(k);
    if causal {
        causal_linear_attention(&phi_q, &phi_k, v)
    } else {
        linear_attention(&phi_q, &phi_k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::estimators::{PrfEstimator, Sampling};
    use crate::rng::{GaussianExt, Pcg64};

    fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
        (0..l)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
            .collect()
    }

    fn to_matrix(rows: &[Vec<f64>]) -> Matrix {
        Matrix::from_rows(rows)
    }

    /// Brute-force normalized aggregation over an explicit kernel gram.
    fn reference_from_gram(gram: &Matrix, v: &Matrix, causal: bool) -> Matrix {
        let (lq, lk, dv) = (gram.rows(), gram.cols(), v.cols());
        let mut out = Matrix::zeros(lq, dv);
        for i in 0..lq {
            let limit = if causal { (i + 1).min(lk) } else { lk };
            let mut denom = 0.0;
            for j in 0..limit {
                denom += gram[(i, j)];
                for c in 0..dv {
                    out[(i, c)] += gram[(i, j)] * v[(j, c)];
                }
            }
            for c in 0..dv {
                out[(i, c)] /= denom;
            }
        }
        out
    }

    #[test]
    fn causal_prefix_sums_match_quadratic_identity() {
        // Algebraic identity, no MC tolerance: the O(L·n·dv) prefix-sum
        // forward must equal brute-force aggregation over the bank's own
        // estimated kernel gram, up to fp reassociation.
        let mut rng = Pcg64::seed(1201);
        let (l, d, dv, m) = (20, 4, 3, 16);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = crate::rfa::features::FeatureBank::draw(&est, &mut rng);
        let q = rows(l, d, 0.4, &mut rng);
        let k = rows(l, d, 0.4, &mut rng);
        let v = to_matrix(&rows(l, dv, 1.0, &mut rng));
        let fast = prf_attention(&bank, &q, &k, &v, true);
        let gram = bank.gram(&q, &k);
        let reference = reference_from_gram(&gram, &v, true);
        assert!(
            fast.max_abs_diff(&reference) < 1e-10,
            "diff={}",
            fast.max_abs_diff(&reference)
        );
    }

    #[test]
    fn noncausal_matches_quadratic_identity() {
        let mut rng = Pcg64::seed(1202);
        let (lq, lk, d, dv, m) = (9, 13, 5, 4, 24);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = crate::rfa::features::FeatureBank::draw(&est, &mut rng);
        let q = rows(lq, d, 0.3, &mut rng);
        let k = rows(lk, d, 0.3, &mut rng);
        let v = to_matrix(&rows(lk, dv, 1.0, &mut rng));
        let fast = prf_attention(&bank, &q, &k, &v, false);
        let gram = bank.gram(&q, &k);
        let reference = reference_from_gram(&gram, &v, false);
        assert!(
            fast.max_abs_diff(&reference) < 1e-10,
            "diff={}",
            fast.max_abs_diff(&reference)
        );
    }

    #[test]
    fn softmax_reference_rows_are_convex_combinations() {
        let mut rng = Pcg64::seed(1203);
        let (l, d) = (12, 4);
        let q = to_matrix(&rows(l, d, 0.5, &mut rng));
        let k = to_matrix(&rows(l, d, 0.5, &mut rng));
        // v = all-ones: any weighted average must be exactly 1.
        let v = Matrix::from_vec(l, 2, vec![1.0; l * 2]);
        for causal in [false, true] {
            let out = softmax_attention(&q, &k, &v, causal);
            for i in 0..l {
                for c in 0..2 {
                    assert!(
                        (out[(i, c)] - 1.0).abs() < 1e-12,
                        "row {i} not normalized: {}",
                        out[(i, c)]
                    );
                }
            }
        }
    }

    #[test]
    fn first_position_of_causal_attends_only_to_itself() {
        let mut rng = Pcg64::seed(1204);
        let (l, d, dv, m) = (6, 3, 2, 64);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = crate::rfa::features::FeatureBank::draw(&est, &mut rng);
        let q = rows(l, d, 0.3, &mut rng);
        let k = rows(l, d, 0.3, &mut rng);
        let v = to_matrix(&rows(l, dv, 1.0, &mut rng));
        let out = prf_attention(&bank, &q, &k, &v, true);
        // Position 0 sees only v_0, and the kernel weight cancels in the
        // normalization — exactly v_0 regardless of the feature draw.
        for c in 0..dv {
            assert!(
                (out[(0, c)] - v[(0, c)]).abs() < 1e-12,
                "out0={} v0={}",
                out[(0, c)],
                v[(0, c)]
            );
        }
    }

    #[test]
    fn prf_attention_approximates_exact_softmax() {
        // MC agreement: with a generous feature budget the PRF forward
        // tracks the exact masked softmax closely on mild inputs.
        let mut rng = Pcg64::seed(1205);
        let (l, d, dv, m) = (24, 4, 3, 2048);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = crate::rfa::features::FeatureBank::draw(&est, &mut rng);
        let q = rows(l, d, 0.25, &mut rng);
        let k = rows(l, d, 0.25, &mut rng);
        let v = to_matrix(&rows(l, dv, 0.5, &mut rng));
        let qm = to_matrix(&q);
        let km = to_matrix(&k);
        for causal in [false, true] {
            let approx = prf_attention(&bank, &q, &k, &v, causal);
            let exact = softmax_attention(&qm, &km, &v, causal);
            let diff = approx.max_abs_diff(&exact);
            assert!(
                diff < 0.15,
                "causal={causal}: PRF attention drifted from exact: {diff}"
            );
        }
    }
}
