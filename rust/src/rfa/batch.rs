//! Batched, threaded Monte-Carlo variance engine.
//!
//! [`super::variance::expected_mc_variance`] is the scalar reference: per
//! (q, k) pair it draws `n_omega` omegas one Vec at a time and evaluates
//! the integrand per draw (with, historically, two O(d²) Mahalanobis
//! norms *inside* every term). This module reworks the same estimator
//! around the shared-bank machinery:
//!
//! * each pair draws its `n_omega×d` bank in one shot
//!   ([`super::features::FeatureBank::draw_n`]): one flat Gaussian fill +
//!   one `Z·Lᵀ` contraction instead of `2·n_omega` small allocations;
//! * pair normalizers are computed once per pair (O(d²)), every term is
//!   O(d);
//! * pairs fan out across `std::thread::scope` workers.
//!
//! **Determinism:** the root rng samples the (q, k) pairs and splits one
//! child stream per pair *before* any thread is spawned; workers only
//! consume their pair-local streams, and results are reduced in pair
//! order. The returned value is therefore a pure function of the seed —
//! independent of the worker count — which `rust/tests/rfa_batch.rs`
//! pins.

use crate::rng::Pcg64;

use super::estimators::PrfEstimator;
use super::features::FeatureBank;
use super::gaussian::MultivariateGaussian;

/// One unit of work: a sampled input pair plus its private rng stream.
struct PairJob {
    q: Vec<f64>,
    k: Vec<f64>,
    rng: Pcg64,
}

/// Sample `n_pairs` (q, k) pairs and split a child stream per pair. Pure
/// function of `rng`'s state; all downstream work is thread-safe replay.
fn pair_jobs(
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    rng: &mut Pcg64,
) -> Vec<PairJob> {
    (0..n_pairs)
        .map(|_| {
            let q = input_dist.sample(rng);
            let k = input_dist.sample(rng);
            let rng = rng.split();
            PairJob { q, k, rng }
        })
        .collect()
}

/// Welford variance of a term stream (the integrand spans orders of
/// magnitude, so the shifted one-pass form matters).
fn welford_variance(terms: &[f64]) -> f64 {
    let mut mean = 0.0;
    let mut m2 = 0.0;
    for (i, &z) in terms.iter().enumerate() {
        let delta = z - mean;
        mean += delta / (i + 1) as f64;
        m2 += delta * (z - mean);
    }
    m2 / (terms.len() - 1) as f64
}

/// `Var_omega[Z(q, k, ω)]` for one pair from a freshly drawn shared bank.
fn pair_variance(
    est: &PrfEstimator,
    job: &mut PairJob,
    n_omega: usize,
) -> f64 {
    let bank = FeatureBank::draw_n(est, n_omega, &mut job.rng);
    welford_variance(&bank.single_terms(&job.q, &job.k))
}

/// Run `f` over the jobs on `threads` workers, writing one value per job.
/// Chunking only affects scheduling: results come back in job order, so
/// any job-order reduction is thread-count independent. Shared with
/// [`super::engine`], which fans attention heads across workers with the
/// same contract.
pub(crate) fn run_jobs<J, T, F>(jobs: &mut [J], threads: usize, f: F) -> Vec<T>
where
    J: Send,
    T: Send,
    F: Fn(&mut J) -> T + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.max(1).min(n);
    let chunk = n.div_ceil(workers);
    let mut results: Vec<Option<T>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    std::thread::scope(|scope| {
        let f = &f;
        for (job_chunk, out_chunk) in
            jobs.chunks_mut(chunk).zip(results.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for (job, out) in job_chunk.iter_mut().zip(out_chunk) {
                    *out = Some(f(job));
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker filled its slot")).collect()
}

pub(crate) fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Batched expected MC variance `V(ψ) = E_{q,k}[Var_ω[κ̂(q,k)]]` of the
/// m-sample estimator — the drop-in fast path for
/// [`super::variance::expected_mc_variance`], using all available cores.
///
/// Same estimand and same `Var[Z]/m` convention as the scalar engine; the
/// draw streams differ (per-pair split streams instead of one shared
/// stream), so values agree statistically, not bitwise.
pub fn expected_mc_variance_batched(
    est: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    rng: &mut Pcg64,
) -> f64 {
    expected_mc_variance_threaded(
        est,
        input_dist,
        n_pairs,
        n_omega,
        default_threads(),
        rng,
    )
}

/// [`expected_mc_variance_batched`] with an explicit worker count. The
/// result is identical for every `threads >= 1` under a fixed seed.
pub fn expected_mc_variance_threaded(
    est: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    threads: usize,
    rng: &mut Pcg64,
) -> f64 {
    assert!(n_omega >= 2, "variance estimation needs at least two draws");
    let mut jobs = pair_jobs(input_dist, n_pairs, rng);
    let vars =
        run_jobs(&mut jobs, threads, |job| pair_variance(est, job, n_omega));
    vars.iter().sum::<f64>() / n_pairs as f64 / est.m as f64
}

/// Paired comparison on the SAME (q, k) pairs (and per-pair streams):
/// returns `(V_a, V_b)`. Mirrors
/// [`super::variance::paired_expected_mc_variance`] so variance *ratios*
/// are free of across-pair noise.
pub fn paired_expected_mc_variance_batched(
    est_a: &PrfEstimator,
    est_b: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    paired_expected_mc_variance_threaded(
        est_a,
        est_b,
        input_dist,
        n_pairs,
        n_omega,
        default_threads(),
        rng,
    )
}

/// Paired comparison with an explicit worker count; see
/// [`paired_expected_mc_variance_batched`].
pub fn paired_expected_mc_variance_threaded(
    est_a: &PrfEstimator,
    est_b: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    threads: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    assert!(n_omega >= 2, "variance estimation needs at least two draws");
    let mut jobs = pair_jobs(input_dist, n_pairs, rng);
    // Both estimators consume the pair's stream in a fixed order:
    // deterministic and shared-pair.
    let results = run_jobs(&mut jobs, threads, |job| {
        let va = pair_variance(est_a, job, n_omega);
        let vb = pair_variance(est_b, job, n_omega);
        (va, vb)
    });
    let np = n_pairs as f64;
    let va: f64 = results.iter().map(|r| r.0).sum::<f64>() / np / est_a.m as f64;
    let vb: f64 = results.iter().map(|r| r.1).sum::<f64>() / np / est_b.m as f64;
    (va, vb)
}
