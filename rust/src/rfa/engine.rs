//! Multi-head, chunk-blocked linear-attention engine — the serving-scale
//! forward on top of [`super::features::FeatureBank`], written once,
//! generically, over the [`Scalar`] storage precision.
//!
//! # Chunked causal evaluation
//!
//! [`super::attention::causal_linear_attention`] walks the sequence one
//! position at a time: per position it does two `n×dv` scalar sweeps
//! (state update, readout) whose loop/indexing overhead — not arithmetic —
//! dominates the runtime. This module blocks the same prefix-sum algebra
//! into chunks of `C` positions (the blocked-prefix formulation of the
//! FAVOR+/linear-RA estimators):
//!
//! ```text
//! for each chunk Q_c, K_c, V_c of C rows:
//!   out_c    = Φ(Q_c)·S          + tril(Φ(Q_c)·Φ(K_c)ᵀ)·V_c   (inter + intra)
//!   denom_c  = Φ(Q_c)·z          + tril(Φ(Q_c)·Φ(K_c)ᵀ)·1
//!   S       += Φ(K_c)ᵀ·V_c ;  z += Φ(K_c)ᵀ·1                  (state fold)
//! ```
//!
//! Everything left of the `tril` is a dense contraction (`matmul`,
//! [`Mat::matmul_transa`]); the masked intra-chunk gram is `C(C+1)/2`
//! unrolled dots per chunk. The causal path therefore costs
//! O(L·(C·n + n·dv)) of dense work instead of O(L) scalar iterations,
//! while the state stays O(n·dv) — a [`CausalState`] can stream L ≫ 10⁵
//! chunk by chunk without ever materializing the sequence. All of those
//! contractions (and the masked-row dots) bottom out in the
//! [`crate::linalg::simd`] microkernels via the sealed [`Scalar`] hooks:
//! explicit AVX2/AVX-512/NEON with runtime dispatch, bitwise-identical to
//! the portable fallback, so nothing in this file is ISA-aware.
//!
//! # The `Scalar::Accum` contract
//!
//! There is exactly one [`CausalState::forward_chunk`] body, generic over
//! the storage precision `T`. Chunk-local compute — intra-chunk grams,
//! inter-chunk readouts, chunk summaries, every O(L·C·n) contraction —
//! runs at storage width `T`, where SIMD width and memory bandwidth pay.
//! Everything whose roundoff compounds with sequence length accumulates
//! in [`Scalar::Accum`] (**f64 for every precision** — the contract
//! documented on the trait):
//!
//! * the running state `S = Σ φ(k_j)·v_jᵀ` and `z = Σ φ(k_j)` are
//!   `Accum` accumulators, folded once per chunk from the storage-width
//!   chunk summaries — they are monotone sums of L positive terms, and a
//!   storage-width running sum would accumulate O(L·ε) relative error
//!   (≈1% at L=10⁵ for f32); folding per chunk bounds each storage-width
//!   partial sum to C terms;
//! * per-row denominators accumulate in `Accum` for the same reason, and
//!   the final normalization divides in `Accum` before rounding the
//!   output to `T` exactly once (the numerator/denominator are
//!   correlated sums — dividing at storage width would forfeit the
//!   cancellation of their shared error);
//! * the state is rounded to `T` once per chunk for the readout matmul
//!   ([`Scalar::mat_from_accum`] — a borrow, not a copy, on the f64
//!   path), so the rounding enters each output once instead of drifting
//!   per-position;
//! * feature values themselves come from
//!   [`FeatureBank::feature_matrix_t`], which exponentiates in `Accum`
//!   (the exponent is a cancellation-sensitive difference) and stores
//!   `T`.
//!
//! On the f64 path every `Accum` conversion is the identity, so the
//! generic body *is* the f64 algorithm; on the f32 path it reproduces the
//! historical `CausalState32` semantics (including the once-per-chunk
//! state rounding) bit for bit. `rust/tests/rfa_generic.rs` pins both
//! against frozen transliterations of the pre-generic implementations,
//! and `rust/tests/rfa_engine.rs` pins the f32 path to the f64 reference
//! at L=512.
//!
//! # Multi-head batching
//!
//! Heads are embarrassingly parallel: [`multi_head_causal_attention`]
//! fans one chunked forward per head across `std::thread::scope` workers
//! via the same job runner as the [`super::batch`] variance engine, and
//! [`draw_head_banks`] splits one child rng stream per head *before* any
//! thread is spawned — outputs are a pure function of the seed,
//! independent of worker count.

use crate::linalg::{Mat, Matrix, Matrix32, Scalar};
use crate::rng::Pcg64;

use super::batch::{default_threads, run_jobs};
use super::estimators::PrfEstimator;
use super::features::FeatureBank;

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Causal chunk length `C`. Larger chunks amortize more per-position
    /// work into dense contractions but pay O(C·n) masked-gram work per
    /// position; 16–64 is the sweet spot for n ∈ [32, 128].
    pub chunk: usize,
    /// Worker threads for multi-head fan-out; `0` = all available cores.
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { chunk: 32, threads: 0 }
    }
}

impl EngineConfig {
    pub(crate) fn worker_count(&self) -> usize {
        if self.threads == 0 {
            default_threads()
        } else {
            self.threads
        }
    }
}

// ---------------------------------------------------------------------
// Chunked causal state, generic over the storage precision
// ---------------------------------------------------------------------

/// Streaming causal-attention state: the O(n·dv) running prefix summaries
/// `S = Σ_{j<t} φ(k_j)·v_jᵀ` and `z = Σ_{j<t} φ(k_j)`, advanced one chunk
/// at a time. Feeding chunks of any sizes produces the same output rows
/// as one monolithic call — only fp reassociation differs.
///
/// The state lives in [`Scalar::Accum`] precision (f64) regardless of
/// the storage precision `T` — the module's accumulation contract — so
/// snapshots of it are exact-bits by construction for every `T`.
pub struct CausalState<T: Scalar> {
    s: Mat<T::Accum>,
    z: Vec<T::Accum>,
}

/// The f32 storage-precision state — one instantiation of the generic
/// [`CausalState`], kept as an alias for the historical name.
pub type CausalState32 = CausalState<f32>;

impl<T: Scalar> CausalState<T> {
    /// Fresh (all-zero) state for `n` features and `dv` value channels.
    pub fn new(n: usize, dv: usize) -> Self {
        Self { s: Mat::zeros(n, dv), z: vec![<T::Accum as Scalar>::ZERO; n] }
    }

    /// Number of feature channels `n`.
    pub fn n_features(&self) -> usize {
        self.s.rows()
    }

    /// Number of value channels `dv`.
    pub fn dv(&self) -> usize {
        self.s.cols()
    }

    /// The running prefix `S = Σ φ(k_j)·v_jᵀ` (`n×dv`, accumulator
    /// precision). Read access for state snapshots ([`crate::rfa::serve`]);
    /// the recursion itself only advances through [`Self::forward_chunk`].
    pub fn state(&self) -> &Mat<T::Accum> {
        &self.s
    }

    /// The running normalizer prefix `z = Σ φ(k_j)` (length `n`,
    /// accumulator precision).
    pub fn z(&self) -> &[T::Accum] {
        &self.z
    }

    /// Rebuild a state from snapshotted parts — the write half of the
    /// snapshot surface. `s` is the `n×dv` prefix, `z` its length-`n`
    /// normalizer; a state restored from [`Self::state`]/[`Self::z`]
    /// continues the stream bitwise identically.
    pub fn from_parts(s: Mat<T::Accum>, z: Vec<T::Accum>) -> Self {
        assert_eq!(s.rows(), z.len(), "state/z feature dims differ");
        Self { s, z }
    }

    /// Frozen readout of the current prefix against a chunk of queries:
    /// `(Φ(Q)·S, Φ(Q)·z)` at storage width, denominators in Accum. Does
    /// **not** mutate the state — the multi-epoch combine in
    /// [`crate::rfa::serve`] calls this on frozen `(bank, S, z)` triples
    /// whose prefixes stopped advancing at their epoch boundary. Uses the
    /// exact ops (and rounding) of the inter-chunk readout inside
    /// [`Self::forward_chunk`].
    pub fn readout(&self, phi_q: &Mat<T>) -> (Mat<T>, Vec<T::Accum>) {
        assert_eq!(phi_q.cols(), self.s.rows(), "phi_q feature dim mismatch");
        let s_t = T::mat_from_accum(&self.s);
        let z_t = T::slice_from_accum(&self.z);
        (phi_q.matmul(&s_t), phi_q.matvec_accum(&z_t))
    }

    /// [`Self::forward_chunk`] minus the final normalization: returns the
    /// *unnormalized* numerator rows (storage width) and the per-row
    /// denominators (Accum), and folds the chunk into the running state.
    /// The multi-epoch serving combine sums these across epoch readouts
    /// before dividing once; [`Self::forward_chunk`] is exactly this plus
    /// the single-epoch division, so the split changes no bits.
    pub fn forward_chunk_unnormalized(
        &mut self,
        phi_q: &Mat<T>,
        phi_k: &Mat<T>,
        v: &Mat<T>,
    ) -> (Mat<T>, Vec<T::Accum>) {
        let (n, dv) = (self.s.rows(), self.s.cols());
        assert_eq!(phi_q.cols(), n, "phi_q feature dim mismatch");
        assert_eq!(phi_k.cols(), n, "phi_k feature dim mismatch");
        assert_eq!(v.cols(), dv, "v channel dim mismatch");
        assert_eq!(phi_q.rows(), phi_k.rows(), "chunk q/k length mismatch");
        assert_eq!(phi_k.rows(), v.rows(), "chunk k/v length mismatch");
        let c = phi_q.rows();

        // One rounding of the running state to storage precision per
        // chunk (a borrow — no copy, no rounding — on the f64 path),
        // scoped so the borrows end before the state fold below mutates
        // the running prefixes. Inter-chunk readout at storage width;
        // denominators accumulate in Accum.
        let (mut out, mut denom) = {
            let s_t = T::mat_from_accum(&self.s);
            let z_t = T::slice_from_accum(&self.z);
            (phi_q.matmul(&s_t), phi_q.matvec_accum(&z_t))
        };

        // Intra-chunk masked gram at storage width — position t sees
        // keys j ≤ t.
        for t in 0..c {
            let qrow = phi_q.row(t);
            let orow = out.row_mut(t);
            let mut acc = <T::Accum as Scalar>::ZERO;
            for j in 0..=t {
                let g = T::dot(qrow, phi_k.row(j));
                acc += g.to_accum();
                for (o, &vc) in orow.iter_mut().zip(v.row(j)) {
                    *o += g * vc;
                }
            }
            denom[t] += acc;
        }

        // Chunk summaries at storage width (≤ C terms each), folded into
        // the Accum state with single contractions over the whole chunk.
        let summary = phi_k.matmul_transa(v);
        for (s, &x) in self.s.data_mut().iter_mut().zip(summary.data()) {
            *s += x.to_accum();
        }
        for (z, x) in self.z.iter_mut().zip(phi_k.col_sums()) {
            *z += x;
        }

        (out, denom)
    }

    /// Process one chunk: returns the normalized attention rows for the
    /// chunk's positions and folds the chunk's key/value summaries into
    /// the running state. The single forward body of the whole stack —
    /// see the module docs for the `Scalar::Accum` contract it encodes.
    pub fn forward_chunk(
        &mut self,
        phi_q: &Mat<T>,
        phi_k: &Mat<T>,
        v: &Mat<T>,
    ) -> Mat<T> {
        let (mut out, denom) =
            self.forward_chunk_unnormalized(phi_q, phi_k, v);

        // Normalize in Accum, store T — one output rounding.
        for t in 0..phi_q.rows() {
            let d = denom[t];
            for o in out.row_mut(t) {
                *o = T::from_accum(o.to_accum() / d);
            }
        }
        out
    }

    /// [`Self::forward`] minus the normalization: slice a segment into
    /// `chunk`-row blocks, return the concatenated unnormalized numerators
    /// and denominators. Chunk blocking restarts at the segment start,
    /// matching [`Self::forward`]'s reassociation exactly.
    pub fn forward_unnormalized(
        &mut self,
        phi_q: &Mat<T>,
        phi_k: &Mat<T>,
        v: &Mat<T>,
        chunk: usize,
    ) -> (Mat<T>, Vec<T::Accum>) {
        let (l, dv) = (phi_q.rows(), self.s.cols());
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(l, dv);
        let mut denom = Vec::with_capacity(l);
        let mut b = 0;
        while b < l {
            let e = (b + chunk).min(l);
            let (block, block_denom) = self.forward_chunk_unnormalized(
                &phi_q.row_block(b, e),
                &phi_k.row_block(b, e),
                &v.row_block(b, e),
            );
            out.data_mut()[b * dv..e * dv].copy_from_slice(block.data());
            denom.extend(block_denom);
            b = e;
        }
        (out, denom)
    }

    /// Process an arbitrary-length segment by slicing it into `chunk`-row
    /// blocks internally (the masked gram in [`Self::forward_chunk`] is
    /// O(C²·n), so large segments must not be fed as one chunk). The
    /// streaming API: feed consecutive segments of any sizes.
    pub fn forward(
        &mut self,
        phi_q: &Mat<T>,
        phi_k: &Mat<T>,
        v: &Mat<T>,
        chunk: usize,
    ) -> Mat<T> {
        let (l, dv) = (phi_q.rows(), self.s.cols());
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(l, dv);
        let mut b = 0;
        while b < l {
            let e = (b + chunk).min(l);
            let block = self.forward_chunk(
                &phi_q.row_block(b, e),
                &phi_k.row_block(b, e),
                &v.row_block(b, e),
            );
            out.data_mut()[b * dv..e * dv].copy_from_slice(block.data());
            b = e;
        }
        out
    }
}

/// Chunk-blocked causal linear attention at storage precision `T`: same
/// estimator as [`super::attention::causal_linear_attention`], evaluated
/// block-wise. `chunk` is the block length C (clamped to ≥ 1); C = 1
/// degenerates to per-position processing.
pub fn chunked_causal_linear_attention<T: Scalar>(
    phi_q: &Mat<T>,
    phi_k: &Mat<T>,
    v: &Mat<T>,
    chunk: usize,
) -> Mat<T> {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_q.rows(), phi_k.rows(), "causal attention needs lq == lk");
    assert_eq!(phi_k.rows(), v.rows(), "k/v length mismatch");
    CausalState::new(phi_q.cols(), v.cols()).forward(phi_q, phi_k, v, chunk)
}

/// [`chunked_causal_linear_attention`] instantiated on the f32 hot path —
/// kept under the historical name.
pub fn chunked_causal_linear_attention32(
    phi_q: &Matrix32,
    phi_k: &Matrix32,
    v: &Matrix32,
    chunk: usize,
) -> Matrix32 {
    chunked_causal_linear_attention(phi_q, phi_k, v, chunk)
}

/// f32 non-causal linear attention: `diag(Φq·z)⁻¹·Φq·(Φkᵀ·V)`. The key
/// summaries are folded per 128-row block so each f32 partial sum is
/// bounded while the length-L accumulation runs in f64 (same policy as
/// the causal state).
pub fn linear_attention32(
    phi_q: &Matrix32,
    phi_k: &Matrix32,
    v: &Matrix32,
) -> Matrix32 {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "k/v length mismatch");
    let (lk, n, dv) = (phi_k.rows(), phi_k.cols(), v.cols());
    const FOLD: usize = 128;
    let mut s = vec![0.0f64; n * dv];
    let mut z = vec![0.0f64; n];
    let mut b = 0;
    while b < lk {
        let e = (b + FOLD).min(lk);
        let summary =
            phi_k.row_block(b, e).matmul_transa(&v.row_block(b, e));
        for (acc, &x) in s.iter_mut().zip(summary.data()) {
            *acc += x as f64;
        }
        for (acc, x) in z.iter_mut().zip(phi_k.row_block(b, e).col_sums_f64())
        {
            *acc += x;
        }
        b = e;
    }
    let s32 =
        Matrix32::from_vec(n, dv, s.iter().map(|&x| x as f32).collect());
    let mut out = phi_q.matmul(&s32);
    for t in 0..phi_q.rows() {
        let d: f64 = phi_q
            .row(t)
            .iter()
            .zip(&z)
            .map(|(&a, b)| a as f64 * b)
            .sum();
        for o in out.row_mut(t) {
            *o = (*o as f64 / d) as f32;
        }
    }
    out
}

// ---------------------------------------------------------------------
// End-to-end single-head wrappers
// ---------------------------------------------------------------------

/// End-to-end chunked causal PRF attention at storage precision `T`:
/// feature maps from the bank ([`FeatureBank::feature_matrix_t`]), then
/// the blocked forward.
pub fn prf_attention_chunked<T: Scalar>(
    bank: &FeatureBank,
    q: &[Vec<f64>],
    k: &[Vec<f64>],
    v: &Mat<T>,
    cfg: &EngineConfig,
) -> Mat<T> {
    let phi_q = bank.feature_matrix_t::<T>(q);
    let phi_k = bank.feature_matrix_t::<T>(k);
    chunked_causal_linear_attention(&phi_q, &phi_k, v, cfg.chunk)
}

/// [`prf_attention_chunked`] instantiated on the f32 hot path — kept
/// under the historical name.
pub fn prf_attention_chunked32(
    bank: &FeatureBank,
    q: &[Vec<f64>],
    k: &[Vec<f64>],
    v: &Matrix32,
    cfg: &EngineConfig,
) -> Matrix32 {
    prf_attention_chunked(bank, q, k, v, cfg)
}

// ---------------------------------------------------------------------
// Multi-head fan-out
// ---------------------------------------------------------------------

/// One attention head's inputs: query/key rows (length `bank.dim()`) and
/// the value matrix (one row per position). Inputs always arrive in f64;
/// the storage precision is a property of the compute path, which rounds
/// values at the head boundary ([`Scalar::mat_from_f64`] — a borrow on
/// the f64 path).
#[derive(Clone)]
pub struct Head {
    pub q: Vec<Vec<f64>>,
    pub k: Vec<Vec<f64>>,
    pub v: Matrix,
}

/// Draw one feature bank per head with the [`super::batch`] seeding
/// scheme: one child stream is split off `rng` per head *before* any
/// thread exists, so bank h is a pure function of (seed, h) regardless
/// of how heads are later scheduled onto workers.
pub fn draw_head_banks(
    est: &PrfEstimator,
    n_heads: usize,
    rng: &mut Pcg64,
) -> Vec<FeatureBank> {
    (0..n_heads)
        .map(|_| {
            let mut child = rng.split();
            FeatureBank::draw(est, &mut child)
        })
        .collect()
}

/// Multi-head chunked causal attention at storage precision `T`: head h
/// runs the blocked forward under `banks[h]`, heads fan across `cfg`
/// worker threads, and outputs come back in head order. Thread-count
/// independent.
pub fn multi_head_causal_attention_t<T: Scalar>(
    banks: &[FeatureBank],
    heads: &[Head],
    cfg: &EngineConfig,
) -> Vec<Mat<T>> {
    assert_eq!(banks.len(), heads.len(), "one bank per head");
    let mut jobs: Vec<(&FeatureBank, &Head)> =
        banks.iter().zip(heads).collect();
    run_jobs(&mut jobs, cfg.worker_count(), |&mut (bank, head)| {
        let v = T::mat_from_f64(&head.v);
        prf_attention_chunked(bank, &head.q, &head.k, &v, cfg)
    })
}

/// [`multi_head_causal_attention_t`] at the default f64 precision.
pub fn multi_head_causal_attention(
    banks: &[FeatureBank],
    heads: &[Head],
    cfg: &EngineConfig,
) -> Vec<Matrix> {
    multi_head_causal_attention_t::<f64>(banks, heads, cfg)
}

/// [`multi_head_causal_attention_t`] on the f32 hot path; values are
/// rounded to f32 at the head boundary.
pub fn multi_head_causal_attention32(
    banks: &[FeatureBank],
    heads: &[Head],
    cfg: &EngineConfig,
) -> Vec<Matrix32> {
    multi_head_causal_attention_t::<f32>(banks, heads, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::attention::causal_linear_attention;
    use crate::rfa::estimators::Sampling;
    use crate::rng::{GaussianExt, Pcg64};

    fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
        (0..l)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
            .collect()
    }

    #[test]
    fn chunked_matches_per_position_reference() {
        let mut rng = Pcg64::seed(3101);
        let (l, d, dv, m) = (37, 4, 3, 24);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut rng);
        let phi_q = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let phi_k = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
        let reference = causal_linear_attention(&phi_q, &phi_k, &v);
        for chunk in [1usize, 5, 16, 37, 64] {
            let blocked =
                chunked_causal_linear_attention(&phi_q, &phi_k, &v, chunk);
            assert!(
                blocked.max_abs_diff(&reference) < 1e-12,
                "chunk={chunk}: diff={}",
                blocked.max_abs_diff(&reference)
            );
        }
    }

    #[test]
    fn streaming_state_equals_one_shot() {
        // Feeding irregular chunk sizes through one CausalState equals the
        // monolithic call: the state is the whole cross-chunk interface.
        let mut rng = Pcg64::seed(3102);
        let (l, d, dv, m) = (23, 3, 2, 16);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut rng);
        let phi_q = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let phi_k = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
        let one_shot =
            chunked_causal_linear_attention(&phi_q, &phi_k, &v, 6);
        let mut state = CausalState::new(m, dv);
        let mut streamed = Matrix::zeros(l, dv);
        let mut b = 0;
        for size in [6usize, 6, 6, 5] {
            let e = (b + size).min(l);
            let block = state.forward_chunk(
                &phi_q.row_block(b, e),
                &phi_k.row_block(b, e),
                &v.row_block(b, e),
            );
            streamed.data_mut()[b * dv..e * dv]
                .copy_from_slice(block.data());
            b = e;
        }
        assert_eq!(b, l);
        assert_eq!(streamed, one_shot, "streaming must be bitwise one-shot");
    }

    #[test]
    fn unnormalized_split_is_bitwise_forward() {
        // forward_chunk = forward_chunk_unnormalized + the divide, and
        // readout never mutates — the identities the serving layer's
        // epoch combine ([`crate::rfa::serve`]) is built on.
        let mut rng = Pcg64::seed(3104);
        let (l, d, dv, m) = (19, 4, 3, 16);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut rng);
        let phi_q = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let phi_k = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
        let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));

        let mut state_a = CausalState::new(m, dv);
        let normalized = state_a.forward_chunk(&phi_q, &phi_k, &v);

        let mut state_b = CausalState::new(m, dv);
        let (mut num, den) =
            state_b.forward_chunk_unnormalized(&phi_q, &phi_k, &v);
        for t in 0..l {
            for o in num.row_mut(t) {
                *o /= den[t];
            }
        }
        assert_eq!(
            normalized, num,
            "normalize(unnormalized) must be bitwise forward_chunk"
        );
        // Both states folded the same keys → identical prefixes.
        assert_eq!(state_a.state(), state_b.state());
        assert_eq!(state_a.z(), state_b.z());

        // readout against the folded prefix is pure: calling it twice
        // gives identical results and leaves the state untouched.
        let (s_before, z_before) =
            (state_b.state().clone(), state_b.z().to_vec());
        let (n1, d1) = state_b.readout(&phi_q);
        let (n2, d2) = state_b.readout(&phi_q);
        assert_eq!(n1, n2);
        assert_eq!(d1, d2);
        assert_eq!(state_b.state(), &s_before);
        assert_eq!(state_b.z(), z_before.as_slice());

        // And the blocked unnormalized walk normalizes to the blocked
        // forward, bit for bit (normalization never feeds the state).
        let mut state_c = CausalState::new(m, dv);
        let (mut num_blocked, den_blocked) =
            state_c.forward_unnormalized(&phi_q, &phi_k, &v, 7);
        assert_eq!(den_blocked.len(), l);
        for t in 0..l {
            for o in num_blocked.row_mut(t) {
                *o /= den_blocked[t];
            }
        }
        let mut state_d = CausalState::new(m, dv);
        let blocked = state_d.forward(&phi_q, &phi_k, &v, 7);
        assert_eq!(
            num_blocked, blocked,
            "blocked unnormalized walk must normalize to forward()"
        );
        assert_eq!(state_c.state(), state_d.state());
    }

    #[test]
    fn f32_engine_tracks_f64() {
        let mut rng = Pcg64::seed(3103);
        let (l, d, dv, m) = (64, 4, 3, 32);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut rng);
        let q = rows(l, d, 0.3, &mut rng);
        let k = rows(l, d, 0.3, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
        let cfg = EngineConfig { chunk: 16, threads: 1 };
        let out64 = prf_attention_chunked(&bank, &q, &k, &v, &cfg);
        let out32 = prf_attention_chunked32(
            &bank,
            &q,
            &k,
            &Matrix32::from_f64(&v),
            &cfg,
        )
        .to_f64();
        assert!(
            out64.max_abs_diff(&out32) < 1e-3,
            "f32 drifted: {}",
            out64.max_abs_diff(&out32)
        );
    }

    #[test]
    fn noncausal_f32_matches_f64_linear_attention() {
        let mut rng = Pcg64::seed(3104);
        let (lq, lk, d, dv, m) = (11, 300, 4, 3, 16);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let bank = FeatureBank::draw(&est, &mut rng);
        let phi_q = bank.feature_matrix(&rows(lq, d, 0.3, &mut rng));
        let phi_k = bank.feature_matrix(&rows(lk, d, 0.3, &mut rng));
        let v = Matrix::from_rows(&rows(lk, dv, 1.0, &mut rng));
        let out64 = crate::rfa::attention::linear_attention(
            &phi_q, &phi_k, &v,
        );
        let out32 = linear_attention32(
            &Matrix32::from_f64(&phi_q),
            &Matrix32::from_f64(&phi_k),
            &Matrix32::from_f64(&v),
        )
        .to_f64();
        assert!(
            out64.max_abs_diff(&out32) < 1e-3,
            "f32 non-causal drifted: {}",
            out64.max_abs_diff(&out32)
        );
    }

    #[test]
    fn head_banks_are_deterministic() {
        let est = PrfEstimator::new(3, 8, Sampling::Isotropic);
        let a = draw_head_banks(&est, 4, &mut Pcg64::seed(77));
        let b = draw_head_banks(&est, 4, &mut Pcg64::seed(77));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.omegas(), y.omegas());
        }
        // Distinct heads get distinct draws.
        assert_ne!(a[0].omegas(), a[1].omegas());
    }

    #[test]
    fn generic_f64_instantiation_borrows_inputs() {
        // multi_head at T=f64 must match the direct f64 path bitwise (the
        // head-boundary conversion is a borrow, not a round-trip).
        let mut rng = Pcg64::seed(3105);
        let (l, d, dv, m) = (19, 3, 2, 8);
        let est = PrfEstimator::new(d, m, Sampling::Isotropic);
        let banks = draw_head_banks(&est, 2, &mut Pcg64::seed(5));
        let heads: Vec<Head> = (0..2)
            .map(|_| Head {
                q: rows(l, d, 0.3, &mut rng),
                k: rows(l, d, 0.3, &mut rng),
                v: Matrix::from_rows(&rows(l, dv, 1.0, &mut rng)),
            })
            .collect();
        let cfg = EngineConfig { chunk: 4, threads: 1 };
        let multi = multi_head_causal_attention(&banks, &heads, &cfg);
        for (h, head) in heads.iter().enumerate() {
            let solo = prf_attention_chunked(
                &banks[h], &head.q, &head.k, &head.v, &cfg,
            );
            assert_eq!(multi[h], solo);
        }
    }
}
