//! PRF softmax-kernel estimators (paper Section 2–4).
//!
//! One estimate of `exp(q . k)` from `m` projection draws:
//!
//! * [`Sampling::Isotropic`] — Performer: `omega ~ N(0, I)`, unweighted
//!   (Lemma 2.1 makes this unbiased).
//! * [`Sampling::Proposal`] — importance-sampled (Lemma 3.1 / Eq. 2):
//!   `omega ~ psi`, each term weighted by `p_I(omega) / psi(omega)`.
//! * [`Sampling::DataAware`] — DARKFormer (Prop. 4.1): `omega ~ N(0, Sigma)`,
//!   unweighted. This estimates `exp(q^T Sigma k)` — the *data-aligned
//!   kernel* — and equals, in expectation, the isotropic estimator of that
//!   kernel re-weighted by `p_Sigma / p_I` (the importance-sampling
//!   equivalence the paper proves).

use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::gaussian::MultivariateGaussian;

/// Exact softmax kernel `exp(q . k)`.
pub fn exact_softmax_kernel(q: &[f64], k: &[f64]) -> f64 {
    let dot: f64 = q.iter().zip(k).map(|(a, b)| a * b).sum();
    dot.exp()
}

/// Exact data-aligned kernel `exp(q^T Sigma k)` (paper Eq. 3 estimand).
pub fn exact_sigma_kernel(q: &[f64], k: &[f64], sigma: &Matrix) -> f64 {
    let sk = sigma.matvec(k);
    let dot: f64 = q.iter().zip(&sk).map(|(a, b)| a * b).sum();
    dot.exp()
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn sq_norm(a: &[f64]) -> f64 {
    dot(a, a)
}

/// How the projection vectors are drawn.
pub enum Sampling {
    /// `omega ~ N(0, I_d)`, unweighted (Performer).
    Isotropic,
    /// `omega ~ proposal`, importance-weighted by `p_I / proposal`
    /// (Lemma 3.1's estimator; with the Theorem 3.2 proposal this is the
    /// minimum-variance scheme).
    Proposal(MultivariateGaussian),
    /// `omega ~ N(0, Sigma)`, unweighted — estimates `exp(q^T Sigma k)`
    /// (DARKFormer's data-aligned kernel).
    DataAware(MultivariateGaussian),
}

/// A PRF estimator with a fixed feature budget `m`.
pub struct PrfEstimator {
    pub m: usize,
    pub sampling: Sampling,
    dim: usize,
    iso: MultivariateGaussian,
}

impl PrfEstimator {
    pub fn new(dim: usize, m: usize, sampling: Sampling) -> Self {
        let iso = MultivariateGaussian::new(Matrix::identity(dim))
            .expect("identity is SPD");
        Self { m, sampling, dim, iso }
    }

    /// The `h`-factor normalizers `(a_q, a_k)` for a (q, k) pair:
    /// `a_x = ½·xᵀΣx` under `DataAware` (Eq. 3's Mahalanobis norms, Σ the
    /// sampling covariance), `a_x = ½·‖x‖²` otherwise. These are O(d²)
    /// for the data-aware arm and depend only on the pair, so every
    /// multi-draw loop hoists them out of the per-draw hot path.
    pub fn pair_normalizers(&self, q: &[f64], k: &[f64]) -> (f64, f64) {
        match &self.sampling {
            Sampling::Isotropic | Sampling::Proposal(_) => {
                (0.5 * sq_norm(q), 0.5 * sq_norm(k))
            }
            Sampling::DataAware(ps) => {
                let sigma = ps.cov();
                (
                    0.5 * dot(q, &sigma.matvec(q)),
                    0.5 * dot(k, &sigma.matvec(k)),
                )
            }
        }
    }

    /// Log importance weight `ln(p_I(ω) / ψ(ω))` of Lemma 3.1 — `0` for
    /// the unweighted (isotropic / data-aware) schemes.
    pub fn log_weight(&self, omega: &[f64]) -> f64 {
        match &self.sampling {
            Sampling::Proposal(psi) => {
                self.iso.log_density(omega) - psi.log_density(omega)
            }
            Sampling::Isotropic | Sampling::DataAware(_) => 0.0,
        }
    }

    /// Single-draw integrand `Z(q, k, omega)` of Lemma 2.1 (including the
    /// importance weight when applicable).
    ///
    /// For `DataAware`, the `h` factors use the Mahalanobis norms
    /// `q^T Sigma q`, `k^T Sigma k` (Eq. 3) so the estimator is unbiased
    /// for the data-aligned kernel. This convenience form recomputes the
    /// normalizers on every call; draw loops should compute them once via
    /// [`PrfEstimator::pair_normalizers`] and use
    /// [`PrfEstimator::single_term_normalized`].
    pub fn single_term(&self, q: &[f64], k: &[f64], omega: &[f64]) -> f64 {
        let (aq, ak) = self.pair_normalizers(q, k);
        self.single_term_normalized(q, k, omega, aq, ak)
    }

    /// [`PrfEstimator::single_term`] with the pair normalizers precomputed:
    /// O(d) per draw for every sampling mode (the O(d²) Mahalanobis norms
    /// are paid once per pair, not once per draw).
    pub fn single_term_normalized(
        &self,
        q: &[f64],
        k: &[f64],
        omega: &[f64],
        aq: f64,
        ak: f64,
    ) -> f64 {
        match &self.sampling {
            Sampling::Proposal(psi) => {
                let w =
                    (self.iso.log_density(omega) - psi.log_density(omega)).exp();
                w * (dot(omega, q) - aq).exp() * (dot(omega, k) - ak).exp()
            }
            Sampling::Isotropic | Sampling::DataAware(_) => {
                (dot(omega, q) - aq).exp() * (dot(omega, k) - ak).exp()
            }
        }
    }

    fn draw(&self, rng: &mut Pcg64) -> Vec<f64> {
        match &self.sampling {
            Sampling::Isotropic => self.iso.sample(rng),
            Sampling::Proposal(psi) => psi.sample(rng),
            Sampling::DataAware(ps) => ps.sample(rng),
        }
    }

    /// The estimand this estimator is unbiased for.
    pub fn target(&self, q: &[f64], k: &[f64]) -> f64 {
        match &self.sampling {
            Sampling::Isotropic | Sampling::Proposal(_) => {
                exact_softmax_kernel(q, k)
            }
            Sampling::DataAware(ps) => exact_sigma_kernel(q, k, ps.cov()),
        }
    }

    /// One m-sample estimate `kappa_hat(q, k)` (Eq. 2 / Eq. 4).
    ///
    /// This is the scalar oracle the batched engine
    /// ([`crate::rfa::features::FeatureBank`]) is property-tested against;
    /// it draws `m` omegas sequentially from `rng`.
    pub fn estimate(&self, q: &[f64], k: &[f64], rng: &mut Pcg64) -> f64 {
        let (aq, ak) = self.pair_normalizers(q, k);
        let mut acc = 0.0;
        for _ in 0..self.m {
            let omega = self.draw(rng);
            acc += self.single_term_normalized(q, k, &omega, aq, ak);
        }
        acc / self.m as f64
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::gaussian::anisotropic_covariance;

    /// Mean of many independent estimates; tolerance scales with the
    /// empirical std error.
    fn mc_mean(
        est: &PrfEstimator,
        q: &[f64],
        k: &[f64],
        reps: usize,
        rng: &mut Pcg64,
    ) -> (f64, f64) {
        let vals: Vec<f64> =
            (0..reps).map(|_| est.estimate(q, k, rng)).collect();
        let mean = vals.iter().sum::<f64>() / reps as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
            / (reps - 1) as f64;
        (mean, (var / reps as f64).sqrt())
    }

    #[test]
    fn isotropic_prf_is_unbiased() {
        let mut rng = Pcg64::seed(101);
        let q = vec![0.3, -0.2, 0.1, 0.4];
        let k = vec![-0.1, 0.2, 0.3, -0.2];
        let est = PrfEstimator::new(4, 64, Sampling::Isotropic);
        let (mean, se) = mc_mean(&est, &q, &k, 4000, &mut rng);
        let exact = exact_softmax_kernel(&q, &k);
        assert!(
            (mean - exact).abs() < 5.0 * se + 1e-9,
            "mean={mean} exact={exact} se={se}"
        );
    }

    #[test]
    fn importance_weighted_estimator_is_unbiased_for_softmax() {
        let mut rng = Pcg64::seed(102);
        let q = vec![0.2, 0.1, -0.3];
        let k = vec![0.1, -0.2, 0.2];
        let cov = anisotropic_covariance(3, 1.3, 0.5, &mut rng);
        let psi = MultivariateGaussian::new(cov).unwrap();
        let est = PrfEstimator::new(3, 64, Sampling::Proposal(psi));
        let (mean, se) = mc_mean(&est, &q, &k, 4000, &mut rng);
        let exact = exact_softmax_kernel(&q, &k);
        assert!(
            (mean - exact).abs() < 5.0 * se + 1e-9,
            "mean={mean} exact={exact} se={se}"
        );
    }

    #[test]
    fn data_aware_estimator_is_unbiased_for_sigma_kernel() {
        let mut rng = Pcg64::seed(103);
        let q = vec![0.25, -0.15, 0.2];
        let k = vec![-0.05, 0.3, 0.1];
        let sigma = anisotropic_covariance(3, 0.8, 0.6, &mut rng);
        let ps = MultivariateGaussian::new(sigma.clone()).unwrap();
        let est = PrfEstimator::new(3, 64, Sampling::DataAware(ps));
        let (mean, se) = mc_mean(&est, &q, &k, 4000, &mut rng);
        let exact = exact_sigma_kernel(&q, &k, &sigma);
        assert!(
            (mean - exact).abs() < 5.0 * se + 1e-9,
            "mean={mean} exact={exact} se={se}"
        );
    }

    #[test]
    fn sigma_identity_reduces_to_softmax_kernel() {
        let q = vec![0.4, -0.2];
        let k = vec![0.1, 0.3];
        let exact = exact_softmax_kernel(&q, &k);
        let viaid = exact_sigma_kernel(&q, &k, &Matrix::identity(2));
        assert!((exact - viaid).abs() < 1e-14);
    }

    #[test]
    fn isotropic_single_term_closed_form_second_moment() {
        // E[Z^2] = exp(2|q+k|^2 - |q|^2 - |k|^2): validate the estimator
        // plumbing against the analytic moment used in Appendix A.
        let mut rng = Pcg64::seed(104);
        let q = vec![0.2, 0.1];
        let k = vec![-0.1, 0.15];
        let est = PrfEstimator::new(2, 1, Sampling::Isotropic);
        let n = 400_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let omega = est.iso.sample(&mut rng);
            acc += est.single_term(&q, &k, &omega).powi(2);
        }
        let emp = acc / n as f64;
        let qk: Vec<f64> = q.iter().zip(&k).map(|(a, b)| a + b).collect();
        let analytic =
            (2.0 * sq_norm(&qk) - sq_norm(&q) - sq_norm(&k)).exp();
        assert!(
            (emp - analytic).abs() / analytic < 0.02,
            "emp={emp} analytic={analytic}"
        );
    }
}
