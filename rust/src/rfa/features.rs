//! Batched PRF feature-map engine: one shared draw bank, whole-matrix
//! feature maps, kernel grams as a single contraction.
//!
//! The scalar oracle [`PrfEstimator::estimate`] redraws `m` omegas per
//! (q, k) pair and pays two O(d²) Mahalanobis norms *per draw* in the
//! data-aware arm. This module restructures the same mathematics around a
//! [`FeatureBank`]:
//!
//! * the `n×d` projection bank `Ω` is drawn **once** and shared across
//!   every query/key (the structure attention actually uses — Performer
//!   redraws per forward pass, not per pair);
//! * Gaussian draws are materialized as one flat standard-normal matrix
//!   and pushed through the covariance's Cholesky factor as a single
//!   `Z·Lᵀ` matmul instead of per-draw matvecs;
//! * the per-row normalizers `a_x = ½·xᵀΣx` are computed once per vector
//!   (O(d²)) rather than once per draw (O(n·d²));
//! * positive feature matrices `Φ(X) ∈ R^{L×n}` come out of one `X·Ωᵀ`
//!   contraction plus a row-wise exp, and kernel grams
//!   `K̂ = Φ(Q)·Φ(K)ᵀ / n` are a single [`Matrix::matmul_transb`];
//! * both are generic over the storage precision
//!   ([`FeatureBank::feature_matrix_t`] / [`FeatureBank::gram_t`] on any
//!   [`Scalar`]): the contraction runs at storage width, the exponent in
//!   [`Scalar::Accum`] (f64). `feature_matrix`/`feature_matrix32` and
//!   `gram`/`gram32` are the f64/f32 instantiations.
//!
//! With a bank drawn from the same seed, [`FeatureBank::estimate`]
//! reproduces the scalar oracle to floating-point noise for all three
//! [`Sampling`] modes — the equivalence property `rust/tests/rfa_batch.rs`
//! pins down.

use crate::linalg::{Mat, Matrix, Matrix32, Scalar};
use crate::rng::{GaussianExt, Pcg64};

use super::estimators::{PrfEstimator, Sampling};
use super::gaussian::MultivariateGaussian;
use super::orthogonal::orthogonal_gaussian_block;

/// A shared bank of `n` projection draws for one estimator geometry.
pub struct FeatureBank {
    /// `n×d` draw matrix Ω; row `i` is one projection vector ω_i.
    omegas: Matrix,
    /// Importance weights `w_i = p_I(ω_i)/ψ(ω_i)` (all 1 when unweighted).
    weights: Vec<f64>,
    /// `√w_i`, split symmetrically across the Φ(Q)/Φ(K) factors so the
    /// gram contraction recovers `w_i` per term.
    sqrt_weights: Vec<f64>,
    /// Σ for the data-aware normalizer; `None` means `a_x = ½‖x‖²`.
    norm_sigma: Option<Matrix>,
}

impl FeatureBank {
    /// Draw a bank of `est.m` features matching `est`'s sampling law.
    ///
    /// Consumes `rng` exactly like `est.m` sequential scalar draws, so a
    /// bank seeded identically to an [`PrfEstimator::estimate`] call
    /// reproduces its result.
    pub fn draw(est: &PrfEstimator, rng: &mut Pcg64) -> Self {
        Self::draw_n(est, est.m, rng)
    }

    /// Draw a bank of `n` features (the variance engine wants `n ≫ m`).
    pub fn draw_n(est: &PrfEstimator, n: usize, rng: &mut Pcg64) -> Self {
        let d = est.dim();
        // One flat standard-normal matrix; row-major fill consumes the rng
        // in the same order as n sequential gaussian_vec(d) calls.
        Self::from_whitened(est, Matrix::from_vec(n, d, rng.gaussian_vec(n * d)))
    }

    /// Draw an `m`-feature data-aware bank directly against a covariance —
    /// the serving layer's online-resampling entry point, where each
    /// epoch's Σ̂ comes from a streaming second-moment estimate rather
    /// than a pre-built estimator.
    pub fn draw_data_aware(
        m: usize,
        gauss: MultivariateGaussian,
        rng: &mut Pcg64,
    ) -> Self {
        let d = gauss.dim();
        let est = PrfEstimator::new(d, m, Sampling::DataAware(gauss));
        Self::draw(&est, rng)
    }

    /// Block-orthogonal bank (Performer's ORF coupling) in the estimator's
    /// sampling geometry: orthogonal in the whitened space, mapped through
    /// `L` so marginals match the sampling covariance. Variance-reduced,
    /// but *not* draw-compatible with the sequential scalar oracle.
    pub fn draw_orthogonal(est: &PrfEstimator, rng: &mut Pcg64) -> Self {
        let d = est.dim();
        let rows = orthogonal_gaussian_block(d, est.m, rng);
        Self::from_whitened(est, Matrix::from_rows(&rows))
    }

    /// Build the bank from whitened draws `Z` (rows ~ the whitened law):
    /// apply the sampling covariance's `Lᵀ`, then derive per-draw
    /// importance weights and the normalizer geometry.
    fn from_whitened(est: &PrfEstimator, z: Matrix) -> Self {
        let (omegas, norm_sigma) = match &est.sampling {
            // chol(I) = I: the transform is the identity, skip the matmul.
            Sampling::Isotropic => (z, None),
            Sampling::Proposal(psi) => {
                (z.matmul(&psi.chol().transpose()), None)
            }
            Sampling::DataAware(ps) => (
                z.matmul(&ps.chol().transpose()),
                Some(ps.cov().clone()),
            ),
        };
        let weights: Vec<f64> = (0..omegas.rows())
            .map(|i| est.log_weight(omegas.row(i)).exp())
            .collect();
        let sqrt_weights = weights.iter().map(|w| w.sqrt()).collect();
        Self { omegas, weights, sqrt_weights, norm_sigma }
    }

    /// Number of draws in the bank.
    pub fn n_features(&self) -> usize {
        self.omegas.rows()
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.omegas.cols()
    }

    /// The draw matrix Ω (rows are omegas).
    pub fn omegas(&self) -> &Matrix {
        &self.omegas
    }

    /// The per-draw importance weights `w_i` (all 1 when unweighted).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// The normalizer covariance Σ (`Some` only for data-aware banks,
    /// where `a_x = ½·xᵀΣx`).
    pub fn norm_sigma(&self) -> Option<&Matrix> {
        self.norm_sigma.as_ref()
    }

    /// Effective sample size of the importance weights,
    /// `ESS = (Σw)²/Σw²` — in `(0, n]`, exactly `n` for an unweighted
    /// bank, collapsing toward 1 as a few draws dominate. A low ESS
    /// means the data-aware proposal is fighting the integrand and the
    /// m-sample average behaves like far fewer effective draws; the
    /// serving layer exports it per head as the `rfa_head_ess` gauge.
    pub fn effective_sample_size(&self) -> f64 {
        let sum: f64 = self.weights.iter().sum();
        let sum_sq: f64 = self.weights.iter().map(|w| w * w).sum();
        if sum_sq <= 0.0 {
            return 0.0;
        }
        (sum * sum) / sum_sq
    }

    /// Rebuild a bank from snapshotted parts ([`Self::omegas`],
    /// [`Self::weights`], [`Self::norm_sigma`]) — the restore half of the
    /// `rfa::serve` snapshot surface. `√w_i` is recomputed; IEEE `sqrt`
    /// is correctly rounded, so the rebuilt bank is bitwise identical to
    /// the one snapshotted.
    pub fn from_parts(
        omegas: Matrix,
        weights: Vec<f64>,
        norm_sigma: Option<Matrix>,
    ) -> Self {
        assert_eq!(omegas.rows(), weights.len(), "one weight per draw");
        if let Some(sigma) = &norm_sigma {
            assert_eq!(
                (sigma.rows(), sigma.cols()),
                (omegas.cols(), omegas.cols()),
                "norm sigma must be d×d"
            );
        }
        let sqrt_weights = weights.iter().map(|w| w.sqrt()).collect();
        Self { omegas, weights, sqrt_weights, norm_sigma }
    }

    /// Row normalizer `a_x`: `½·xᵀΣx` for data-aware banks, `½‖x‖²`
    /// otherwise. O(d²) worst case — called once per vector, never per
    /// draw.
    pub fn normalizer(&self, x: &[f64]) -> f64 {
        match &self.norm_sigma {
            Some(sigma) => {
                let sx = sigma.matvec(x);
                0.5 * x.iter().zip(&sx).map(|(a, b)| a * b).sum::<f64>()
            }
            None => 0.5 * x.iter().map(|a| a * a).sum::<f64>(),
        }
    }

    /// Positive feature matrix `Φ(X) ∈ R^{L×n}` for rows `xs` at storage
    /// precision `T`: `Φ[l,i] = √w_i · exp(ω_i·x_l − a_{x_l})`.
    ///
    /// One `X·Ωᵀ` contraction in `T` materializes every projection (the
    /// O(L·n·d) bulk, where SIMD width and memory bandwidth pay); the
    /// per-row normalizers are computed once each, in f64, and the
    /// exponent is evaluated in [`Scalar::Accum`] — it is a
    /// cancellation-sensitive difference, and getting it wrong costs
    /// *relative* error `≈ |Δ|` in every feature. Only the final feature
    /// value is rounded to `T`. On the f64 path every conversion is the
    /// identity (the bank's Ω is *borrowed*, not copied).
    pub fn feature_matrix_t<T: Scalar>(&self, xs: &[Vec<f64>]) -> Mat<T> {
        let l = xs.len();
        let d = self.dim();
        let n = self.n_features();
        let mut flat = Vec::with_capacity(l * d);
        for x in xs {
            assert_eq!(x.len(), d, "feature_matrix: row dim mismatch");
            flat.extend(x.iter().map(|&v| T::from_f64(v)));
        }
        let x_mat = Mat::from_vec(l, d, flat);
        let omegas_t = T::mat_from_f64(&self.omegas);
        // proj[l, i] = ω_i · x_l
        let mut proj = x_mat.matmul_transb(&omegas_t);
        for (li, x) in xs.iter().enumerate() {
            let a = self.normalizer(x);
            let row = &mut proj.data_mut()[li * n..(li + 1) * n];
            // Widen, subtract, scalar-libm exp, scale, round back to T
            // once — the dispatched feature-map finish microkernel.
            T::feature_finish(row, a, &self.sqrt_weights);
        }
        proj
    }

    /// Estimated kernel gram `K̂[i,j] ≈ κ(q_i, k_j)` for every (q, k)
    /// pair at once: `Φ(Q)·Φ(K)ᵀ / n`, a single contraction at storage
    /// precision `T`.
    pub fn gram_t<T: Scalar>(
        &self,
        qs: &[Vec<f64>],
        ks: &[Vec<f64>],
    ) -> Mat<T> {
        let phi_q = self.feature_matrix_t::<T>(qs);
        let phi_k = self.feature_matrix_t::<T>(ks);
        let inv_n = T::ONE / T::from_f64(self.n_features() as f64);
        phi_q.matmul_transb(&phi_k).scale(inv_n)
    }

    /// [`Self::feature_matrix_t`] at the default f64 precision.
    pub fn feature_matrix(&self, xs: &[Vec<f64>]) -> Matrix {
        self.feature_matrix_t::<f64>(xs)
    }

    /// [`Self::gram_t`] at the default f64 precision.
    pub fn gram(&self, qs: &[Vec<f64>], ks: &[Vec<f64>]) -> Matrix {
        self.gram_t::<f64>(qs, ks)
    }

    /// [`Self::feature_matrix_t`] on the f32 SIMD hot path.
    pub fn feature_matrix32(&self, xs: &[Vec<f64>]) -> Matrix32 {
        self.feature_matrix_t::<f32>(xs)
    }

    /// [`Self::gram_t`] on the f32 SIMD hot path.
    pub fn gram32(&self, qs: &[Vec<f64>], ks: &[Vec<f64>]) -> Matrix32 {
        self.gram_t::<f32>(qs, ks)
    }

    /// Per-draw integrand values `Z_i(q, k)` — the variance engine's
    /// input. Normalizers are computed once per call; each draw costs two
    /// O(d) dots.
    pub fn single_terms(&self, q: &[f64], k: &[f64]) -> Vec<f64> {
        let aq = self.normalizer(q);
        let ak = self.normalizer(k);
        (0..self.n_features())
            .map(|i| {
                let omega = self.omegas.row(i);
                let oq: f64 = omega.iter().zip(q).map(|(a, b)| a * b).sum();
                let ok: f64 = omega.iter().zip(k).map(|(a, b)| a * b).sum();
                self.weights[i] * (oq - aq).exp() * (ok - ak).exp()
            })
            .collect()
    }

    /// Bank-shared m-sample estimate of the kernel for one pair; equal to
    /// the scalar oracle when the bank was drawn from the same seed.
    pub fn estimate(&self, q: &[f64], k: &[f64]) -> f64 {
        let terms = self.single_terms(q, k);
        terms.iter().sum::<f64>() / terms.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::estimators::{exact_sigma_kernel, exact_softmax_kernel};
    use crate::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-300)
    }

    #[test]
    fn bank_estimate_matches_scalar_oracle_isotropic() {
        let est = PrfEstimator::new(4, 32, Sampling::Isotropic);
        let q = vec![0.3, -0.2, 0.1, 0.4];
        let k = vec![-0.1, 0.2, 0.3, -0.2];
        let mut rng_bank = Pcg64::seed(901);
        let bank = FeatureBank::draw(&est, &mut rng_bank);
        let mut rng_scalar = Pcg64::seed(901);
        let scalar = est.estimate(&q, &k, &mut rng_scalar);
        assert!(
            rel_err(bank.estimate(&q, &k), scalar) < 1e-12,
            "batched={} scalar={scalar}",
            bank.estimate(&q, &k)
        );
    }

    #[test]
    fn gram_rows_match_per_pair_estimates() {
        let mut rng = Pcg64::seed(902);
        let sigma = anisotropic_covariance(3, 0.7, 0.5, &mut rng);
        let est = PrfEstimator::new(
            3,
            16,
            Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
        );
        let qs: Vec<Vec<f64>> =
            (0..5).map(|_| rng.gaussian_vec(3)).collect();
        let ks: Vec<Vec<f64>> =
            (0..4).map(|_| rng.gaussian_vec(3)).collect();
        let bank = FeatureBank::draw(&est, &mut rng);
        let gram = bank.gram(&qs, &ks);
        for (i, q) in qs.iter().enumerate() {
            for (j, k) in ks.iter().enumerate() {
                let direct = bank.estimate(q, k);
                assert!(
                    rel_err(gram[(i, j)], direct) < 1e-10,
                    "gram[{i},{j}]={} direct={direct}",
                    gram[(i, j)]
                );
            }
        }
    }

    #[test]
    fn bank_is_unbiased_for_its_target() {
        // Average fresh banks: isotropic → softmax kernel, data-aware →
        // Sigma kernel.
        let mut rng = Pcg64::seed(903);
        let q = vec![0.25, -0.15, 0.2];
        let k = vec![-0.05, 0.3, 0.1];
        let sigma = anisotropic_covariance(3, 0.8, 0.6, &mut rng);
        let cases: Vec<(PrfEstimator, f64)> = vec![
            (
                PrfEstimator::new(3, 8, Sampling::Isotropic),
                exact_softmax_kernel(&q, &k),
            ),
            (
                PrfEstimator::new(
                    3,
                    8,
                    Sampling::DataAware(
                        MultivariateGaussian::new(sigma.clone()).unwrap(),
                    ),
                ),
                exact_sigma_kernel(&q, &k, &sigma),
            ),
        ];
        for (est, target) in &cases {
            let reps = 6000;
            let vals: Vec<f64> = (0..reps)
                .map(|_| FeatureBank::draw(est, &mut rng).estimate(&q, &k))
                .collect();
            let mean = vals.iter().sum::<f64>() / reps as f64;
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
                / (reps - 1) as f64;
            let se = (var / reps as f64).sqrt();
            assert!(
                (mean - target).abs() < 5.0 * se + 1e-9,
                "mean={mean} target={target} se={se}"
            );
        }
    }

    #[test]
    fn orthogonal_bank_is_unbiased_for_softmax() {
        let mut rng = Pcg64::seed(904);
        let q = vec![0.3, -0.2, 0.1];
        let k = vec![-0.1, 0.25, 0.2];
        let est = PrfEstimator::new(3, 6, Sampling::Isotropic);
        let reps = 4000;
        let vals: Vec<f64> = (0..reps)
            .map(|_| {
                FeatureBank::draw_orthogonal(&est, &mut rng).estimate(&q, &k)
            })
            .collect();
        let mean = vals.iter().sum::<f64>() / reps as f64;
        let exact = exact_softmax_kernel(&q, &k);
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
            / (reps - 1) as f64;
        let se = (var / reps as f64).sqrt();
        assert!(
            (mean - exact).abs() < 5.0 * se + 1e-9,
            "mean={mean} exact={exact} se={se}"
        );
    }

    #[test]
    fn feature_matrix32_tracks_f64_path() {
        // f32 features vs the f64 reference on the same bank: the
        // projection runs in f32 (relative error ~n·d·eps32), the
        // normalizer/exp in f64, so entries agree to ~1e-5 relative.
        let mut rng = Pcg64::seed(906);
        let sigma = anisotropic_covariance(4, 0.7, 0.5, &mut rng);
        for sampling in [
            Sampling::Isotropic,
            Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
        ] {
            let est = PrfEstimator::new(4, 24, sampling);
            let bank = FeatureBank::draw(&est, &mut rng);
            let xs: Vec<Vec<f64>> = (0..9)
                .map(|_| rng.gaussian_vec(4).iter().map(|x| 0.4 * x).collect())
                .collect();
            let phi64 = bank.feature_matrix(&xs);
            let phi32 = bank.feature_matrix32(&xs).to_f64();
            for r in 0..phi64.rows() {
                for c in 0..phi64.cols() {
                    let (a, b) = (phi64[(r, c)], phi32[(r, c)]);
                    assert!(
                        rel_err(b, a) < 1e-4,
                        "phi32[{r},{c}]={b} phi64={a}"
                    );
                }
            }
            let g64 = bank.gram(&xs, &xs);
            let g32 = bank.gram32(&xs, &xs).to_f64();
            assert!(g64.max_abs_diff(&g32) < 1e-3 * g64.frobenius_norm());
        }
    }

    #[test]
    fn effective_sample_size_bounds() {
        // Unweighted (isotropic) bank: every w_i = 1 → ESS = n exactly.
        let iso = PrfEstimator::new(3, 20, Sampling::Isotropic);
        let bank = FeatureBank::draw(&iso, &mut Pcg64::seed(907));
        assert!((bank.effective_sample_size() - 20.0).abs() < 1e-12);

        // Weighted bank: 1 ≤ ESS ≤ n, and a hand-built degenerate
        // weight vector collapses toward 1.
        let mut rng = Pcg64::seed(908);
        let sigma = anisotropic_covariance(3, 0.8, 0.6, &mut rng);
        let da = PrfEstimator::new(
            3,
            20,
            Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
        );
        let ess = FeatureBank::draw(&da, &mut rng).effective_sample_size();
        assert!(ess >= 1.0 && ess <= 20.0, "ess={ess}");

        let skewed = FeatureBank::from_parts(
            Matrix::from_vec(2, 1, vec![0.0, 0.0]),
            vec![1.0, 1e-9],
            None,
        );
        assert!((skewed.effective_sample_size() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn feature_matrix_shapes() {
        let est = PrfEstimator::new(5, 12, Sampling::Isotropic);
        let mut rng = Pcg64::seed(905);
        let bank = FeatureBank::draw(&est, &mut rng);
        let xs: Vec<Vec<f64>> = (0..7).map(|_| rng.gaussian_vec(5)).collect();
        let phi = bank.feature_matrix(&xs);
        assert_eq!((phi.rows(), phi.cols()), (7, 12));
        assert_eq!(bank.n_features(), 12);
        assert_eq!(bank.dim(), 5);
        assert!(phi.data().iter().all(|v| *v > 0.0), "features are positive");
    }
}
