//! Multivariate Gaussian sampling and anisotropic covariance constructors.

use crate::linalg::Matrix;
use crate::rng::{GaussianExt, Pcg64};

/// `N(0, Cov)` sampler backed by a Cholesky factor: `x = L z`, `z ~ N(0, I)`.
#[derive(Debug, Clone)]
pub struct MultivariateGaussian {
    chol: Matrix,
    cov: Matrix,
}

impl MultivariateGaussian {
    /// Build from a symmetric positive-definite covariance.
    pub fn new(cov: Matrix) -> Option<Self> {
        let chol = cov.cholesky()?;
        Some(Self { chol, cov })
    }

    /// Build from a covariance *and* its already-known lower Cholesky
    /// factor, skipping the O(d³) factorization. The caller owns the
    /// invariant `cov = chol·cholᵀ` (with `chol` lower triangular,
    /// positive diagonal) — the serving layer's maintained-factor
    /// resample path produces exactly this pair in O(d²) per epoch via
    /// [`crate::linalg::Matrix::cholesky_update_rank1`].
    pub fn from_parts(cov: Matrix, chol: Matrix) -> Self {
        assert_eq!(cov.rows(), cov.cols(), "covariance must be square");
        assert_eq!(
            (chol.rows(), chol.cols()),
            (cov.rows(), cov.cols()),
            "factor/covariance shape mismatch"
        );
        Self { chol, cov }
    }

    pub fn dim(&self) -> usize {
        self.cov.rows()
    }

    pub fn cov(&self) -> &Matrix {
        &self.cov
    }

    /// Lower-triangular Cholesky factor `L` with `Cov = L·Lᵀ`. Exposed so
    /// batched samplers ([`crate::rfa::features::FeatureBank`]) can draw a
    /// whole bank as one `Z·Lᵀ` contraction instead of per-draw matvecs.
    pub fn chol(&self) -> &Matrix {
        &self.chol
    }

    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let z = rng.gaussian_vec(self.dim());
        self.chol.matvec(&z)
    }

    /// Log-density up to the `-d/2 log(2 pi)` constant-free full form.
    pub fn log_density(&self, x: &[f64]) -> f64 {
        let d = self.dim() as f64;
        let y = self.cov.solve_spd(x).expect("covariance is SPD");
        let quad: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let logdet = 2.0
            * (0..self.dim())
                .map(|i| self.chol[(i, i)].ln())
                .sum::<f64>();
        -0.5 * (quad + logdet + d * (2.0 * std::f64::consts::PI).ln())
    }
}

/// Anisotropic covariance with eigenvalues interpolating between
/// `base * (1 - eps)` and `base * (1 + eps)` (linear ramp), rotated by a
/// random orthogonal basis so anisotropy is not axis-aligned.
///
/// `eps = 0` gives `base * I` (the isotropic control); larger `eps` gives a
/// wider spread — the knob the paper's variance experiments turn.
pub fn anisotropic_covariance(
    d: usize,
    base: f64,
    eps: f64,
    rng: &mut Pcg64,
) -> Matrix {
    assert!((0.0..1.0).contains(&eps), "eps must be in [0, 1)");
    let eigvals: Vec<f64> = (0..d)
        .map(|i| {
            let t = if d > 1 { i as f64 / (d - 1) as f64 } else { 0.5 };
            base * (1.0 - eps + 2.0 * eps * t)
        })
        .collect();
    let q = random_orthogonal(d, rng);
    q.matmul(&Matrix::diag(&eigvals)).matmul(&q.transpose())
}

/// Random orthogonal matrix via Gram–Schmidt on a Gaussian matrix
/// (Haar-ish; exact Haar is not required for these experiments).
pub fn random_orthogonal(d: usize, rng: &mut Pcg64) -> Matrix {
    let mut q = Matrix::zeros(d, d);
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(d);
    for _ in 0..d {
        let mut v = rng.gaussian_vec(d);
        for u in &cols {
            let dot: f64 = v.iter().zip(u).map(|(a, b)| a * b).sum();
            for (vi, ui) in v.iter_mut().zip(u) {
                *vi -= dot * ui;
            }
        }
        let norm: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
        assert!(norm > 1e-12, "degenerate Gram-Schmidt draw");
        for vi in &mut v {
            *vi /= norm;
        }
        cols.push(v);
    }
    for (c, col) in cols.iter().enumerate() {
        for (r, &val) in col.iter().enumerate() {
            q[(r, c)] = val;
        }
    }
    q
}

/// Streaming (unnormalized) second-moment accumulator `C = Σ_j x_j·x_jᵀ`,
/// folded one rank-1 update per observation — the online estimate the
/// serving layer's bank resampling ([`crate::rfa::serve`]) tracks per
/// head. `C` and the count are plain f64 sums in observation order, so
/// the accumulator is bit-deterministic for a given stream and snapshots
/// exactly (see [`Self::from_parts`]).
#[derive(Debug, Clone)]
pub struct SecondMomentAccumulator {
    sum: Matrix,
    count: u64,
}

impl SecondMomentAccumulator {
    /// Fresh all-zero accumulator for `d`-dimensional observations.
    pub fn new(d: usize) -> Self {
        Self { sum: Matrix::zeros(d, d), count: 0 }
    }

    /// Rebuild from snapshotted parts ([`Self::sum`], [`Self::count`]) —
    /// bitwise, since the state is exactly these two fields.
    pub fn from_parts(sum: Matrix, count: u64) -> Self {
        assert_eq!(sum.rows(), sum.cols(), "second moment must be square");
        Self { sum, count }
    }

    pub fn dim(&self) -> usize {
        self.sum.rows()
    }

    /// Observations folded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The unnormalized running sum `Σ_j x_j·x_jᵀ`.
    pub fn sum(&self) -> &Matrix {
        &self.sum
    }

    /// Fold one observation: `C += x·xᵀ` (rank-1, exploiting symmetry).
    pub fn accumulate(&mut self, x: &[f64]) {
        let d = self.dim();
        assert_eq!(x.len(), d, "observation dim mismatch");
        for i in 0..d {
            let xi = x[i];
            for j in i..d {
                let v = xi * x[j];
                self.sum[(i, j)] += v;
                if j != i {
                    self.sum[(j, i)] += v;
                }
            }
        }
        self.count += 1;
    }

    /// Shrinkage estimate of the second moment:
    /// `Σ̂ = (1-λ)·C/count + λ·I`, which is symmetric positive definite
    /// for any `λ ∈ (0, 1]` (the raw `C/count` is PSD, the identity floor
    /// makes it PD even before `count ≥ d` observations arrive).
    pub fn shrunk_estimate(&self, lambda: f64) -> Matrix {
        assert!(
            lambda > 0.0 && lambda <= 1.0,
            "shrinkage must be in (0, 1], got {lambda}"
        );
        let d = self.dim();
        let mut est = if self.count == 0 {
            Matrix::zeros(d, d)
        } else {
            self.sum.scale((1.0 - lambda) / self.count as f64)
        };
        for i in 0..d {
            est[(i, i)] += lambda;
        }
        est
    }
}

/// Empirical covariance of a sample set (rows are observations).
pub fn empirical_covariance(samples: &[Vec<f64>]) -> Matrix {
    let n = samples.len();
    assert!(n > 1);
    let d = samples[0].len();
    let mut mean = vec![0.0; d];
    for s in samples {
        for (m, &x) in mean.iter_mut().zip(s) {
            *m += x;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut cov = Matrix::zeros(d, d);
    for s in samples {
        for i in 0..d {
            for j in 0..d {
                cov[(i, j)] += (s[i] - mean[i]) * (s[j] - mean[j]);
            }
        }
    }
    cov.scale(1.0 / (n as f64 - 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_match_requested_covariance() {
        let mut rng = Pcg64::seed(17);
        let cov = anisotropic_covariance(4, 0.2, 0.8, &mut rng);
        let g = MultivariateGaussian::new(cov.clone()).unwrap();
        let samples: Vec<Vec<f64>> =
            (0..60_000).map(|_| g.sample(&mut rng)).collect();
        let emp = empirical_covariance(&samples);
        assert!(
            emp.max_abs_diff(&cov) < 0.02,
            "diff={}",
            emp.max_abs_diff(&cov)
        );
    }

    #[test]
    fn isotropic_at_eps_zero() {
        let mut rng = Pcg64::seed(5);
        let cov = anisotropic_covariance(6, 0.3, 0.0, &mut rng);
        assert!(cov.max_abs_diff(&Matrix::identity(6).scale(0.3)) < 1e-10);
    }

    #[test]
    fn orthogonal_matrix_is_orthogonal() {
        let mut rng = Pcg64::seed(23);
        let q = random_orthogonal(8, &mut rng);
        let g = q.transpose().matmul(&q);
        assert!(g.max_abs_diff(&Matrix::identity(8)) < 1e-10);
    }

    #[test]
    fn eigenvalue_spread_follows_eps() {
        let mut rng = Pcg64::seed(31);
        let cov = anisotropic_covariance(5, 0.2, 0.6, &mut rng);
        let (vals, _) = cov.jacobi_eigen();
        let max = vals[0];
        let min = *vals.last().unwrap();
        assert!((max - 0.2 * 1.6).abs() < 1e-9);
        assert!((min - 0.2 * 0.4).abs() < 1e-9);
    }

    #[test]
    fn second_moment_accumulator_matches_direct_sum() {
        let mut rng = Pcg64::seed(91);
        let d = 5;
        let xs: Vec<Vec<f64>> =
            (0..37).map(|_| rng.gaussian_vec(d)).collect();
        let mut acc = SecondMomentAccumulator::new(d);
        for x in &xs {
            acc.accumulate(x);
        }
        let mut direct = Matrix::zeros(d, d);
        for x in &xs {
            for i in 0..d {
                for j in 0..d {
                    direct[(i, j)] += x[i] * x[j];
                }
            }
        }
        assert_eq!(acc.count(), 37);
        // Same order of adds per entry → bitwise, not approximately.
        for i in 0..d {
            for j in 0..d {
                assert_eq!(acc.sum()[(i, j)], direct[(i, j)]);
                assert_eq!(acc.sum()[(i, j)], acc.sum()[(j, i)]);
            }
        }
        let rebuilt =
            SecondMomentAccumulator::from_parts(acc.sum().clone(), 37);
        assert_eq!(rebuilt.sum(), acc.sum());
        assert_eq!(rebuilt.count(), acc.count());
    }

    #[test]
    fn shrunk_estimate_is_spd_even_underdetermined() {
        let mut rng = Pcg64::seed(92);
        let d = 6;
        // Fewer observations than dimensions: the raw C/count is rank
        // deficient, but the identity floor must keep Σ̂ Cholesky-able.
        let mut acc = SecondMomentAccumulator::new(d);
        for _ in 0..3 {
            acc.accumulate(&rng.gaussian_vec(d));
        }
        for lambda in [1e-3, 0.05, 1.0] {
            let est = acc.shrunk_estimate(lambda);
            assert!(
                MultivariateGaussian::new(est).is_some(),
                "λ={lambda}: shrunk estimate is not SPD"
            );
        }
        // Even a fresh accumulator gives λ·I — still SPD.
        let empty = SecondMomentAccumulator::new(d);
        assert!(
            MultivariateGaussian::new(empty.shrunk_estimate(0.05)).is_some()
        );
    }

    #[test]
    fn log_density_standard_normal_at_origin() {
        let g = MultivariateGaussian::new(Matrix::identity(2)).unwrap();
        let expected = -(2.0 * std::f64::consts::PI).ln();
        assert!((g.log_density(&[0.0, 0.0]) - expected).abs() < 1e-12);
    }
}
