//! Mahalanobis geometry and whitening (paper Appendix C).
//!
//! `exp(q^T Sigma k)` is, up to scaling, a Gaussian kernel in the
//! Mahalanobis distance `||q - k||_Sigma`; with `Sigma = Lambda^{-1}` the
//! re-embedding `x -> Lambda^{-1/2} x` whitens inputs whose covariance is
//! `Lambda` (Proposition C.1). These are the identities DARKFormer's
//! learned `M` exploits; here they are implemented and testable directly.

use crate::linalg::Matrix;

/// `||x||_Sigma^2 = x^T Sigma x`.
pub fn mahalanobis_sq_norm(x: &[f64], sigma: &Matrix) -> f64 {
    let sx = sigma.matvec(x);
    x.iter().zip(&sx).map(|(a, b)| a * b).sum()
}

/// `||x - y||_Sigma^2`.
pub fn mahalanobis_sq_dist(x: &[f64], y: &[f64], sigma: &Matrix) -> f64 {
    let diff: Vec<f64> = x.iter().zip(y).map(|(a, b)| a - b).collect();
    mahalanobis_sq_norm(&diff, sigma)
}

/// `q^T Sigma k` via the polarization identity
/// `1/2 (|q|_S^2 + |k|_S^2 - |q - k|_S^2)` — the decomposition behind the
/// paper's "Gaussian kernel in Mahalanobis distance" reading.
pub fn sigma_inner_via_polarization(
    q: &[f64],
    k: &[f64],
    sigma: &Matrix,
) -> f64 {
    0.5 * (mahalanobis_sq_norm(q, sigma) + mahalanobis_sq_norm(k, sigma)
        - mahalanobis_sq_dist(q, k, sigma))
}

/// Symmetric positive-definite square root via eigendecomposition.
pub fn spd_sqrt(a: &Matrix) -> Matrix {
    let (vals, vecs) = a.jacobi_eigen();
    let sqrt_vals: Vec<f64> = vals
        .iter()
        .map(|&v| {
            assert!(v > 0.0, "spd_sqrt needs positive eigenvalues, got {v}");
            v.sqrt()
        })
        .collect();
    vecs.matmul(&Matrix::diag(&sqrt_vals)).matmul(&vecs.transpose())
}

/// Whitening transform `M = Lambda^{-1/2}` for input covariance `Lambda`.
pub fn whitening_transform(lambda: &Matrix) -> Option<Matrix> {
    let inv = lambda.inverse_spd()?;
    Some(spd_sqrt(&inv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::gaussian::{
        anisotropic_covariance, empirical_covariance, MultivariateGaussian,
    };
    use crate::rng::Pcg64;

    #[test]
    fn polarization_identity_matches_direct_inner() {
        let mut rng = Pcg64::seed(61);
        let sigma = anisotropic_covariance(4, 1.0, 0.5, &mut rng);
        let q = vec![0.3, -0.2, 0.5, 0.1];
        let k = vec![-0.1, 0.4, 0.2, -0.3];
        let direct: f64 = {
            let sk = sigma.matvec(&k);
            q.iter().zip(&sk).map(|(a, b)| a * b).sum()
        };
        let polar = sigma_inner_via_polarization(&q, &k, &sigma);
        assert!((direct - polar).abs() < 1e-12);
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Pcg64::seed(62);
        let a = anisotropic_covariance(5, 0.7, 0.6, &mut rng);
        let s = spd_sqrt(&a);
        assert!(s.matmul(&s).max_abs_diff(&a) < 1e-9);
    }

    /// Proposition C.1: Cov(M q) = I for M = Lambda^{-1/2}.
    #[test]
    fn whitening_produces_isotropic_covariance() {
        let mut rng = Pcg64::seed(63);
        let lambda = anisotropic_covariance(3, 0.5, 0.7, &mut rng);
        let m = whitening_transform(&lambda).unwrap();
        let dist = MultivariateGaussian::new(lambda).unwrap();
        let samples: Vec<Vec<f64>> = (0..50_000)
            .map(|_| m.matvec(&dist.sample(&mut rng)))
            .collect();
        let emp = empirical_covariance(&samples);
        assert!(
            emp.max_abs_diff(&Matrix::identity(3)) < 0.03,
            "emp={emp:?}"
        );
    }

    /// Proposition C.1's spectral form: |q - k|^2_{Lambda^{-1}} equals
    /// sum_i delta_i^2 / lambda_i in Lambda's eigenbasis.
    #[test]
    fn mahalanobis_distance_in_eigenbasis() {
        let mut rng = Pcg64::seed(64);
        let lambda = anisotropic_covariance(4, 0.6, 0.5, &mut rng);
        let inv = lambda.inverse_spd().unwrap();
        let q = vec![0.2, 0.5, -0.1, 0.3];
        let k = vec![-0.2, 0.1, 0.4, 0.0];
        let direct = mahalanobis_sq_dist(&q, &k, &inv);

        let (vals, vecs) = lambda.jacobi_eigen();
        let diff: Vec<f64> = q.iter().zip(&k).map(|(a, b)| a - b).collect();
        let delta = vecs.transpose().matvec(&diff);
        let spectral: f64 =
            delta.iter().zip(&vals).map(|(d, l)| d * d / l).sum();
        assert!((direct - spectral).abs() < 1e-9);
    }
}
