//! Random-feature-attention mathematics in pure Rust.
//!
//! This module reproduces the paper's Section 3 / Appendix A analysis
//! numerically, independent of the JAX stack:
//!
//! * [`gaussian`] — multivariate Gaussians with arbitrary covariance
//!   (Cholesky sampling), anisotropic covariance constructors.
//! * [`estimators`] — the PRF softmax-kernel estimators: isotropic
//!   (Performer), data-aware `N(0, Sigma)` (DARKFormer), and explicitly
//!   importance-weighted (Lemma 3.1 form).
//! * [`proposal`] — the closed-form optimal proposal of Theorem 3.2,
//!   `Sigma* = (I + 2L)(I - 2L)^{-1}`, plus its validity condition.
//! * [`variance`] — Monte-Carlo and closed-form variance evaluation; the
//!   engine behind the `variance` bench and `exp variance` table.
//! * [`mahalanobis`] — Mahalanobis geometry and whitening (App. C).
//! * [`orthogonal`] — block-orthogonal feature draws (Performer's ORF
//!   coupling; extension ablation).
//!
//! Everything here is f64 and deliberately estimator-shaped rather than
//! attention-shaped: it validates the paper's *theory* claims, while the
//! AOT/JAX stack validates the *system* claims.

pub mod estimators;
pub mod gaussian;
pub mod mahalanobis;
pub mod orthogonal;
pub mod proposal;
pub mod variance;

pub use estimators::{exact_softmax_kernel, PrfEstimator, Sampling};
pub use gaussian::MultivariateGaussian;
pub use proposal::{optimal_proposal, proposal_is_valid};
