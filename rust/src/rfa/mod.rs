//! Random-feature-attention mathematics in pure Rust.
//!
//! This module reproduces the paper's Section 3 / Appendix A analysis
//! numerically, independent of the JAX stack:
//!
//! * [`gaussian`] — multivariate Gaussians with arbitrary covariance
//!   (Cholesky sampling), anisotropic covariance constructors.
//! * [`estimators`] — the PRF softmax-kernel estimators: isotropic
//!   (Performer), data-aware `N(0, Sigma)` (DARKFormer), and explicitly
//!   importance-weighted (Lemma 3.1 form). The scalar
//!   [`PrfEstimator::estimate`] is the oracle every batched path is
//!   property-tested against.
//! * [`features`] — the batched feature-map engine: one shared `n×d`
//!   draw bank per estimator ([`features::FeatureBank`], optionally
//!   block-orthogonal), positive feature matrices `Φ(X) ∈ R^{L×n}` with
//!   per-row normalizers computed once per vector, and kernel grams as a
//!   single `Φ(Q)·Φ(K)ᵀ` contraction — generic over the
//!   [`crate::linalg::Scalar`] storage precision
//!   (`feature_matrix_t`/`gram_t`; the exponent always runs in
//!   `Scalar::Accum`).
//! * [`attention`] — pure-Rust linear-attention forwards over the
//!   feature maps: non-causal and causal (FAVOR+-style running
//!   prefix-sum state), plus an exact masked-softmax reference (the
//!   causal reference computes only the surviving lower-triangle
//!   scores).
//! * [`engine`] — the serving-scale forward: chunk-blocked causal
//!   evaluation (dense intra-chunk grams + per-chunk state folds,
//!   streamable to L ≫ 10⁵ with O(n·dv) state), written **once** as a
//!   generic `CausalState<T: Scalar>` — the f64 path and the f32 SIMD
//!   hot path are two instantiations of the same `forward_chunk` body
//!   under the `Scalar::Accum` accumulation contract — plus multi-head
//!   fan-out across `std::thread::scope` workers with deterministic
//!   per-head bank seeding.
//! * [`serve`] — the streaming inference-serving layer on top of
//!   [`engine`]: per-user [`serve::Session`]s owning O(n·dv) causal
//!   state, a budgeted [`serve::SessionPool`] with LRU
//!   eviction-to-snapshot, a session-batched [`serve::BatchScheduler`]
//!   fanning (session × head) work across workers, and bitwise-resumable
//!   KV-state snapshots through the [`crate::checkpoint`] store.
//! * [`obs`] (re-export of [`crate::obs`]) — zero-dependency serving
//!   telemetry: counters/gauges/histograms, span timers, a structured
//!   event ring, kernel-quality gauges (per-head ESS, Σ̂ anisotropy),
//!   and Prometheus/JSON exporters — write-only from the hot path, so
//!   max verbosity is bitwise-identical in outputs to disabled.
//! * [`proposal`] — the closed-form optimal proposal of Theorem 3.2,
//!   `Sigma* = (I + 2L)(I - 2L)^{-1}`, plus its validity condition.
//! * [`variance`] — scalar-reference Monte-Carlo and closed-form
//!   variance evaluation.
//! * [`batch`] — the batched, `std::thread::scope`-parallel variance
//!   engine behind the `variance` bench: shared draw banks per pair,
//!   deterministic for a fixed seed and independent of worker count.
//! * [`mahalanobis`] — Mahalanobis geometry and whitening (App. C).
//! * [`orthogonal`] — block-orthogonal feature draws (Performer's ORF
//!   coupling; extension ablation).
//!
//! The estimator layer is f64 and validates the paper's *theory* claims;
//! [`features`] + [`attention`] carry those statistics into an O(L·m·d)
//! attention forward, [`engine`] runs that forward at serving scale
//! (chunked, multi-head, generic over the [`crate::linalg::Scalar`]
//! storage precision), [`serve`] is the top of the stack — the
//! multi-tenant streaming entry point (session pool, batch scheduler,
//! resumable snapshots), dispatching the runtime `Precision` choice once
//! at the session boundary — and the AOT/JAX stack (behind the `pjrt`
//! feature) validates the *system* claims. Adding a storage precision
//! (e.g. a bf16 emulation) means adding one `Scalar` impl in
//! [`crate::linalg`]; the whole pipeline exists for it immediately.

pub mod attention;
pub mod batch;
pub mod engine;
pub mod estimators;
pub mod features;
pub mod gaussian;
pub mod mahalanobis;
pub mod orthogonal;
pub mod proposal;
pub mod serve;
pub mod variance;

/// Serving observability lives at the crate root ([`crate::obs`]); this
/// alias keeps the `rfa::obs` path working alongside `rfa::serve`.
pub use crate::obs;

pub use attention::{
    causal_linear_attention, linear_attention, prf_attention,
    softmax_attention,
};
pub use batch::{
    expected_mc_variance_batched, expected_mc_variance_threaded,
    paired_expected_mc_variance_batched, paired_expected_mc_variance_threaded,
};
pub use engine::{
    chunked_causal_linear_attention, chunked_causal_linear_attention32,
    draw_head_banks, linear_attention32, multi_head_causal_attention,
    multi_head_causal_attention32, multi_head_causal_attention_t,
    prf_attention_chunked, prf_attention_chunked32, CausalState,
    CausalState32, EngineConfig, Head,
};
pub use estimators::{exact_softmax_kernel, PrfEstimator, Sampling};
pub use features::FeatureBank;
pub use gaussian::MultivariateGaussian;
pub use proposal::{optimal_proposal, proposal_is_valid};
pub use serve::{
    BatchScheduler, Precision, ServeConfig, Session, SessionPool,
    StepRequest, StepResponse,
};
