//! Orthogonal random features (extension).
//!
//! Performer couples PRFs with *orthogonal* projection blocks: draw a
//! Gaussian matrix, Gram–Schmidt its rows, and rescale each row to a chi
//! draw so the marginal distribution of every omega stays `N(0, I)` while
//! rows within a block are exactly orthogonal — a classical variance
//! reduction (Yu et al. 2016) on top of either sampling geometry. For
//! DARKFormer the block is drawn orthogonal in the whitened space and
//! mapped through `M^T`, preserving the data-aligned covariance.
//!
//! This module provides block-orthogonal draws and the coupled estimator
//! used by the `variance` bench's ablation.

use crate::linalg::Matrix;
use crate::rng::{GaussianExt, Pcg64};

/// Draw `m` projection vectors in blocks of size `<= d` whose rows are
/// orthogonal within each block, each row rescaled to an independent chi
/// draw so marginals match `N(0, I_d)`.
pub fn orthogonal_gaussian_block(
    d: usize,
    m: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(m);
    while out.len() < m {
        let block = (m - out.len()).min(d);
        // Gram-Schmidt a fresh Gaussian d x d block.
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(block);
        while rows.len() < block {
            let mut v = rng.gaussian_vec(d);
            for u in &rows {
                let dot: f64 = v.iter().zip(u).map(|(a, b)| a * b).sum();
                let un: f64 = u.iter().map(|a| a * a).sum();
                for (vi, ui) in v.iter_mut().zip(u) {
                    *vi -= dot / un * ui;
                }
            }
            let norm: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
            if norm < 1e-9 {
                continue; // Degenerate draw; retry.
            }
            // Rescale to a chi_d-distributed length: ||g||, g ~ N(0, I_d).
            let target: f64 = rng
                .gaussian_vec(d)
                .iter()
                .map(|a| a * a)
                .sum::<f64>()
                .sqrt();
            for vi in &mut v {
                *vi *= target / norm;
            }
            rows.push(v);
        }
        out.extend(rows);
    }
    out.truncate(m);
    out
}

/// One m-sample PRF estimate of `exp(q . k)` with block-orthogonal
/// isotropic features (Performer's ORF + PRF coupling).
pub fn orthogonal_prf_estimate(
    q: &[f64],
    k: &[f64],
    m: usize,
    rng: &mut Pcg64,
) -> f64 {
    let d = q.len();
    let omegas = orthogonal_gaussian_block(d, m, rng);
    let qn: f64 = q.iter().map(|a| a * a).sum();
    let kn: f64 = k.iter().map(|a| a * a).sum();
    let mut acc = 0.0;
    for omega in &omegas {
        let oq: f64 = omega.iter().zip(q).map(|(a, b)| a * b).sum();
        let ok: f64 = omega.iter().zip(k).map(|(a, b)| a * b).sum();
        acc += (oq - 0.5 * qn).exp() * (ok - 0.5 * kn).exp();
    }
    acc / m as f64
}

/// Data-aligned orthogonal draw: orthogonal block in the whitened space,
/// mapped through `chol(Sigma)` so the marginal is `N(0, Sigma)` with
/// within-block orthogonality in the Mahalanobis geometry.
pub fn orthogonal_aligned_block(
    sigma_chol: &Matrix,
    m: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<f64>> {
    let d = sigma_chol.rows();
    orthogonal_gaussian_block(d, m, rng)
        .into_iter()
        .map(|w| sigma_chol.matvec(&w))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::estimators::exact_softmax_kernel;
    use crate::rfa::gaussian::empirical_covariance;

    #[test]
    fn blocks_are_orthogonal_within() {
        let mut rng = Pcg64::seed(71);
        let d = 6;
        let omegas = orthogonal_gaussian_block(d, d, &mut rng);
        for i in 0..d {
            for j in 0..i {
                let dot: f64 = omegas[i]
                    .iter()
                    .zip(&omegas[j])
                    .map(|(a, b)| a * b)
                    .sum();
                assert!(dot.abs() < 1e-9, "rows {i},{j} not orthogonal: {dot}");
            }
        }
    }

    #[test]
    fn marginals_match_standard_gaussian() {
        let mut rng = Pcg64::seed(72);
        let d = 4;
        let samples: Vec<Vec<f64>> = (0..4000)
            .flat_map(|_| orthogonal_gaussian_block(d, d, &mut rng))
            .collect();
        let cov = empirical_covariance(&samples);
        let eye = Matrix::identity(d);
        assert!(
            cov.max_abs_diff(&eye) < 0.12,
            "marginal covariance should be ~I: {cov:?}"
        );
    }

    #[test]
    fn orthogonal_prf_is_unbiased() {
        let mut rng = Pcg64::seed(73);
        let q = vec![0.3, -0.2, 0.1];
        let k = vec![-0.1, 0.25, 0.2];
        let reps = 4000;
        let vals: Vec<f64> = (0..reps)
            .map(|_| orthogonal_prf_estimate(&q, &k, 6, &mut rng))
            .collect();
        let mean = vals.iter().sum::<f64>() / reps as f64;
        let exact = exact_softmax_kernel(&q, &k);
        let se = {
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
                / (reps - 1) as f64;
            (var / reps as f64).sqrt()
        };
        assert!(
            (mean - exact).abs() < 5.0 * se + 1e-9,
            "mean={mean} exact={exact} se={se}"
        );
    }

    #[test]
    fn orthogonal_reduces_variance_vs_iid() {
        use crate::rfa::{PrfEstimator, Sampling};
        let mut rng = Pcg64::seed(74);
        let d = 8;
        let m = 8;
        let q: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let k: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.4 * x).collect();
        let reps = 3000;
        let var_of = |vals: &[f64]| {
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
                / (vals.len() - 1) as f64
        };
        let iid = PrfEstimator::new(d, m, Sampling::Isotropic);
        let v_iid = var_of(
            &(0..reps)
                .map(|_| iid.estimate(&q, &k, &mut rng))
                .collect::<Vec<_>>(),
        );
        let v_ort = var_of(
            &(0..reps)
                .map(|_| orthogonal_prf_estimate(&q, &k, m, &mut rng))
                .collect::<Vec<_>>(),
        );
        assert!(
            v_ort < v_iid * 1.05,
            "orthogonal should not increase variance: iid={v_iid} ort={v_ort}"
        );
    }

    #[test]
    fn aligned_block_has_sigma_covariance() {
        use crate::rfa::gaussian::anisotropic_covariance;
        let mut rng = Pcg64::seed(75);
        let sigma = anisotropic_covariance(3, 0.8, 0.5, &mut rng);
        let chol = sigma.cholesky().unwrap();
        let samples: Vec<Vec<f64>> = (0..6000)
            .flat_map(|_| orthogonal_aligned_block(&chol, 3, &mut rng))
            .collect();
        let cov = empirical_covariance(&samples);
        assert!(
            cov.max_abs_diff(&sigma) < 0.15,
            "aligned block covariance should be ~Sigma"
        );
    }
}
