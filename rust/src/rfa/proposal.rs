//! Theorem 3.2: the closed-form variance-optimal proposal.
//!
//! For `q, k ~ N(0, Lambda)` the optimal PRF sampling density is the
//! centered Gaussian `psi* = N(0, Sigma*)` with
//!
//! ```text
//! Sigma* = (I + 2 Lambda)(I - 2 Lambda)^{-1}
//! ```
//!
//! valid whenever every eigenvalue of `Lambda` is below 1/2 (otherwise
//! `psi*` is not normalizable). `Sigma*` shares `Lambda`'s eigenbasis and
//! is isotropic iff `Lambda` is — the motivation for DARKFormer's learned
//! anisotropic sampling geometry.

use crate::linalg::Matrix;

/// Largest eigenvalue bound for validity: `lambda_max < 1/2`.
pub fn proposal_is_valid(lambda: &Matrix) -> bool {
    let (vals, _) = lambda.jacobi_eigen();
    vals.first().is_some_and(|&v| v < 0.5)
}

/// `Sigma* = (I + 2 Lambda)(I - 2 Lambda)^{-1}`; `None` when the proposal
/// is not normalizable (some eigenvalue of `Lambda` >= 1/2).
pub fn optimal_proposal(lambda: &Matrix) -> Option<Matrix> {
    if !proposal_is_valid(lambda) {
        return None;
    }
    let n = lambda.rows();
    let i = Matrix::identity(n);
    let plus = i.add(&lambda.scale(2.0));
    let minus = i.sub(&lambda.scale(2.0));
    Some(plus.matmul(&minus.inverse()?))
}

/// Eigenvalue map of Theorem 3.2: `sigma_i = 1 / (1 - 2 beta_i)` with
/// `beta_i = 2 lambda_i / (2 lambda_i + 1)` — equivalently
/// `(1 + 2 lambda_i) / (1 - 2 lambda_i)`. Exposed for the spectrum-level
/// tests and the variance bench's reporting.
pub fn optimal_eigenvalue(lambda_i: f64) -> f64 {
    (1.0 + 2.0 * lambda_i) / (1.0 - 2.0 * lambda_i)
}

/// Anisotropy index: ratio of extreme eigenvalues (1.0 = isotropic).
pub fn anisotropy_index(cov: &Matrix) -> f64 {
    let (vals, _) = cov.jacobi_eigen();
    let max = vals[0];
    let min = *vals.last().unwrap();
    max / min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfa::gaussian::anisotropic_covariance;
    use crate::rng::Pcg64;

    #[test]
    fn isotropic_lambda_gives_isotropic_proposal() {
        let lambda = Matrix::identity(4).scale(0.2);
        let sigma = optimal_proposal(&lambda).unwrap();
        let expected = Matrix::identity(4).scale(optimal_eigenvalue(0.2));
        assert!(sigma.max_abs_diff(&expected) < 1e-12);
    }

    #[test]
    fn anisotropic_lambda_gives_anisotropic_proposal() {
        let mut rng = Pcg64::seed(7);
        let lambda = anisotropic_covariance(4, 0.2, 0.8, &mut rng);
        let sigma = optimal_proposal(&lambda).unwrap();
        assert!(anisotropy_index(&sigma) > 1.5);
    }

    #[test]
    fn proposal_shares_eigenbasis_with_lambda() {
        let mut rng = Pcg64::seed(19);
        let lambda = anisotropic_covariance(5, 0.15, 0.7, &mut rng);
        let sigma = optimal_proposal(&lambda).unwrap();
        // Same eigenbasis <=> they commute.
        let ab = lambda.matmul(&sigma);
        let ba = sigma.matmul(&lambda);
        assert!(ab.max_abs_diff(&ba) < 1e-9);
    }

    #[test]
    fn eigenvalues_follow_closed_form_map() {
        let mut rng = Pcg64::seed(29);
        let lambda = anisotropic_covariance(4, 0.1, 0.9, &mut rng);
        let sigma = optimal_proposal(&lambda).unwrap();
        let (lvals, _) = lambda.jacobi_eigen();
        let (svals, _) = sigma.jacobi_eigen();
        for (l, s) in lvals.iter().zip(&svals) {
            assert!(
                (optimal_eigenvalue(*l) - s).abs() < 1e-9,
                "lambda={l} sigma={s}"
            );
        }
    }

    #[test]
    fn invalid_when_eigenvalue_exceeds_half() {
        let lambda = Matrix::diag(&[0.6, 0.1]);
        assert!(!proposal_is_valid(&lambda));
        assert!(optimal_proposal(&lambda).is_none());
        let edge = Matrix::diag(&[0.5, 0.1]);
        assert!(optimal_proposal(&edge).is_none());
    }

    #[test]
    fn proposal_is_spd() {
        let mut rng = Pcg64::seed(41);
        let lambda = anisotropic_covariance(6, 0.2, 0.5, &mut rng);
        let sigma = optimal_proposal(&lambda).unwrap();
        assert!(sigma.cholesky().is_some());
    }
}
