//! Streaming inference-serving layer over the chunked attention engine.
//!
//! [`super::engine`] is a fast single-request forward; this module turns
//! it into a multi-tenant streaming attention server. The O(n·dv)
//! running state of causal linear attention is exactly what makes
//! per-user streaming cheap: a [`session::Session`] owns one
//! [`super::engine::CausalState`] (or `CausalState32`) per head plus the
//! head's [`super::features::FeatureBank`], and each incoming chunk of
//! (q, k, v) rows advances that state — no per-session KV cache growing
//! with the stream length.
//!
//! Three pieces:
//!
//! * [`session`] — [`session::Session`] (per-head banks + states, a
//!   monotone position counter, resident-byte accounting) and
//!   [`session::SessionPool`] (id allocation, a configurable memory
//!   budget, LRU eviction). Evicted sessions are **snapshotted, not
//!   dropped**: the pool writes a DKFT snapshot and faults the session
//!   back in on its next request, so a tight budget changes wall-clock,
//!   never outputs.
//! * [`scheduler`] — [`scheduler::BatchScheduler`]: accepts
//!   [`scheduler::StepRequest`]s into per-session FIFO queues, drains
//!   the head of every non-empty queue per tick (a ready-list keeps
//!   that O(batch), not O(backlog)), and fans (session × head) work
//!   items across the same job runner as the variance/engine fan-outs.
//! * [`snapshot`] — serialize/restore a session through the
//!   [`crate::checkpoint::Checkpoint`] tensor store.
//! * [`store`] — the [`store::SnapshotStore`] boundary all snapshot IO
//!   crosses: crash-safe [`store::FsStore`] in production, the
//!   deterministic [`store::FaultyStore`] injector in the chaos suite
//!   (see the failure-semantics section below).
//!
//! # Precision dispatch: once, at the session boundary
//!
//! The forward stack below this module is generic over the
//! [`crate::linalg::Scalar`] storage precision; the runtime
//! [`session::Precision`] choice in [`session::ServeConfig`] is resolved
//! to a compile-time scalar exactly once per code path — when a session
//! is created, when a tick's fan-out unwraps [`session::SessionHeads`],
//! when a snapshot is restored. Everything per-head (feature maps,
//! chunked forwards, tensor ser/de) runs through single generic bodies;
//! no precision `match` exists below the session boundary.
//!
//! # Scheduler determinism contract
//!
//! Every session's output stream is a pure function of its seed and its
//! own request sequence. Concretely:
//!
//! * per tick the scheduler takes **at most one** pending request per
//!   session — the earliest — so same-session requests apply in arrival
//!   order; different sessions are independent;
//! * the tick's work items are ordered by (request arrival, head index)
//!   and run through [`super::batch::run_jobs`], whose job-order
//!   reduction makes results bitwise independent of the worker count;
//! * states mutate only inside the owning work item, and eviction /
//!   fault-in happens serially between ticks through exact-bits
//!   snapshots.
//!
//! Consequently outputs are invariant under thread count, tick
//! boundaries, arrival interleaving *across* sessions, and memory
//! budget — the properties `rust/tests/rfa_serve.rs` pins. Chunk
//! blocking is per segment: a request of `L` rows is evaluated in
//! `chunk`-row blocks from the segment start, so feeding segments whose
//! lengths are multiples of `chunk` is bitwise identical to one
//! monolithic evaluation (the engine's streaming property).
//!
//! # Online bank resampling: the epoch contract
//!
//! With [`session::ResampleConfig`] set, each head adapts its bank to
//! the keys it actually sees — the paper's data-aware kernel made
//! streaming. Per head the session maintains a second-moment estimate
//! `C = Σ k_j·k_jᵀ` (rank-1 updates folded in stream order), and at
//! every **epoch boundary** — the fixed absolute stream positions
//! `K, 2K, 3K, …` with `K = epoch_positions` — it:
//!
//! 1. produces `Σ̂ = (1-λ)·C/count + λ·I` and its Cholesky factor —
//!    from the **maintained factor** (below), not a fresh O(d³)
//!    factorization (shrinkage keeps Σ̂ SPD),
//! 2. **freezes** the current `(bank, S, z)` triple, and
//! 3. redraws a data-aware bank against Σ̂, seeded by a pure function of
//!    `(session_seed, head, epoch)` — no RNG state carries across
//!    epochs, so restores cannot perturb future draws.
//!
//! Of the two sound designs — restart the attention window at the
//! boundary, or freeze-and-combine — this module implements
//! **freeze-and-combine**: the causal prefix `S, z` is only meaningful
//! in the feature space it was accumulated in, so each epoch keeps its
//! own triple, queries take a [`super::engine::CausalState::readout`]
//! against every retained frozen triple plus the live one, and the
//! per-epoch *unnormalized* numerators and denominators are summed
//! (frozen epochs oldest-first, live epoch last, in `Scalar::Accum`)
//! before the single normalization divide. Each epoch is an unbiased
//! estimator of its own segment's kernel attention, so the combined
//! readout keeps the full window without rewriting history. Memory is
//! bounded by `max_epochs`: the oldest frozen triple is dropped beyond
//! the cap, which removes that epoch's keys from the attention window —
//! a sliding-window approximation, applied deterministically at
//! boundaries.
//!
//! ## The maintained factor: boundaries in O(d²·k), not O(d³)
//!
//! Each online head maintains the lower Cholesky factor `L` of the
//! *unnormalized* shrunk moment `U = (1-λ)·C + λ·floor·I` alongside `C`
//! itself, via [`crate::linalg::Matrix::cholesky_update_rank1`]: every
//! key observation folds `x = √(1-λ)·k` into `L` (O(d²), same stream
//! order as the rank-1 update of `C`). The boundary then needs only the
//! scaled-factor identity `chol(U/c) = L/√c`: with `c = count`,
//! `U/count = (1-λ)·C/count + λ·(floor/count)·I`, so `L/√count` is an
//! exact factor of a Σ̂ whose identity floor is `λ·floor/count` instead
//! of `λ` — the one approximation of the scheme. `floor` is pinned to
//! the count at the last full refresh and a refresh is forced whenever
//! `count ≥ 2·floor` (the doubling rule), so the floor drifts by at
//! most 2× between O(log stream-length) full O(d³) refactorizations;
//! the first boundary is always a full refresh (the factor starts
//! unmaintained, so the pre-boundary stream pays zero extra work when
//! resampling is off). The drift only perturbs *which* Σ̂ the redraw
//! targets — never determinism: `L` is a pure function of the key
//! stream and the refresh schedule is a pure function of `count`, both
//! of which snapshot exactly. The bank redraw consumes `(Σ̂, L/√count)`
//! directly (`MultivariateGaussian::from_parts`), skipping the
//! per-boundary factorization entirely; if a refresh ever finds the
//! accumulated `U` numerically non-SPD the head falls back to the
//! identity proposal for that epoch and retries at the next boundary,
//! exactly as the materialize-from-scratch path did.
//!
//! ## Frozen-epoch compaction: bounding the tail
//!
//! [`session::ResampleConfig::compaction`]
//! ([`session::CompactionConfig`]) bounds retained frozen triples to a
//! `window` *before* `max_epochs` drops them. Where the `max_epochs`
//! cap simply forgets the oldest epoch's keys, compaction **merges**
//! the oldest frozen epoch into its successor: it draws `probes`
//! Gaussian probe points from a pure-function RNG of `(seed, head,
//! merge_index)`, evaluates both banks' feature maps on them, solves
//! the ridge-regularized least squares `M = (Φ₁ᵀΦ₁ + ε·I)⁻¹ Φ₁ᵀ Φ₀`
//! mapping old features onto successor features, and folds `S₁ += M·S₀`,
//! `z₁ += M·z₀`. The merged epoch's contribution to every future
//! readout is thereafter *approximated* in the successor's feature
//! space — error governed by how well the successor bank spans the old
//! one on the probe set (banks drawn from neighboring Σ̂ estimates
//! overlap heavily, and the ridge `ε` caps amplification), and it
//! decays in relative weight as the stream grows. Determinism survives
//! because the probes, the merge schedule (deque length vs `window`,
//! checked at boundaries only) and the arithmetic are all pure
//! functions of `(seed, per-session request order)` — no data-dependent
//! branching, no wall clock. **Off by default**: with `compaction:
//! None` (including every pre-existing config literal) the retained-
//! epoch behavior and every output bit match the previous stack
//! exactly.
//!
//! The determinism contract extends unchanged: epoch boundaries are
//! absolute positions (independent of how the stream is sliced into
//! requests — a boundary mid-segment splits the segment internally),
//! the bank redraw depends only on `(seed, head, epoch)` and the keys
//! before the boundary, and all resample state snapshots exactly —
//! including the maintained factor and the compaction merge count. So
//! outputs remain a pure function of `(seed, per-session request
//! order)` across thread counts, tick boundaries, and eviction — now
//! across resample epochs and compaction merges too. With `resample:
//! None` the serving path is bitwise identical to the pre-resampling
//! stack, and an enabled path changes no bits before its first boundary
//! (the combine of one live epoch is exact; the factor is lazily
//! initialized at the first boundary).
//!
//! # Snapshot tensor naming scheme
//!
//! A session snapshot is a DKFT checkpoint with names:
//!
//! ```text
//! session/version      u32[1]   snapshot schema version (3; v1/v2 load)
//! session/id           u32[2]   u64 as [lo, hi]
//! session/seed         u32[2]   bank-draw seed as [lo, hi]
//! session/position     u32[2]   stream position as [lo, hi]
//! session/precision    u32[1]   0 = f64, 1 = f32
//! session/n_heads      u32[1]
//! session/dv           u32[1]
//! session/resample     u32[1]   1 = online resampling, 0 = static banks
//! head{h}/bank/omegas  f64[n, d]
//! head{h}/bank/weights f64[n]
//! head{h}/bank/sigma   f64[d, d]  (data-aware banks only)
//! head{h}/state        f64[n, dv] running S prefix
//! head{h}/z            f64[n]     running normalizer prefix
//! ```
//!
//! and, when `session/resample` is 1 (all added in schema version 2):
//!
//! ```text
//! session/resample/epoch_positions  u32[2]   K as [lo, hi]
//! session/resample/max_epochs       u32[1]
//! session/resample/shrinkage        f64[1]
//! head{h}/online/epoch              u32[2]   completed resamples [lo, hi]
//! head{h}/online/count              u32[2]   keys folded into C [lo, hi]
//! head{h}/online/cov_sum            f64[d, d] the running C = Σ k·kᵀ
//! head{h}/online/n_frozen           u32[1]
//! head{h}/frozen{j}/bank/omegas     f64[n, d]  (j oldest-first)
//! head{h}/frozen{j}/bank/weights    f64[n]
//! head{h}/frozen{j}/bank/sigma      f64[d, d]  (data-aware banks only)
//! head{h}/frozen{j}/state           f64[n, dv] frozen S
//! head{h}/frozen{j}/z               f64[n]     frozen z
//! ```
//!
//! plus, in schema version 3 (read by presence, so v2 files load with a
//! fresh factor state — the next boundary refreshes it — and no
//! compaction):
//!
//! ```text
//! session/resample/compaction/window  u32[1]   (only when configured)
//! session/resample/compaction/probes  u32[1]
//! session/resample/compaction/ridge   f64[1]
//! head{h}/online/chol_floor           u32[2]   count at last refresh
//! head{h}/online/chol_rank1           u32[2]   rank-1 updates folded
//! head{h}/online/chol_refreshes       u32[2]   full refactorizations
//! head{h}/online/compactions          u32[2]   merges applied
//! head{h}/online/chol_factor          f64[d, d] maintained L (if live)
//! ```
//!
//! State tensors are F64 even for f32 sessions — the running state
//! lives in `Scalar::Accum` (f64) for every storage precision (see
//! [`super::engine`]) — so every round-trip is exact-bits and a restored
//! session continues its stream bitwise identically to an uninterrupted
//! one. The covariance sum is an exact f64 accumulation, so this holds
//! across resample epochs as well.
//!
//! # Failure semantics: retry, quarantine, degraded mode
//!
//! All snapshot IO flows through the [`store::SnapshotStore`] trait —
//! [`store::FsStore`] in production (crash-safe writes: staging file +
//! fsync + atomic rename, so no crash or ENOSPC interleaving ever
//! leaves a torn file at a snapshot path), [`store::FaultyStore`] in
//! the chaos suite (`rust/tests/rfa_chaos.rs`), a deterministic
//! scripted injector. Faults are contained in three layers, none of
//! which ever consults a wall clock:
//!
//! * **Per-session retry with tick-counted backoff.** A tick no longer
//!   fails its batch on one session's snapshot error: the failing
//!   session's request goes back to its queue front, the session backs
//!   off for an exponentially growing, capped number of *ticks*
//!   ([`scheduler::RetryPolicy`]), and every healthy session in the
//!   same tick completes and queues its response as usual.
//! * **Quarantine.** After `quarantine_persistent` consecutive
//!   persistent-classified failures (or `quarantine_any` of any kind —
//!   the termination backstop), the session is quarantined: its queued
//!   requests surface as typed [`scheduler::FailedStep`]s via
//!   [`scheduler::BatchScheduler::poll_failures`], new submits to it
//!   are rejected, other sessions keep serving, and
//!   [`scheduler::BatchScheduler::unquarantine`] re-admits it for an
//!   operator retry (resubmit the failed requests in seq order).
//! * **Degraded mode.** While the last snapshot *write* is failing, the
//!   pool suspends eviction (residents overshoot the soft budget rather
//!   than risking stream loss) and admission control rejects *new*
//!   sessions once resident bytes reach the budget; the first
//!   successful write clears the mode. Failed snapshot unlinks are
//!   recorded as orphans and retried, never silently dropped.
//!   [`store::HealthReport`] (on pool and scheduler) exposes all of it.
//!
//! What stays deterministic under faults: the fault schedule is part of
//! the input. For a fixed schedule (in store-op/tick counts, as
//! [`store::FaultyStore`] scripts it), the set of completed responses,
//! the quarantine membership, and every output bit are invariant under
//! thread count and precision-independent in structure — and once the
//! store heals and abandoned requests are resubmitted in order, each
//! session's concatenated output stream is bitwise identical to a
//! never-faulted run. What is *not* deterministic: wall-clock-induced
//! schedules against a real flaky filesystem (production `FsStore`
//! faults arrive whenever they arrive) — determinism is with respect to
//! the schedule, not a guarantee about nature.
//!
//! # Observability and the determinism contract
//!
//! The whole stack is instrumented through [`crate::obs`]: the pool and
//! scheduler share one [`crate::obs::ServeObs`]
//! ([`session::SessionPool::obs`] / [`scheduler::BatchScheduler::obs`])
//! holding always-on counters (eviction/restore churn, snapshot bytes
//! and failures, quarantine transitions, requests/rows/ticks, resample
//! epochs, Cholesky factor maintenance — rank-1 updates and full
//! refreshes — and compaction merges), span-timed latency histograms (tick, forward fan-out,
//! snapshot IO, post-epoch kernel-quality recompute), pool gauges, the
//! per-head kernel-quality gauges (importance-weight ESS, Σ̂ anisotropy,
//! epoch count, frozen-epoch bytes), and — at full verbosity — a
//! bounded structured event ring. Prometheus text and flat-JSON
//! exporters read the shared registry.
//!
//! Telemetry is **write-only from the hot path**, which is how it
//! coexists with every guarantee above:
//!
//! * no control flow reads a metric, gauge, or the ring — the degraded
//!   flag, backoff clocks and budgets remain plain fields that telemetry
//!   only mirrors;
//! * wall-clock time appears solely *inside* histogram values (span
//!   timers); nothing branches on it;
//! * worker threads touch nothing but sharded counter cells — events,
//!   gauges and metric registration happen on serial pool/scheduler
//!   paths only. Resample epochs cross *inside* the worker fan-out, so
//!   the serial paths diff each session's epoch counters afterwards
//!   (`drain_epoch_telemetry`) instead of emitting from workers;
//! * therefore a run at [`crate::obs::ObsLevel::Full`] is
//!   bitwise-identical in its outputs to one at `Off`, and the event
//!   sequence, deterministic histograms (batch sizes, request rows) and
//!   counters are thread-count-invariant for a fixed workload and fault
//!   schedule — all pinned by `rust/tests/rfa_obs.rs`.
//!
//! Verbosity comes from `RFA_OBS` (`off`/`basic`/`full`) by default, or
//! explicitly via [`session::SessionPool::with_obs`]. `Off` still keeps
//! the counters ([`session::PoolStats`] and [`store::HealthReport`] are
//! views over them) at ~one relaxed `fetch_add` per event.

pub mod scheduler;
pub mod session;
pub mod snapshot;
pub mod store;

pub use crate::obs::{ObsConfig, ObsLevel, ServeObs};

pub use scheduler::{
    BatchScheduler, DrainOutcome, FailedStep, RetryPolicy, StepRequest,
    StepResponse,
};
pub use session::{
    CompactionConfig, FrozenEpoch, HeadSlot, OnlineState, PoolStats,
    Precision, ResampleConfig, ServeConfig, Session, SessionHeads,
    SessionPool, StepOutput,
};
pub use snapshot::{load_session, save_session};
pub use store::{
    Fault, FaultHandle, FaultRule, FaultyStore, FiredFault, FsStore,
    HealthReport, SeededFaults, SnapshotStore, StoreError, StoreOp,
};
