//! Session-batched scheduling: coalesce pending step requests into one
//! batch per tick and fan (session × head) work items across worker
//! threads.
//!
//! The scheduling discipline (at most one request per session per tick,
//! earliest first; work items ordered by (arrival, head index); job-order
//! reduction via [`crate::rfa::batch::run_jobs`]) makes every session's
//! output stream a pure function of its seed and its own request
//! sequence — see the determinism contract in the [`super`] module docs.

use std::collections::{BTreeSet, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::rfa::engine::Head;

use super::session::{HeadSlot, SessionPool, StepOutput};

/// One streaming step for one session: a segment of per-head (q, k, v)
/// rows to append to the session's stream. All heads must cover the same
/// positions (equal row counts).
pub struct StepRequest {
    pub session_id: u64,
    pub heads: Vec<Head>,
}

impl StepRequest {
    /// Convenience: the same (q, k, v) segment for every head (the heads
    /// still produce distinct outputs — their banks differ). Note this
    /// clones the segment once per head because requests own per-head
    /// inputs; latency-sensitive callers with genuinely distinct per-head
    /// projections should build `heads` directly (no redundant copies).
    pub fn broadcast(
        session_id: u64,
        n_heads: usize,
        q: Vec<Vec<f64>>,
        k: Vec<Vec<f64>>,
        v: crate::linalg::Matrix,
    ) -> Self {
        let heads = (0..n_heads)
            .map(|_| Head { q: q.clone(), k: k.clone(), v: v.clone() })
            .collect();
        Self { session_id, heads }
    }

    fn rows(&self) -> usize {
        self.heads.first().map_or(0, |h| h.v.rows())
    }
}

/// Outputs for one completed [`StepRequest`].
pub struct StepResponse {
    pub session_id: u64,
    /// Arrival sequence number assigned by [`BatchScheduler::submit`].
    pub seq: u64,
    /// Stream position of the first output row (the session's position
    /// counter before this request applied).
    pub start_position: u64,
    /// One output per head, in head order, in the session's precision.
    pub outputs: Vec<StepOutput>,
}

/// Work item of one scheduling tick: one head of one scheduled session.
struct HeadJob<'a> {
    slot: &'a mut HeadSlot,
    input: &'a Head,
}

/// Coalescing batch scheduler over a [`SessionPool`].
///
/// `submit` enqueues; each `tick` drains at most one request per session
/// (earliest first), faults their sessions in, runs all (session × head)
/// items on the worker pool, and queues the responses; `poll_responses`
/// drains completed responses. [`BatchScheduler::run_until_idle`] is the
/// synchronous wall-clock-free drain used by tests and benches.
pub struct BatchScheduler {
    pool: SessionPool,
    pending: VecDeque<(u64, StepRequest)>,
    ready: VecDeque<StepResponse>,
    next_seq: u64,
}

impl BatchScheduler {
    pub fn new(pool: SessionPool) -> Self {
        Self {
            pool,
            pending: VecDeque::new(),
            ready: VecDeque::new(),
            next_seq: 0,
        }
    }

    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }

    pub fn pool_mut(&mut self) -> &mut SessionPool {
        &mut self.pool
    }

    /// Recover the pool (e.g. to snapshot every session at shutdown).
    pub fn into_pool(self) -> SessionPool {
        self.pool
    }

    /// Number of requests waiting for a tick.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Validate and enqueue a request; returns its arrival sequence
    /// number (echoed in the response).
    pub fn submit(&mut self, req: StepRequest) -> Result<u64> {
        ensure!(
            self.pool.contains(req.session_id),
            "no session with id {}",
            req.session_id
        );
        let cfg = self.pool.cfg();
        ensure!(
            req.heads.len() == cfg.n_heads,
            "request for session {} has {} heads, pool serves {}",
            req.session_id,
            req.heads.len(),
            cfg.n_heads
        );
        let rows = req.rows();
        let d = cfg.est.dim();
        for (h, head) in req.heads.iter().enumerate() {
            ensure!(
                head.q.len() == rows
                    && head.k.len() == rows
                    && head.v.rows() == rows,
                "head {h}: q/k/v row counts ({}, {}, {}) must all equal {rows}",
                head.q.len(),
                head.k.len(),
                head.v.rows()
            );
            ensure!(
                head.q.iter().chain(&head.k).all(|r| r.len() == d),
                "head {h}: q/k rows must have dim {d}"
            );
            ensure!(
                head.v.cols() == cfg.dv,
                "head {h}: v has {} channels, pool serves {}",
                head.v.cols(),
                cfg.dv
            );
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back((seq, req));
        Ok(seq)
    }

    /// Run one scheduling tick; returns the number of requests completed
    /// (0 when the queue is empty). On a snapshot-IO error (eviction or
    /// fault-in) the batch is re-queued in arrival order and the error
    /// propagated — no request is lost.
    pub fn tick(&mut self) -> Result<usize> {
        // Coalesce: earliest pending request per distinct session. This
        // rescans the whole queue (one shallow move per deferred entry),
        // so draining a B-deep single-session backlog costs O(B) moves
        // per tick; per-session FIFO queues are the upgrade path if
        // backlogs ever reach that scale (see the ROADMAP item).
        let mut scheduled_ids = BTreeSet::new();
        let mut batch: Vec<(u64, StepRequest)> = Vec::new();
        let mut rest: VecDeque<(u64, StepRequest)> = VecDeque::new();
        while let Some((seq, req)) = self.pending.pop_front() {
            if scheduled_ids.insert(req.session_id) {
                batch.push((seq, req));
            } else {
                rest.push_back((seq, req));
            }
        }
        self.pending = rest;
        if batch.is_empty() {
            return Ok(0);
        }
        match self.run_batch(&batch) {
            Ok(responses) => {
                let completed = responses.len();
                self.ready.extend(responses);
                // A tick pins its whole batch, so a many-session batch
                // can legitimately overshoot the budget while running;
                // re-enforce it now that nothing is pinned. The batch is
                // NOT requeued on failure here — every request already
                // completed and its response is queued.
                self.pool.ensure_budget(&[])?;
                Ok(completed)
            }
            Err(e) => {
                let mut all: Vec<(u64, StepRequest)> = batch
                    .into_iter()
                    .chain(self.pending.drain(..))
                    .collect();
                all.sort_by_key(|(seq, _)| *seq);
                self.pending = all.into();
                Err(e)
            }
        }
    }

    /// Fault the batch's sessions in and run every (session × head) item
    /// on the worker pool. All fallible (IO) work happens before any
    /// session state is touched, so an `Err` leaves every stream intact.
    fn run_batch(
        &mut self,
        batch: &[(u64, StepRequest)],
    ) -> Result<Vec<StepResponse>> {
        // Fault every scheduled session in, serially, with the whole
        // batch pinned so faulting one in never evicts another.
        let ids: Vec<u64> = batch.iter().map(|(_, r)| r.session_id).collect();
        for &id in &ids {
            self.pool.ensure_resident(id, &ids)?;
        }

        // Fan out: jobs ordered by (request arrival, head index).
        let chunk = self.pool.cfg().chunk;
        let workers = self.pool.cfg().worker_count();
        let sessions = self.pool.sessions_mut(&ids);
        let mut starts = Vec::with_capacity(batch.len());
        let mut jobs: Vec<HeadJob> = Vec::new();
        for (session, (_, req)) in sessions.into_iter().zip(batch) {
            let (start, slots) = session.begin_step(req.rows() as u64);
            starts.push(start);
            for (slot, input) in slots.iter_mut().zip(&req.heads) {
                jobs.push(HeadJob { slot, input });
            }
        }
        let outputs = crate::rfa::batch::run_jobs(
            &mut jobs,
            workers,
            |job: &mut HeadJob| job.slot.step(job.input, chunk),
        );

        // Reassemble responses in batch order.
        let mut outputs = outputs.into_iter();
        let mut responses = Vec::with_capacity(batch.len());
        for ((seq, req), start_position) in batch.iter().zip(starts) {
            let head_outputs: Vec<StepOutput> =
                (&mut outputs).take(req.heads.len()).collect();
            responses.push(StepResponse {
                session_id: req.session_id,
                seq: *seq,
                start_position,
                outputs: head_outputs,
            });
        }
        Ok(responses)
    }

    /// Drain completed responses (in completion order; `seq` identifies
    /// the request).
    pub fn poll_responses(&mut self) -> Vec<StepResponse> {
        self.ready.drain(..).collect()
    }

    /// Tick until the pending queue is empty, then drain every response —
    /// the synchronous, wall-clock-free way to run a workload to
    /// completion.
    pub fn run_until_idle(&mut self) -> Result<Vec<StepResponse>> {
        while !self.pending.is_empty() {
            let done = self.tick()?;
            if done == 0 {
                bail!("scheduler made no progress with non-empty queue");
            }
        }
        Ok(self.poll_responses())
    }
}
