//! Session-batched scheduling: coalesce pending step requests into one
//! batch per tick and fan (session × head) work items across worker
//! threads.
//!
//! Requests are held in **per-session FIFO queues** with a ready-list of
//! `(head-of-queue seq, session id)` pairs: a tick drains the head of
//! every non-empty queue (earliest arrival first) in O(batch) work,
//! instead of rescanning a single global backlog — a B-deep
//! single-session backlog no longer costs O(B) queue moves per tick.
//! The scheduling discipline is unchanged: at most one request per
//! session per tick, earliest first; work items ordered by (arrival,
//! head index); job-order reduction via [`crate::rfa::batch::run_jobs`].
//! Together these make every session's output stream a pure function of
//! its seed and its own request sequence — see the determinism contract
//! in the [`super`] module docs.
//!
//! Precision dispatch follows the session-boundary rule: the fan-out
//! unwraps each scheduled session's [`SessionHeads`] once, collects
//! generic [`HeadJob`]s at the pool's storage precision, and runs one
//! generic job loop — no per-head-step precision matching.
//!
//! Snapshot-IO failures are contained per session, never per batch: a
//! failing fault-in sends that one request back to its queue front
//! under a tick-counted backoff ([`RetryPolicy`]), repeated persistent
//! failures quarantine the session (typed [`FailedStep`]s via
//! [`BatchScheduler::poll_failures`], operator retry via
//! [`BatchScheduler::unquarantine`]), and every other session in the
//! same tick still completes. See the failure-semantics section of the
//! [`super`] module docs.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::linalg::{Mat, Scalar};
use crate::obs::{EventKind, ServeObs};
use crate::rfa::engine::Head;

use super::session::{HeadSlot, SessionHeads, SessionPool, StepOutput};
use super::store::{HealthReport, StoreError};

/// One streaming step for one session: a segment of per-head (q, k, v)
/// rows to append to the session's stream. All heads must cover the same
/// positions (equal row counts).
pub struct StepRequest {
    pub session_id: u64,
    pub heads: Vec<Head>,
}

impl StepRequest {
    /// Convenience: the same (q, k, v) segment for every head (the heads
    /// still produce distinct outputs — their banks differ). Note this
    /// clones the segment once per head because requests own per-head
    /// inputs; latency-sensitive callers with genuinely distinct per-head
    /// projections should build `heads` directly (no redundant copies).
    pub fn broadcast(
        session_id: u64,
        n_heads: usize,
        q: Vec<Vec<f64>>,
        k: Vec<Vec<f64>>,
        v: crate::linalg::Matrix,
    ) -> Self {
        let heads = (0..n_heads)
            .map(|_| Head { q: q.clone(), k: k.clone(), v: v.clone() })
            .collect();
        Self { session_id, heads }
    }

    fn rows(&self) -> usize {
        self.heads.first().map_or(0, |h| h.v.rows())
    }
}

/// Outputs for one completed [`StepRequest`].
pub struct StepResponse {
    pub session_id: u64,
    /// Arrival sequence number assigned by [`BatchScheduler::submit`].
    pub seq: u64,
    /// Stream position of the first output row (the session's position
    /// counter before this request applied).
    pub start_position: u64,
    /// One output per head, in head order, in the session's precision.
    pub outputs: Vec<StepOutput>,
}

/// Retry/quarantine policy for per-session snapshot-IO failures. Every
/// quantity is counted in ticks or attempts — never wall-clock time —
/// so fault handling stays inside the determinism contract.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Consecutive *persistent*-classified failures that quarantine the
    /// session.
    pub quarantine_persistent: u32,
    /// Consecutive failures of any classification that quarantine the
    /// session — the termination backstop for endless transient faults.
    pub quarantine_any: u32,
    /// Backoff after the first failure, in ticks; doubles per
    /// consecutive failure.
    pub backoff_base: u64,
    /// Upper bound on the per-session backoff, in ticks.
    pub backoff_cap: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            quarantine_persistent: 3,
            quarantine_any: 12,
            backoff_base: 1,
            backoff_cap: 8,
        }
    }
}

/// A request the scheduler gave up on (its session was quarantined).
/// Carries the original request so an operator can resubmit it after
/// [`BatchScheduler::unquarantine`].
pub struct FailedStep {
    pub session_id: u64,
    /// The seq [`BatchScheduler::submit`] assigned to the request.
    pub seq: u64,
    pub request: StepRequest,
    /// Human-readable cause, ending in the store error's
    /// transient/persistent classification.
    pub error: String,
}

/// Everything a [`BatchScheduler::run_until_idle`] drain produced —
/// lossless even when the drain did not finish cleanly: responses
/// completed before a mid-drain error are returned alongside it, never
/// dropped.
pub struct DrainOutcome {
    /// Responses completed during the drain, in completion order.
    pub responses: Vec<StepResponse>,
    /// Requests abandoned to quarantine during the drain.
    pub failures: Vec<FailedStep>,
    /// The error that stopped the drain, if it did not run to idle.
    pub error: Option<anyhow::Error>,
}

impl DrainOutcome {
    /// True when the drain finished with no error and no failed steps.
    pub fn is_clean(&self) -> bool {
        self.error.is_none() && self.failures.is_empty()
    }

    /// Collapse to the strict all-or-nothing view (what tests and
    /// benches want): `Ok(responses)` only for a clean drain.
    pub fn into_result(self) -> Result<Vec<StepResponse>> {
        if let Some(e) = self.error {
            return Err(e);
        }
        if let Some(first) = self.failures.first() {
            return Err(anyhow!(
                "drain abandoned {} request(s); first: session {} seq {}: {}",
                self.failures.len(),
                first.session_id,
                first.seq,
                first.error
            ));
        }
        Ok(self.responses)
    }
}

/// Per-session failure bookkeeping (absent = healthy).
#[derive(Debug, Default, Clone, Copy)]
struct SessionHealth {
    /// Consecutive failed fault-in attempts.
    consecutive: u32,
    /// Trailing run of persistent-classified failures (a transient
    /// failure resets it).
    persistent_streak: u32,
    /// The session's requests are not scheduled before this tick.
    eligible_at: u64,
}

/// Work item of one scheduling tick: one head of one scheduled session,
/// at the pool's storage precision. The `Accum = f64` bound mirrors
/// [`HeadSlot::step`]'s (true of every precision — the sealed-trait
/// accumulation policy).
struct HeadJob<'a, T: Scalar<Accum = f64>> {
    slot: &'a mut HeadSlot<T>,
    input: &'a Head,
}

/// Run one precision's job list on the worker pool and wrap the outputs.
fn fan_out<T: Scalar<Accum = f64>>(
    mut jobs: Vec<HeadJob<'_, T>>,
    workers: usize,
    chunk: usize,
    wrap: fn(Mat<T>) -> StepOutput,
) -> Vec<StepOutput> {
    crate::rfa::batch::run_jobs(&mut jobs, workers, |job: &mut HeadJob<T>| {
        job.slot.step(job.input, chunk)
    })
    .into_iter()
    .map(wrap)
    .collect()
}

/// Coalescing batch scheduler over a [`SessionPool`].
///
/// `submit` enqueues onto the request's per-session FIFO; each `tick`
/// drains the head of every non-empty queue (earliest first), faults
/// their sessions in, runs all (session × head) items on the worker
/// pool, and queues the responses; `poll_responses` drains completed
/// responses. [`BatchScheduler::run_until_idle`] is the synchronous
/// wall-clock-free drain used by tests and benches.
pub struct BatchScheduler {
    pool: SessionPool,
    /// Per-session FIFO queues of `(seq, request)` in arrival order.
    /// Empty queues are pruned after each tick, so the map stays bounded
    /// by the number of sessions with outstanding work.
    queues: BTreeMap<u64, VecDeque<(u64, StepRequest)>>,
    /// Ready-list: one `(head seq, session id)` entry per non-empty
    /// queue. BTreeSet iteration order *is* the tick's batch order —
    /// earliest head request first.
    ready: BTreeSet<(u64, u64)>,
    /// Total queued requests across all sessions.
    pending: usize,
    responses: VecDeque<StepResponse>,
    next_seq: u64,
    /// A budget re-enforcement failure from the end of a completed tick,
    /// deferred so the tick could still surface its responses. Retried
    /// at the start of the next tick; inspectable via
    /// [`Self::budget_error`]/[`Self::take_budget_error`].
    deferred_budget: Option<anyhow::Error>,
    policy: RetryPolicy,
    /// Monotone tick counter — the clock every backoff is measured in.
    ticks: u64,
    /// Failure bookkeeping for sessions with a live retry streak.
    session_health: BTreeMap<u64, SessionHealth>,
    /// Sessions the retry policy gave up on; their submits are rejected
    /// until [`Self::unquarantine`].
    quarantined: BTreeSet<u64>,
    /// Typed failure records awaiting [`Self::poll_failures`].
    failures: VecDeque<FailedStep>,
    /// The pool's observability handle (same `Arc`): tick/forward spans,
    /// batch/row histograms, quarantine counters and events. Write-only
    /// — no scheduling decision reads it.
    obs: Arc<ServeObs>,
}

impl BatchScheduler {
    pub fn new(pool: SessionPool) -> Self {
        Self::with_policy(pool, RetryPolicy::default())
    }

    /// A scheduler with an explicit [`RetryPolicy`] (the default suits
    /// production; chaos tests shrink the windows).
    pub fn with_policy(pool: SessionPool, policy: RetryPolicy) -> Self {
        let obs = pool.obs().clone();
        Self {
            pool,
            queues: BTreeMap::new(),
            ready: BTreeSet::new(),
            pending: 0,
            responses: VecDeque::new(),
            next_seq: 0,
            deferred_budget: None,
            policy,
            ticks: 0,
            session_health: BTreeMap::new(),
            quarantined: BTreeSet::new(),
            failures: VecDeque::new(),
            obs,
        }
    }

    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }

    /// The serving stack's observability handle (shared with the pool):
    /// registry, event ring, exporters.
    pub fn obs(&self) -> &Arc<ServeObs> {
        &self.obs
    }

    pub fn pool_mut(&mut self) -> &mut SessionPool {
        &mut self.pool
    }

    /// Recover the pool (e.g. to snapshot every session at shutdown).
    pub fn into_pool(self) -> SessionPool {
        self.pool
    }

    /// Number of requests waiting for a tick.
    pub fn pending_len(&self) -> usize {
        self.pending
    }

    /// The deferred budget re-enforcement error from a completed tick,
    /// if one is outstanding (the pool may be over budget until a later
    /// tick re-enforces successfully).
    pub fn budget_error(&self) -> Option<&anyhow::Error> {
        self.deferred_budget.as_ref()
    }

    /// Take (and clear) the deferred budget error.
    pub fn take_budget_error(&mut self) -> Option<anyhow::Error> {
        self.deferred_budget.take()
    }

    /// Combined serving health: the pool's degraded/failure/orphan state
    /// plus the scheduler's quarantine count and deferred-budget flag.
    pub fn health(&self) -> HealthReport {
        let mut report = self.pool.health();
        report.quarantined = self.quarantined.len();
        report.deferred_budget = self.deferred_budget.is_some();
        report
    }

    /// Ids of currently quarantined sessions, ascending.
    pub fn quarantined_sessions(&self) -> Vec<u64> {
        self.quarantined.iter().copied().collect()
    }

    pub fn is_quarantined(&self, id: u64) -> bool {
        self.quarantined.contains(&id)
    }

    /// Operator retry: lift a session's quarantine and reset its failure
    /// bookkeeping. The session's abandoned requests were surfaced via
    /// [`Self::poll_failures`]; resubmit them (in seq order) to replay.
    pub fn unquarantine(&mut self, id: u64) -> Result<()> {
        ensure!(
            self.quarantined.remove(&id),
            "session {id} is not quarantined"
        );
        self.session_health.remove(&id);
        self.obs.unquarantines.inc();
        self.obs.event(EventKind::Unquarantine { session: id });
        if self.obs.gauges_enabled() {
            self.obs
                .quarantined_sessions
                .set(self.quarantined.len() as f64);
        }
        Ok(())
    }

    /// Drain the typed records of abandoned requests (quarantined
    /// sessions), in the order they were given up on.
    pub fn poll_failures(&mut self) -> Vec<FailedStep> {
        self.failures.drain(..).collect()
    }

    /// Ticks run so far — the clock backoffs are measured against.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// The current ready-list, in tick batch order: one
    /// `(head-of-queue seq, session id)` pair per non-empty queue.
    /// Introspection for error-path determinism tests.
    pub fn ready_snapshot(&self) -> Vec<(u64, u64)> {
        self.ready.iter().copied().collect()
    }

    /// Every queued request's seq, per session, in queue (arrival)
    /// order. Introspection for error-path determinism tests.
    pub fn queued_seqs(&self) -> BTreeMap<u64, Vec<u64>> {
        self.queues
            .iter()
            .map(|(sid, q)| (*sid, q.iter().map(|&(seq, _)| seq).collect()))
            .collect()
    }

    /// Close a session: drop its queued requests (they will never get
    /// responses), then remove it from the pool — including its snapshot
    /// file if it was evicted (see [`SessionPool::close_session`]).
    pub fn close_session(&mut self, id: u64) -> Result<()> {
        if let Some(queue) = self.queues.remove(&id) {
            if let Some(&(seq, _)) = queue.front() {
                self.ready.remove(&(seq, id));
            }
            self.pending -= queue.len();
        }
        self.quarantined.remove(&id);
        self.session_health.remove(&id);
        self.pool.close_session(id)
    }

    /// Validate and enqueue a request; returns its arrival sequence
    /// number (echoed in the response).
    pub fn submit(&mut self, req: StepRequest) -> Result<u64> {
        ensure!(
            self.pool.contains(req.session_id),
            "no session with id {}",
            req.session_id
        );
        ensure!(
            !self.quarantined.contains(&req.session_id),
            "session {} is quarantined after repeated snapshot failures; \
             unquarantine it to retry",
            req.session_id
        );
        ensure!(
            !req.heads.is_empty(),
            "request for session {} has no heads",
            req.session_id
        );
        let cfg = self.pool.cfg();
        ensure!(
            req.heads.len() == cfg.n_heads,
            "request for session {} has {} heads, pool serves {}",
            req.session_id,
            req.heads.len(),
            cfg.n_heads
        );
        let rows = req.rows();
        ensure!(
            rows > 0,
            "request for session {} covers zero positions — a step must \
             carry at least one row",
            req.session_id
        );
        let d = cfg.est.dim();
        for (h, head) in req.heads.iter().enumerate() {
            ensure!(
                head.q.len() == rows
                    && head.k.len() == rows
                    && head.v.rows() == rows,
                "head {h}: q/k/v row counts ({}, {}, {}) must all equal {rows}",
                head.q.len(),
                head.k.len(),
                head.v.rows()
            );
            ensure!(
                head.q.iter().chain(&head.k).all(|r| r.len() == d),
                "head {h}: q/k rows must have dim {d}"
            );
            ensure!(
                head.v.cols() == cfg.dv,
                "head {h}: v has {} channels, pool serves {}",
                head.v.cols(),
                cfg.dv
            );
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let queue = self.queues.entry(req.session_id).or_default();
        if queue.is_empty() {
            self.ready.insert((seq, req.session_id));
        }
        queue.push_back((seq, req));
        self.pending += 1;
        Ok(seq)
    }

    /// Run one scheduling tick; returns the number of requests completed
    /// (0 when nothing was eligible). Snapshot-IO failures are contained
    /// per session:
    ///
    /// * A session whose fault-in fails gets its request back at the
    ///   queue front and a tick-counted backoff; every *other* session
    ///   in the batch still runs and queues its response in this tick.
    /// * After [`RetryPolicy::quarantine_persistent`] consecutive
    ///   persistent failures (or [`RetryPolicy::quarantine_any`] of any
    ///   kind), the session is quarantined: its requests surface as
    ///   [`FailedStep`]s via [`Self::poll_failures`] and `pending`
    ///   drops accordingly.
    /// * A budget re-enforcement failure *after* the batch completed is
    ///   non-fatal: the responses are already queued, so the tick
    ///   returns `Ok` and the error is deferred (see
    ///   [`Self::budget_error`]) and retried at the next tick.
    ///
    /// `Err` from a tick is reserved for non-containable conditions;
    /// no request is ever lost on any path.
    pub fn tick(&mut self) -> Result<usize> {
        self.ticks += 1;
        // Telemetry only: the `ticks` *field* above is the backoff clock
        // control flow reads; the counter and span are write-only
        // mirrors (the span records on every exit path when it drops).
        self.obs.ticks.inc();
        let _tick_span = self.obs.span(&self.obs.tick_ms);
        // Retry a deferred budget re-enforcement first, while nothing is
        // pinned. Still failing is still non-fatal — the pool simply
        // stays over budget until the snapshot dir heals.
        if self.deferred_budget.is_some() {
            match self.pool.try_heal() {
                Ok(()) => self.deferred_budget = None,
                Err(e) => self.deferred_budget = Some(e),
            }
        }
        // Pick the ready sessions that are past their backoff gate; the
        // rest keep their ready entries for a later tick. The ready-list
        // is ordered by head seq, so the batch comes out in arrival
        // order without touching any deferred request.
        let now = self.ticks;
        let picked: Vec<(u64, u64)> = self
            .ready
            .iter()
            .copied()
            .filter(|&(_, sid)| {
                self.session_health
                    .get(&sid)
                    .is_none_or(|h| h.eligible_at <= now)
            })
            .collect();
        for key in &picked {
            self.ready.remove(key);
        }
        // Phase A — snapshot IO, serial, in arrival order: pop each
        // picked head request and fault its session in. One session's
        // failure routes to the retry path instead of failing the batch.
        let mut runnable: Vec<(u64, StepRequest)> =
            Vec::with_capacity(picked.len());
        let mut faulted: Vec<(u64, StepRequest, StoreError)> = Vec::new();
        for &(seq, sid) in &picked {
            let queue =
                self.queues.get_mut(&sid).expect("ready session has a queue");
            let (head_seq, req) =
                queue.pop_front().expect("ready queue is non-empty");
            debug_assert_eq!(head_seq, seq, "ready-list out of sync");
            match self.pool.fault_in(sid) {
                Ok(()) => runnable.push((seq, req)),
                Err(e) => faulted.push((seq, req, e)),
            }
        }
        if runnable.is_empty() && faulted.is_empty() {
            return Ok(0);
        }
        // Phase B — compute, infallible: every runnable session is
        // resident. The batch may overshoot the memory budget while it
        // runs (as it always did, when the whole batch was pinned);
        // re-enforced below.
        let completed = runnable.len();
        if completed > 0 {
            self.obs.observe_batch(completed);
            let responses = self.run_resident_batch(&runnable);
            self.obs.requests_completed.add(responses.len() as u64);
            self.pending -= responses.len();
            self.responses.extend(responses);
            for (_, req) in &runnable {
                self.session_health.remove(&req.session_id);
            }
        }
        // Phase C — failure bookkeeping: requeue-with-backoff or
        // quarantine each faulted request.
        for (seq, req, err) in faulted {
            self.note_failure(seq, req, err);
        }
        // Re-arm the ready-list from the surviving queue heads (a
        // requeued request re-enters here; its backoff gate keeps it out
        // of the next pick until eligible) and prune emptied queues.
        for &(_, sid) in &picked {
            if let Some(&(seq, _)) =
                self.queues.get(&sid).and_then(VecDeque::front)
            {
                self.ready.insert((seq, sid));
            }
        }
        self.queues.retain(|_, q| !q.is_empty());
        // A failure here must NOT fail the tick: every completed request
        // already queued its response — returning `Err` would make
        // callers lose a fully-completed batch. Defer the error instead.
        if let Err(e) = self.pool.ensure_budget(&[]) {
            self.deferred_budget = Some(e);
        }
        self.pool.refresh_gauges();
        Ok(completed)
    }

    /// Record one failed fault-in: bump the session's streaks, arm the
    /// (exponential, capped, tick-counted) backoff, requeue the request
    /// at the queue front — or, past the policy's thresholds, quarantine
    /// the session and surface its requests as [`FailedStep`]s.
    fn note_failure(&mut self, seq: u64, req: StepRequest, err: StoreError) {
        let sid = req.session_id;
        let health = self.session_health.entry(sid).or_default();
        health.consecutive += 1;
        if err.is_transient() {
            health.persistent_streak = 0;
        } else {
            health.persistent_streak += 1;
        }
        let exp = 1u64
            .checked_shl(health.consecutive.saturating_sub(1))
            .unwrap_or(u64::MAX);
        let backoff = self
            .policy
            .backoff_base
            .saturating_mul(exp)
            .clamp(1, self.policy.backoff_cap.max(1));
        health.eligible_at = self.ticks + backoff;
        let quarantine = health.persistent_streak
            >= self.policy.quarantine_persistent
            || health.consecutive >= self.policy.quarantine_any;
        if !quarantine {
            self.queues.entry(sid).or_default().push_front((seq, req));
            return;
        }
        let streak = health.consecutive;
        self.session_health.remove(&sid);
        self.quarantined.insert(sid);
        self.obs.quarantines.inc();
        self.obs.event(EventKind::Quarantine {
            session: sid,
            failures: streak,
        });
        if self.obs.gauges_enabled() {
            self.obs
                .quarantined_sessions
                .set(self.quarantined.len() as f64);
        }
        self.pending -= 1;
        self.failures.push_back(FailedStep {
            session_id: sid,
            seq,
            request: req,
            error: format!(
                "session {sid} quarantined after {streak} consecutive \
                 snapshot failures: {err}"
            ),
        });
        // The rest of the session's queue can never run before an
        // operator intervenes; abandon it as typed failures too.
        if let Some(queue) = self.queues.remove(&sid) {
            self.pending -= queue.len();
            for (qseq, qreq) in queue {
                self.failures.push_back(FailedStep {
                    session_id: sid,
                    seq: qseq,
                    request: qreq,
                    error: format!(
                        "session {sid} quarantined; queued request \
                         abandoned (unquarantine and resubmit to retry)"
                    ),
                });
            }
        }
    }

    /// Run every (session × head) item of an already-resident batch on
    /// the worker pool. Infallible: all IO happened in phase A.
    fn run_resident_batch(
        &mut self,
        batch: &[(u64, StepRequest)],
    ) -> Vec<StepResponse> {
        let ids: Vec<u64> = batch.iter().map(|(_, r)| r.session_id).collect();
        // Fan out: jobs ordered by (request arrival, head index). The
        // pool is single-precision, so every session's heads land in the
        // same per-precision job list — the SessionHeads match below is
        // the once-per-session dispatch of the serve contract.
        let chunk = self.pool.cfg().chunk;
        let workers = self.pool.cfg().worker_count();
        // Request-size telemetry on the serial path, before the fan-out.
        for (_, req) in batch {
            self.obs.observe_rows(req.rows());
            self.obs.rows_served.add(req.rows() as u64);
        }
        let sessions = self.pool.sessions_mut(&ids);
        let mut starts = Vec::with_capacity(batch.len());
        let mut jobs64: Vec<HeadJob<'_, f64>> = Vec::new();
        let mut jobs32: Vec<HeadJob<'_, f32>> = Vec::new();
        for (session, (_, req)) in sessions.into_iter().zip(batch) {
            let (start, heads) = session.begin_step(req.rows() as u64);
            starts.push(start);
            match heads {
                SessionHeads::F64(slots) => {
                    for (slot, input) in slots.iter_mut().zip(&req.heads) {
                        jobs64.push(HeadJob { slot, input });
                    }
                }
                SessionHeads::F32(slots) => {
                    for (slot, input) in slots.iter_mut().zip(&req.heads) {
                        jobs32.push(HeadJob { slot, input });
                    }
                }
            }
        }
        let outputs: Vec<StepOutput> = {
            let _fwd = self.obs.span(&self.obs.forward_ms);
            if jobs32.is_empty() {
                fan_out(jobs64, workers, chunk, StepOutput::F64)
            } else {
                debug_assert!(jobs64.is_empty(), "pool precision is uniform");
                fan_out(jobs32, workers, chunk, StepOutput::F32)
            }
        };
        // Epoch crossings happened inside the fan-out (on workers);
        // surface them now, serially, in batch order — event sequence
        // and gauge registration stay thread-count-invariant.
        for session in self.pool.sessions_mut(&ids) {
            session.drain_epoch_telemetry();
        }

        // Reassemble responses in batch order.
        let mut outputs = outputs.into_iter();
        let mut responses = Vec::with_capacity(batch.len());
        for ((seq, req), start_position) in batch.iter().zip(starts) {
            let head_outputs: Vec<StepOutput> =
                (&mut outputs).take(req.heads.len()).collect();
            responses.push(StepResponse {
                session_id: req.session_id,
                seq: *seq,
                start_position,
                outputs: head_outputs,
            });
        }
        responses
    }

    /// Drain completed responses (in completion order; `seq` identifies
    /// the request).
    pub fn poll_responses(&mut self) -> Vec<StepResponse> {
        self.responses.drain(..).collect()
    }

    /// Tick until the pending queues are empty, then drain everything —
    /// the synchronous, wall-clock-free way to run a workload to
    /// completion. Lossless: responses and failures produced before a
    /// mid-drain error are returned in the [`DrainOutcome`] alongside
    /// it, never dropped. Backoff ticks complete zero requests without
    /// being stalls; the drain only errors out after the retry policy's
    /// worst-case no-progress window is exhausted.
    pub fn run_until_idle(&mut self) -> DrainOutcome {
        // Longest legitimate no-progress stretch: a session can fail
        // `quarantine_any` times, each behind a backoff of at most
        // `backoff_cap` idle ticks, before quarantine shrinks `pending`.
        let max_stall = (self.policy.quarantine_any as u64 + 1)
            * (self.policy.backoff_cap.max(1) + 1)
            + 1;
        let mut stalled = 0u64;
        let mut error = None;
        while self.pending > 0 {
            let before = self.pending;
            match self.tick() {
                Ok(done) => {
                    if done > 0 || self.pending < before {
                        stalled = 0;
                    } else {
                        stalled += 1;
                    }
                    if stalled > max_stall {
                        error = Some(anyhow!(
                            "scheduler stalled: {} request(s) pending with \
                             no progress for {stalled} ticks",
                            self.pending
                        ));
                        break;
                    }
                }
                Err(e) => {
                    error = Some(e);
                    break;
                }
            }
        }
        DrainOutcome {
            responses: self.poll_responses(),
            failures: self.poll_failures(),
            error,
        }
    }
}
