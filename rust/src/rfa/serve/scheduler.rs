//! Session-batched scheduling: coalesce pending step requests into one
//! batch per tick and fan (session × head) work items across worker
//! threads.
//!
//! Requests are held in **per-session FIFO queues** with a ready-list of
//! `(head-of-queue seq, session id)` pairs: a tick drains the head of
//! every non-empty queue (earliest arrival first) in O(batch) work,
//! instead of rescanning a single global backlog — a B-deep
//! single-session backlog no longer costs O(B) queue moves per tick.
//! The scheduling discipline is unchanged: at most one request per
//! session per tick, earliest first; work items ordered by (arrival,
//! head index); job-order reduction via [`crate::rfa::batch::run_jobs`].
//! Together these make every session's output stream a pure function of
//! its seed and its own request sequence — see the determinism contract
//! in the [`super`] module docs.
//!
//! Precision dispatch follows the session-boundary rule: the fan-out
//! unwraps each scheduled session's [`SessionHeads`] once, collects
//! generic [`HeadJob`]s at the pool's storage precision, and runs one
//! generic job loop — no per-head-step precision matching.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::linalg::{Mat, Scalar};
use crate::rfa::engine::Head;

use super::session::{HeadSlot, SessionHeads, SessionPool, StepOutput};

/// One streaming step for one session: a segment of per-head (q, k, v)
/// rows to append to the session's stream. All heads must cover the same
/// positions (equal row counts).
pub struct StepRequest {
    pub session_id: u64,
    pub heads: Vec<Head>,
}

impl StepRequest {
    /// Convenience: the same (q, k, v) segment for every head (the heads
    /// still produce distinct outputs — their banks differ). Note this
    /// clones the segment once per head because requests own per-head
    /// inputs; latency-sensitive callers with genuinely distinct per-head
    /// projections should build `heads` directly (no redundant copies).
    pub fn broadcast(
        session_id: u64,
        n_heads: usize,
        q: Vec<Vec<f64>>,
        k: Vec<Vec<f64>>,
        v: crate::linalg::Matrix,
    ) -> Self {
        let heads = (0..n_heads)
            .map(|_| Head { q: q.clone(), k: k.clone(), v: v.clone() })
            .collect();
        Self { session_id, heads }
    }

    fn rows(&self) -> usize {
        self.heads.first().map_or(0, |h| h.v.rows())
    }
}

/// Outputs for one completed [`StepRequest`].
pub struct StepResponse {
    pub session_id: u64,
    /// Arrival sequence number assigned by [`BatchScheduler::submit`].
    pub seq: u64,
    /// Stream position of the first output row (the session's position
    /// counter before this request applied).
    pub start_position: u64,
    /// One output per head, in head order, in the session's precision.
    pub outputs: Vec<StepOutput>,
}

/// Work item of one scheduling tick: one head of one scheduled session,
/// at the pool's storage precision.
struct HeadJob<'a, T: Scalar> {
    slot: &'a mut HeadSlot<T>,
    input: &'a Head,
}

/// Run one precision's job list on the worker pool and wrap the outputs.
fn fan_out<T: Scalar>(
    mut jobs: Vec<HeadJob<'_, T>>,
    workers: usize,
    chunk: usize,
    wrap: fn(Mat<T>) -> StepOutput,
) -> Vec<StepOutput> {
    crate::rfa::batch::run_jobs(&mut jobs, workers, |job: &mut HeadJob<T>| {
        job.slot.step(job.input, chunk)
    })
    .into_iter()
    .map(wrap)
    .collect()
}

/// Coalescing batch scheduler over a [`SessionPool`].
///
/// `submit` enqueues onto the request's per-session FIFO; each `tick`
/// drains the head of every non-empty queue (earliest first), faults
/// their sessions in, runs all (session × head) items on the worker
/// pool, and queues the responses; `poll_responses` drains completed
/// responses. [`BatchScheduler::run_until_idle`] is the synchronous
/// wall-clock-free drain used by tests and benches.
pub struct BatchScheduler {
    pool: SessionPool,
    /// Per-session FIFO queues of `(seq, request)` in arrival order.
    /// Empty queues are pruned after each tick, so the map stays bounded
    /// by the number of sessions with outstanding work.
    queues: BTreeMap<u64, VecDeque<(u64, StepRequest)>>,
    /// Ready-list: one `(head seq, session id)` entry per non-empty
    /// queue. BTreeSet iteration order *is* the tick's batch order —
    /// earliest head request first.
    ready: BTreeSet<(u64, u64)>,
    /// Total queued requests across all sessions.
    pending: usize,
    responses: VecDeque<StepResponse>,
    next_seq: u64,
    /// A budget re-enforcement failure from the end of a completed tick,
    /// deferred so the tick could still surface its responses. Retried
    /// at the start of the next tick; inspectable via
    /// [`Self::budget_error`]/[`Self::take_budget_error`].
    deferred_budget: Option<anyhow::Error>,
}

impl BatchScheduler {
    pub fn new(pool: SessionPool) -> Self {
        Self {
            pool,
            queues: BTreeMap::new(),
            ready: BTreeSet::new(),
            pending: 0,
            responses: VecDeque::new(),
            next_seq: 0,
            deferred_budget: None,
        }
    }

    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }

    pub fn pool_mut(&mut self) -> &mut SessionPool {
        &mut self.pool
    }

    /// Recover the pool (e.g. to snapshot every session at shutdown).
    pub fn into_pool(self) -> SessionPool {
        self.pool
    }

    /// Number of requests waiting for a tick.
    pub fn pending_len(&self) -> usize {
        self.pending
    }

    /// The deferred budget re-enforcement error from a completed tick,
    /// if one is outstanding (the pool may be over budget until a later
    /// tick re-enforces successfully).
    pub fn budget_error(&self) -> Option<&anyhow::Error> {
        self.deferred_budget.as_ref()
    }

    /// Take (and clear) the deferred budget error.
    pub fn take_budget_error(&mut self) -> Option<anyhow::Error> {
        self.deferred_budget.take()
    }

    /// The current ready-list, in tick batch order: one
    /// `(head-of-queue seq, session id)` pair per non-empty queue.
    /// Introspection for error-path determinism tests.
    pub fn ready_snapshot(&self) -> Vec<(u64, u64)> {
        self.ready.iter().copied().collect()
    }

    /// Every queued request's seq, per session, in queue (arrival)
    /// order. Introspection for error-path determinism tests.
    pub fn queued_seqs(&self) -> BTreeMap<u64, Vec<u64>> {
        self.queues
            .iter()
            .map(|(sid, q)| (*sid, q.iter().map(|&(seq, _)| seq).collect()))
            .collect()
    }

    /// Close a session: drop its queued requests (they will never get
    /// responses), then remove it from the pool — including its snapshot
    /// file if it was evicted (see [`SessionPool::close_session`]).
    pub fn close_session(&mut self, id: u64) -> Result<()> {
        if let Some(queue) = self.queues.remove(&id) {
            if let Some(&(seq, _)) = queue.front() {
                self.ready.remove(&(seq, id));
            }
            self.pending -= queue.len();
        }
        self.pool.close_session(id)
    }

    /// Validate and enqueue a request; returns its arrival sequence
    /// number (echoed in the response).
    pub fn submit(&mut self, req: StepRequest) -> Result<u64> {
        ensure!(
            self.pool.contains(req.session_id),
            "no session with id {}",
            req.session_id
        );
        ensure!(
            !req.heads.is_empty(),
            "request for session {} has no heads",
            req.session_id
        );
        let cfg = self.pool.cfg();
        ensure!(
            req.heads.len() == cfg.n_heads,
            "request for session {} has {} heads, pool serves {}",
            req.session_id,
            req.heads.len(),
            cfg.n_heads
        );
        let rows = req.rows();
        ensure!(
            rows > 0,
            "request for session {} covers zero positions — a step must \
             carry at least one row",
            req.session_id
        );
        let d = cfg.est.dim();
        for (h, head) in req.heads.iter().enumerate() {
            ensure!(
                head.q.len() == rows
                    && head.k.len() == rows
                    && head.v.rows() == rows,
                "head {h}: q/k/v row counts ({}, {}, {}) must all equal {rows}",
                head.q.len(),
                head.k.len(),
                head.v.rows()
            );
            ensure!(
                head.q.iter().chain(&head.k).all(|r| r.len() == d),
                "head {h}: q/k rows must have dim {d}"
            );
            ensure!(
                head.v.cols() == cfg.dv,
                "head {h}: v has {} channels, pool serves {}",
                head.v.cols(),
                cfg.dv
            );
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let queue = self.queues.entry(req.session_id).or_default();
        if queue.is_empty() {
            self.ready.insert((seq, req.session_id));
        }
        queue.push_back((seq, req));
        self.pending += 1;
        Ok(seq)
    }

    /// Run one scheduling tick; returns the number of requests completed
    /// (0 when the queue is empty). On a snapshot-IO error (eviction or
    /// fault-in) *before* any state advanced, the batch goes back to the
    /// front of its sessions' queues in arrival order and the error
    /// propagates — no request is lost. A budget re-enforcement failure
    /// *after* the batch completed is non-fatal: the responses are
    /// already queued and `pending` decremented, so the tick returns
    /// `Ok` and the error is deferred (see [`Self::budget_error`]) and
    /// retried at the start of the next tick.
    pub fn tick(&mut self) -> Result<usize> {
        // Retry a deferred budget re-enforcement first, while nothing is
        // pinned. Still failing is still non-fatal — the pool simply
        // stays over budget until the snapshot dir heals.
        if self.deferred_budget.is_some() {
            match self.pool.ensure_budget(&[]) {
                Ok(()) => self.deferred_budget = None,
                Err(e) => self.deferred_budget = Some(e),
            }
        }
        // Batch: pop the head request of every ready session. The
        // ready-list is ordered by head seq, so the batch comes out in
        // arrival order without touching any deferred request.
        let picked: Vec<(u64, u64)> =
            std::mem::take(&mut self.ready).into_iter().collect();
        let mut batch: Vec<(u64, StepRequest)> =
            Vec::with_capacity(picked.len());
        for &(seq, sid) in &picked {
            let queue =
                self.queues.get_mut(&sid).expect("ready session has a queue");
            let (head_seq, req) =
                queue.pop_front().expect("ready queue is non-empty");
            debug_assert_eq!(head_seq, seq, "ready-list out of sync");
            batch.push((seq, req));
        }
        if batch.is_empty() {
            return Ok(0);
        }
        match self.run_batch(&batch) {
            Ok(responses) => {
                let completed = responses.len();
                self.pending -= completed;
                self.responses.extend(responses);
                // Re-arm the ready-list with each session's next queued
                // request and prune emptied queues.
                for (_, sid) in picked {
                    if let Some(&(seq, _)) =
                        self.queues.get(&sid).and_then(VecDeque::front)
                    {
                        self.ready.insert((seq, sid));
                    }
                }
                self.queues.retain(|_, q| !q.is_empty());
                // A tick pins its whole batch, so a many-session batch
                // can legitimately overshoot the budget while running;
                // re-enforce it now that nothing is pinned. A failure
                // here must NOT fail the tick: every request already
                // completed, its response is queued and `pending` was
                // decremented — returning `Err` would make callers lose
                // a fully-completed drain. Defer the error instead.
                if let Err(e) = self.pool.ensure_budget(&[]) {
                    self.deferred_budget = Some(e);
                }
                Ok(completed)
            }
            Err(e) => {
                // Each batch entry was its session's queue head; put it
                // back in front and rebuild the ready-list from the
                // (unchanged) queue heads.
                for (seq, req) in batch {
                    self.queues
                        .entry(req.session_id)
                        .or_default()
                        .push_front((seq, req));
                }
                self.ready = self
                    .queues
                    .iter()
                    .filter_map(|(sid, q)| {
                        q.front().map(|&(seq, _)| (seq, *sid))
                    })
                    .collect();
                Err(e)
            }
        }
    }

    /// Fault the batch's sessions in and run every (session × head) item
    /// on the worker pool. All fallible (IO) work happens before any
    /// session state is touched, so an `Err` leaves every stream intact.
    fn run_batch(
        &mut self,
        batch: &[(u64, StepRequest)],
    ) -> Result<Vec<StepResponse>> {
        // Fault every scheduled session in, serially, with the whole
        // batch pinned so faulting one in never evicts another.
        let ids: Vec<u64> = batch.iter().map(|(_, r)| r.session_id).collect();
        for &id in &ids {
            self.pool.ensure_resident(id, &ids)?;
        }

        // Fan out: jobs ordered by (request arrival, head index). The
        // pool is single-precision, so every session's heads land in the
        // same per-precision job list — the SessionHeads match below is
        // the once-per-session dispatch of the serve contract.
        let chunk = self.pool.cfg().chunk;
        let workers = self.pool.cfg().worker_count();
        let sessions = self.pool.sessions_mut(&ids);
        let mut starts = Vec::with_capacity(batch.len());
        let mut jobs64: Vec<HeadJob<'_, f64>> = Vec::new();
        let mut jobs32: Vec<HeadJob<'_, f32>> = Vec::new();
        for (session, (_, req)) in sessions.into_iter().zip(batch) {
            let (start, heads) = session.begin_step(req.rows() as u64);
            starts.push(start);
            match heads {
                SessionHeads::F64(slots) => {
                    for (slot, input) in slots.iter_mut().zip(&req.heads) {
                        jobs64.push(HeadJob { slot, input });
                    }
                }
                SessionHeads::F32(slots) => {
                    for (slot, input) in slots.iter_mut().zip(&req.heads) {
                        jobs32.push(HeadJob { slot, input });
                    }
                }
            }
        }
        let outputs: Vec<StepOutput> = if jobs32.is_empty() {
            fan_out(jobs64, workers, chunk, StepOutput::F64)
        } else {
            debug_assert!(jobs64.is_empty(), "pool precision is uniform");
            fan_out(jobs32, workers, chunk, StepOutput::F32)
        };

        // Reassemble responses in batch order.
        let mut outputs = outputs.into_iter();
        let mut responses = Vec::with_capacity(batch.len());
        for ((seq, req), start_position) in batch.iter().zip(starts) {
            let head_outputs: Vec<StepOutput> =
                (&mut outputs).take(req.heads.len()).collect();
            responses.push(StepResponse {
                session_id: req.session_id,
                seq: *seq,
                start_position,
                outputs: head_outputs,
            });
        }
        Ok(responses)
    }

    /// Drain completed responses (in completion order; `seq` identifies
    /// the request).
    pub fn poll_responses(&mut self) -> Vec<StepResponse> {
        self.responses.drain(..).collect()
    }

    /// Tick until the pending queues are empty, then drain every
    /// response — the synchronous, wall-clock-free way to run a workload
    /// to completion.
    pub fn run_until_idle(&mut self) -> Result<Vec<StepResponse>> {
        while self.pending > 0 {
            let done = self.tick()?;
            if done == 0 {
                bail!("scheduler made no progress with non-empty queue");
            }
        }
        Ok(self.poll_responses())
    }
}
