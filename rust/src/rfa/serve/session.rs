//! Sessions and the budgeted session pool.
//!
//! A [`Session`] is one user stream: per-head feature banks drawn from
//! the session seed plus per-head running causal states, advanced one
//! (q, k, v) segment at a time. The [`SessionPool`] owns every session,
//! enforces a resident-memory budget, and evicts least-recently-used
//! sessions to snapshots (never dropping state) so they fault back in
//! transparently on their next request.
//!
//! Precision dispatch happens **once, at the session boundary**: a
//! session's heads are a [`SessionHeads`] — one enum over the generic
//! per-precision [`HeadSlot<T>`] vectors — and every entry point
//! (`step`, the scheduler's fan-out, snapshots) matches on it exactly
//! once before running generic [`crate::linalg::Scalar`] code. Nothing
//! below the session matches on [`Precision`] again.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::linalg::{Mat, Matrix, Matrix32, Scalar};
use crate::obs::serve::bank_anisotropy;
use crate::obs::{EventKind, ObsConfig, ServeObs};
use crate::rfa::engine::{draw_head_banks, CausalState, Head};
use crate::rfa::estimators::PrfEstimator;
use crate::rfa::features::FeatureBank;
use crate::rfa::gaussian::{MultivariateGaussian, SecondMomentAccumulator};
use crate::rng::{GaussianExt, Pcg64};

use super::snapshot;
use super::store::{FsStore, HealthReport, SnapshotStore, StoreError};

/// Numeric precision of a session's forward path. The running state is
/// f64 either way (the engine's `Scalar::Accum` contract); `F32` runs
/// the chunk-local contractions on the f32 SIMD hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F64,
    F32,
}

/// Online bank-resampling policy: every `epoch_positions` stream
/// positions each head freezes its `(bank, S, z)` triple and redraws a
/// data-aware bank against its streaming key second-moment estimate (see
/// the epoch contract in the [`super`] module docs). Deterministic by
/// construction: epoch boundaries are fixed absolute positions, and the
/// epoch-`e` bank of head `h` is a pure function of
/// `(session_seed, h, e)` plus the keys seen before the boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct ResampleConfig {
    /// Epoch length `K` in stream positions (≥ 1): head banks are
    /// redrawn at absolute positions `K, 2K, 3K, …`.
    pub epoch_positions: u64,
    /// Retained frozen epochs per head (≥ 1). Older epochs are dropped
    /// oldest-first, bounding memory; dropping one removes its keys from
    /// the attention window (the sliding-window approximation the module
    /// docs describe).
    pub max_epochs: usize,
    /// Shrinkage λ ∈ (0, 1] toward the identity in the second-moment
    /// estimate `Σ̂ = (1-λ)·C/count + λ·I`, keeping Σ̂ SPD even early in
    /// the stream.
    pub shrinkage: f64,
    /// Frozen-epoch compaction policy. `None` (the default of
    /// [`ResampleConfig::every`]) keeps every retained epoch verbatim —
    /// bitwise-identical to the pre-compaction serving stack; `Some`
    /// bounds resident frozen state to `window` epochs by merging the
    /// oldest into its successor (a documented approximation — see the
    /// epoch contract in the [`super`] module docs).
    pub compaction: Option<CompactionConfig>,
}

/// Frozen-epoch compaction: once more than `window` frozen epochs are
/// resident, the oldest is merged into its successor by projecting its
/// `(S, z)` state through the successor's feature bank — a ridge
/// least-squares fit `M = (Φ₁ᵀΦ₁ + ε·I)⁻¹·Φ₁ᵀ·Φ₀` over `probes` seeded
/// Gaussian probe points, then `S₁ += M·S₀`, `z₁ += M·z₀`. The merged
/// epoch's readout is thereafter approximated in the successor's feature
/// space (error = the feature-space projection residual on the probe
/// distribution); determinism is unaffected because the probes are a
/// pure function of `(session_seed, head, merge_index)`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompactionConfig {
    /// Max resident frozen epochs per head (≥ 1) before the oldest is
    /// merged away. Bounds per-head resident state to O(window) instead
    /// of O(max_epochs).
    pub window: usize,
    /// Probe points per merge (≥ 1); more probes = a better-conditioned
    /// fit of the old feature map in the successor's basis.
    pub probes: usize,
    /// Ridge ε > 0 added to the probe Gram matrix so the fit stays
    /// solvable even when `probes < m`.
    pub ridge: f64,
}

impl CompactionConfig {
    /// Keep at most `window` frozen epochs, with default fit size
    /// (64 probes) and ridge (1e-8).
    pub fn keep(window: usize) -> Self {
        Self { window, probes: 64, ridge: 1e-8 }
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.window >= 1, "compaction window must be >= 1 epoch");
        ensure!(self.probes >= 1, "compaction needs at least one probe");
        ensure!(
            self.ridge > 0.0 && self.ridge.is_finite(),
            "compaction ridge must be positive and finite, got {}",
            self.ridge
        );
        Ok(())
    }
}

impl ResampleConfig {
    /// Resample every `k` positions with default retention (8 epochs),
    /// shrinkage (0.05) and no compaction.
    pub fn every(k: u64) -> Self {
        Self {
            epoch_positions: k,
            max_epochs: 8,
            shrinkage: 0.05,
            compaction: None,
        }
    }

    pub(crate) fn validate(&self) -> Result<()> {
        ensure!(
            self.epoch_positions >= 1,
            "resample epoch length must be >= 1 position"
        );
        ensure!(self.max_epochs >= 1, "must retain at least one epoch");
        ensure!(
            self.shrinkage > 0.0 && self.shrinkage <= 1.0,
            "resample shrinkage must be in (0, 1], got {}",
            self.shrinkage
        );
        if let Some(cc) = &self.compaction {
            cc.validate()?;
        }
        Ok(())
    }
}

/// Serving-layer configuration: model geometry, precision, scheduling
/// knobs and the pool's memory policy.
pub struct ServeConfig {
    /// Estimator geometry the per-head banks are drawn from (input dim
    /// `d`, features `m`, sampling law).
    pub est: PrfEstimator,
    /// Attention heads per session.
    pub n_heads: usize,
    /// Value channels per head.
    pub dv: usize,
    /// Forward-path precision for every session in the pool.
    pub precision: Precision,
    /// Causal chunk length `C` (see [`crate::rfa::engine::EngineConfig`]).
    pub chunk: usize,
    /// Worker threads for the scheduler's (session × head) fan-out;
    /// `0` = all available cores.
    pub threads: usize,
    /// Resident-state budget in bytes; `0` = unlimited. The pool evicts
    /// LRU sessions to snapshots to stay under it (a single session may
    /// exceed the budget — it is then the only resident one).
    pub memory_budget: usize,
    /// Directory evicted-session snapshots are written to.
    pub snapshot_dir: PathBuf,
    /// Online bank-resampling policy; `None` keeps the original static
    /// banks for the life of every session (bitwise-identical to the
    /// pre-resampling serving stack).
    pub resample: Option<ResampleConfig>,
}

impl ServeConfig {
    pub(crate) fn worker_count(&self) -> usize {
        if self.threads == 0 {
            crate::rfa::batch::default_threads()
        } else {
            self.threads
        }
    }
}

/// One head's output rows for one request, in the session's precision.
///
/// The accessor pair is symmetric: exactly one of [`Self::as_f64`] /
/// [`Self::as_f32`] returns `Some` for any given output, so callers
/// never need to pattern-match the enum directly.
#[derive(Debug)]
pub enum StepOutput {
    F64(Matrix),
    F32(Matrix32),
}

impl StepOutput {
    /// Number of output rows (= request positions).
    pub fn rows(&self) -> usize {
        match self {
            StepOutput::F64(m) => m.rows(),
            StepOutput::F32(m) => m.rows(),
        }
    }

    /// Borrow the f64 output rows; `None` for an f32 session's output.
    /// Symmetric counterpart of [`Self::as_f32`].
    pub fn as_f64(&self) -> Option<&Mat<f64>> {
        match self {
            StepOutput::F64(m) => Some(m),
            StepOutput::F32(_) => None,
        }
    }

    /// Borrow the f32 output rows; `None` for an f64 session's output.
    /// Symmetric counterpart of [`Self::as_f64`].
    pub fn as_f32(&self) -> Option<&Mat<f32>> {
        match self {
            StepOutput::F32(m) => Some(m),
            StepOutput::F64(_) => None,
        }
    }

    /// Widen to f64 (copy for f32 outputs) — convenience for checksums
    /// and cross-precision comparisons.
    pub fn to_f64(&self) -> Matrix {
        match self {
            StepOutput::F64(m) => m.clone(),
            StepOutput::F32(m) => m.to_f64(),
        }
    }
}

/// One frozen resample epoch of a head: the bank the epoch's keys were
/// featurized under and the causal prefix `(S, z)` accumulated over
/// exactly that epoch's positions. Read-only after the boundary — later
/// queries only [`CausalState::readout`] against it.
pub struct FrozenEpoch<T: Scalar> {
    pub(crate) bank: FeatureBank,
    pub(crate) state: CausalState<T>,
}

impl<T: Scalar> FrozenEpoch<T> {
    pub fn bank(&self) -> &FeatureBank {
        &self.bank
    }

    pub fn state(&self) -> &CausalState<T> {
        &self.state
    }
}

/// The maintained Cholesky factor of the *unnormalized* shrunk moment
/// `U = (1-λ)·C + λ·floor·I`, where `floor` is the observation count at
/// the last from-scratch refresh, plus the monotone maintenance totals
/// the serial telemetry drain diffs against. `chol` is `None` until the
/// first epoch boundary (U is not factorized before any boundary work
/// exists) and after a (pathological) failed refresh; every state here
/// is persisted bitwise by snapshot schema v3 so evict→restore cannot
/// perturb the refresh schedule or the update stream.
#[derive(Debug, Clone, Default)]
pub(crate) struct FactorState {
    /// Lower Cholesky factor `L` with `U = L·Lᵀ`, streamed forward one
    /// rank-1 update per key observation.
    pub(crate) chol: Option<Matrix>,
    /// Observation count at the last from-scratch refresh — the scale
    /// of the identity floor baked into `U`.
    pub(crate) floor: u64,
    /// Total rank-1 factor updates applied (monotone).
    pub(crate) rank1: u64,
    /// Total from-scratch refactorizations (monotone).
    pub(crate) refreshes: u64,
    /// Total frozen-epoch compaction merges (monotone; also the seed
    /// index of the *next* merge's probe generator).
    pub(crate) compactions: u64,
}

/// Per-head online-resampling state: the streaming second-moment
/// estimate of the head's keys, the epoch counter, the maintained
/// Cholesky factor of the shrunk moment, and the retained frozen
/// `(bank, S, z)` triples of past epochs (oldest first).
pub struct OnlineState<T: Scalar> {
    pub(crate) cfg: ResampleConfig,
    pub(crate) seed: u64,
    pub(crate) head: usize,
    pub(crate) epoch: u64,
    pub(crate) moment: SecondMomentAccumulator,
    pub(crate) factor: FactorState,
    pub(crate) frozen: VecDeque<FrozenEpoch<T>>,
}

impl<T: Scalar> OnlineState<T> {
    pub(crate) fn fresh(
        cfg: ResampleConfig,
        seed: u64,
        head: usize,
        d: usize,
    ) -> Self {
        Self {
            cfg,
            seed,
            head,
            epoch: 0,
            moment: SecondMomentAccumulator::new(d),
            factor: FactorState::default(),
            frozen: VecDeque::new(),
        }
    }

    /// Rebuild from snapshotted parts (the restore half of the snapshot
    /// surface; schema v2 restores carry a default [`FactorState`] — the
    /// next boundary refreshes from scratch).
    pub(crate) fn from_parts(
        cfg: ResampleConfig,
        seed: u64,
        head: usize,
        epoch: u64,
        moment: SecondMomentAccumulator,
        factor: FactorState,
        frozen: VecDeque<FrozenEpoch<T>>,
    ) -> Self {
        Self { cfg, seed, head, epoch, moment, factor, frozen }
    }

    /// Completed resamples so far (0 = still on the initial bank).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn config(&self) -> &ResampleConfig {
        &self.cfg
    }

    /// Key positions folded into the second-moment estimate (= the
    /// head's stream position).
    pub fn count(&self) -> u64 {
        self.moment.count()
    }

    /// Retained frozen epochs.
    pub fn frozen_len(&self) -> usize {
        self.frozen.len()
    }

    /// The maintained lower Cholesky factor of the unnormalized shrunk
    /// moment `U = (1-λ)·C + λ·floor·I`; `None` before the first epoch
    /// boundary.
    pub fn chol_factor(&self) -> Option<&Matrix> {
        self.factor.chol.as_ref()
    }

    /// Observation count at the last from-scratch factor refresh.
    pub fn chol_floor(&self) -> u64 {
        self.factor.floor
    }

    /// Total rank-1 factor updates applied so far (monotone).
    pub fn chol_rank1_updates(&self) -> u64 {
        self.factor.rank1
    }

    /// Total from-scratch refactorizations so far (monotone).
    pub fn chol_refreshes(&self) -> u64 {
        self.factor.refreshes
    }

    /// Total frozen-epoch compaction merges so far (monotone).
    pub fn compactions(&self) -> u64 {
        self.factor.compactions
    }

    /// O(d) anisotropy proxy of the effective covariance
    /// `Σ̃ = U/count`, read straight off the maintained factor:
    /// `ln(tr(Σ̃)/d) − logdet(Σ̃)/d` with
    /// `logdet Σ̃ = 2·Σᵢ ln Lᵢᵢ − d·ln count` and the trace taken from
    /// the running sum's diagonal. `None` when no factor is maintained
    /// yet (pre-first-boundary, or after a failed refresh) — callers
    /// fall back to the on-demand [`bank_anisotropy`] proxy.
    pub fn factor_anisotropy(&self) -> Option<f64> {
        let l = self.factor.chol.as_ref()?;
        let count = self.moment.count();
        if count == 0 {
            return None;
        }
        let d = self.moment.dim();
        let c = count as f64;
        let lambda = self.cfg.shrinkage;
        let mut trace = 0.0;
        for i in 0..d {
            trace += (1.0 - lambda) * self.moment.sum()[(i, i)] / c
                + lambda * self.factor.floor as f64 / c;
        }
        let logdet = 2.0
            * (0..d).map(|i| l[(i, i)].ln()).sum::<f64>()
            - d as f64 * c.ln();
        Some(((trace / d as f64).ln() - logdet / d as f64).max(0.0))
    }
}

/// The epoch-`e` resample generator for head `h` of a session: a pure
/// function of `(session_seed, h, e)` — no generator state is carried
/// across epochs, so evict→restore cannot perturb future draws.
fn resample_rng(seed: u64, head: usize, epoch: u64) -> Pcg64 {
    Pcg64::seed_stream(
        seed ^ epoch.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        0x00da_7aaa_0000_0000 ^ head as u64,
    )
}

/// The probe generator of compaction merge `merge_index` for head `h`:
/// like [`resample_rng`], a pure function of `(session_seed, h, index)`
/// on a stream disjoint from the resample draws, so merges are
/// deterministic across thread counts, ticks and evict→restore (the
/// merge index is persisted as part of the factor state).
fn compaction_rng(seed: u64, head: usize, merge_index: u64) -> Pcg64 {
    Pcg64::seed_stream(
        seed ^ merge_index.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        0x00da_7acc_0000_0000 ^ head as u64,
    )
}

/// `U = (1-λ)·C + λ·floor·I` — the unnormalized shrunk moment whose
/// lower Cholesky factor [`FactorState`] maintains across boundaries.
/// Materialized O(d²) only on from-scratch refreshes.
fn unnormalized_shrunk(sum: &Matrix, lambda: f64, floor: u64) -> Matrix {
    let mut u = sum.scale(1.0 - lambda);
    for i in 0..u.rows() {
        u[(i, i)] += lambda * floor as f64;
    }
    u
}

/// The effective covariance the redraw and the feature normalizers see:
/// `Σ̃ = U/count = (1-λ)·C/count + λ·(floor/count)·I`, materialized
/// O(d²) straight from the running sum — never via `L·Lᵀ`. Between
/// refreshes `floor/count ∈ (1/2, 1]` (the doubling rule), so Σ̃ tracks
/// the exact shrunk estimate `Σ̂ = (1-λ)·C/count + λ·I` up to at most a
/// 2× decay of the identity floor (see the epoch contract).
fn effective_sigma(
    sum: &Matrix,
    lambda: f64,
    floor: u64,
    count: u64,
) -> Matrix {
    let c = count as f64;
    let mut sigma = sum.scale((1.0 - lambda) / c);
    for i in 0..sigma.rows() {
        sigma[(i, i)] += lambda * floor as f64 / c;
    }
    sigma
}

/// One head of a session: its feature bank plus its running state at the
/// session's storage precision, and — when resampling is configured —
/// the online covariance/epoch state. The scheduler's unit of parallel
/// work.
pub struct HeadSlot<T: Scalar> {
    pub(crate) bank: FeatureBank,
    pub(crate) state: CausalState<T>,
    pub(crate) online: Option<OnlineState<T>>,
}

impl<T: Scalar> HeadSlot<T> {
    pub fn bank(&self) -> &FeatureBank {
        &self.bank
    }

    pub fn state(&self) -> &CausalState<T> {
        &self.state
    }

    /// Online-resampling state; `None` for static-bank sessions.
    pub fn online(&self) -> Option<&OnlineState<T>> {
        self.online.as_ref()
    }

    /// Completed resample epochs (0 for static-bank heads).
    pub fn epoch(&self) -> u64 {
        self.online.as_ref().map_or(0, |o| o.epoch)
    }
}

/// The stepping half of a head slot. Bounded to `Scalar::Accum = f64`
/// (true of every precision — the sealed-trait accumulation policy)
/// so the epoch machinery can run factor maintenance and compaction
/// merges directly on the f64 accumulator matrices.
impl<T: Scalar<Accum = f64>> HeadSlot<T> {
    /// Advance this head by one request segment and return its output
    /// rows. Chunk blocking restarts at the segment start (the
    /// determinism contract in the module docs). The f64-side input
    /// values are rounded to `T` at this boundary (a borrow on the f64
    /// path).
    pub(crate) fn step(&mut self, input: &Head, chunk: usize) -> Mat<T> {
        if self.online.is_some() {
            return self.step_online(input, chunk);
        }
        let phi_q = self.bank.feature_matrix_t::<T>(&input.q);
        let phi_k = self.bank.feature_matrix_t::<T>(&input.k);
        let v = T::mat_from_f64(&input.v);
        self.state.forward(&phi_q, &phi_k, &v, chunk)
    }

    /// The online forward: split the segment at epoch boundaries; per
    /// span, fold keys into the moment estimate, run the current-epoch
    /// unnormalized forward, add every frozen epoch's readout
    /// (numerators and denominators summed in `Scalar::Accum`,
    /// oldest-first, current-epoch last), divide once. With no frozen
    /// epochs and no boundary inside the segment this reduces to the
    /// static path's exact operations (adding into an all-zero `Accum`
    /// sum is exact), so enabling resampling changes no bits before the
    /// first boundary.
    fn step_online(&mut self, input: &Head, chunk: usize) -> Mat<T> {
        let l = input.v.rows();
        let dv = self.state.dv();
        let mut out: Mat<T> = Mat::zeros(l, dv);
        let mut b = 0usize;
        while b < l {
            let online = self.online.as_mut().expect("online state present");
            let k_epoch = online.cfg.epoch_positions;
            let into_epoch = online.moment.count() % k_epoch;
            let span = ((k_epoch - into_epoch) as usize).min(l - b);
            let e = b + span;

            let q_span = &input.q[b..e];
            let k_span = &input.k[b..e];
            // Stream order: keys enter the moment estimate span by span,
            // so the estimate at a boundary is independent of how the
            // stream was sliced into requests. The maintained factor
            // streams forward with the same keys — `U += (1-λ)·k·kᵀ` is
            // one O(d²) rank-1 update with `√(1-λ)·k` — in the same
            // order, so factor and moment stay in lockstep regardless of
            // request slicing. No factor exists before the first epoch
            // boundary, so enabling resampling still changes no bits
            // (and does no extra work) until a boundary is crossed.
            let up_scale = (1.0 - online.cfg.shrinkage).sqrt();
            for key in k_span {
                online.moment.accumulate(key);
                if let Some(l) = online.factor.chol.as_mut() {
                    let x: Vec<f64> =
                        key.iter().map(|&k| up_scale * k).collect();
                    l.cholesky_update_rank1(&x);
                    online.factor.rank1 += 1;
                }
            }
            let phi_q = self.bank.feature_matrix_t::<T>(q_span);
            let phi_k = self.bank.feature_matrix_t::<T>(k_span);
            let v_span = input.v.row_block(b, e);
            let v_t = T::mat_from_f64(&v_span);
            let (num_cur, den_cur) =
                self.state.forward_unnormalized(&phi_q, &phi_k, &v_t, chunk);

            // Frozen-epoch readouts, oldest → newest, then the current
            // epoch — a fixed summation order independent of request
            // slicing and thread count.
            let mut num =
                vec![<T::Accum as Scalar>::ZERO; span * dv];
            let mut den = vec![<T::Accum as Scalar>::ZERO; span];
            for fe in &online.frozen {
                let phi_qe = fe.bank.feature_matrix_t::<T>(q_span);
                let (num_e, den_e) = fe.state.readout(&phi_qe);
                for (acc, &x) in num.iter_mut().zip(num_e.data()) {
                    *acc += x.to_accum();
                }
                for (acc, x) in den.iter_mut().zip(den_e) {
                    *acc += x;
                }
            }
            for (acc, &x) in num.iter_mut().zip(num_cur.data()) {
                *acc += x.to_accum();
            }
            for (acc, x) in den.iter_mut().zip(den_cur) {
                *acc += x;
            }
            for t in 0..span {
                let d = den[t];
                let orow = &mut out.data_mut()[(b + t) * dv..(b + t + 1) * dv];
                for (o, &nx) in orow.iter_mut().zip(&num[t * dv..(t + 1) * dv])
                {
                    *o = T::from_accum(nx / d);
                }
            }

            // Epoch boundary reached: freeze the triple and redraw the
            // bank against the maintained factor of the shrunk
            // second-moment estimate (the epoch contract in the module
            // docs).
            if online.moment.count() % k_epoch == 0 {
                online.epoch += 1;
                let d_in = self.bank.dim();
                let count = online.moment.count();
                let lambda = online.cfg.shrinkage;
                // Refresh from scratch only when no factor exists yet
                // (first boundary, restore from a pre-v3 snapshot, or a
                // previously failed refresh) or the identity floor has
                // decayed past 2× (count ≥ 2·floor) — the doubling rule
                // that makes O(d³) refreshes O(log positions) per
                // session and every other boundary O(d²·k).
                let refresh = match &online.factor.chol {
                    Some(_) => count >= 2 * online.factor.floor,
                    None => true,
                };
                if refresh {
                    let u = unnormalized_shrunk(
                        online.moment.sum(),
                        lambda,
                        count,
                    );
                    match u.cholesky() {
                        Some(l) => {
                            online.factor.chol = Some(l);
                            online.factor.floor = count;
                            online.factor.refreshes += 1;
                        }
                        None => {
                            // Pathological rounding can defeat the
                            // shrinkage floor; drop the factor and fall
                            // back to the isotropic geometry
                            // deterministically rather than fail the
                            // step (the next boundary retries).
                            online.factor.chol = None;
                        }
                    }
                }
                let gauss = match &online.factor.chol {
                    Some(l) => {
                        // Scaled-factor identity: chol(U/c) = L/√c, so
                        // the redraw consumes the maintained factor in
                        // O(d²); Σ̃ for the feature normalizers is
                        // materialized O(d²) from the running sum,
                        // never via L·Lᵀ.
                        let sigma = effective_sigma(
                            online.moment.sum(),
                            lambda,
                            online.factor.floor,
                            count,
                        );
                        let chol = l.scale(1.0 / (count as f64).sqrt());
                        MultivariateGaussian::from_parts(sigma, chol)
                    }
                    None => {
                        MultivariateGaussian::new(Matrix::identity(d_in))
                            .expect("identity is SPD")
                    }
                };
                let mut rng =
                    resample_rng(online.seed, online.head, online.epoch);
                let n = self.state.n_features();
                let new_bank = FeatureBank::draw_data_aware(
                    self.bank.n_features(),
                    gauss,
                    &mut rng,
                );
                let old_bank = std::mem::replace(&mut self.bank, new_bank);
                let old_state = std::mem::replace(
                    &mut self.state,
                    CausalState::new(n, dv),
                );
                online
                    .frozen
                    .push_back(FrozenEpoch { bank: old_bank, state: old_state });
                // Compaction (when configured) bounds resident frozen
                // epochs to the window by merging oldest → successor;
                // the max_epochs trim below is then a no-op unless the
                // window exceeds it.
                if let Some(cc) = online.cfg.compaction.clone() {
                    while online.frozen.len() > cc.window
                        && online.frozen.len() >= 2
                    {
                        let mut rng = compaction_rng(
                            online.seed,
                            online.head,
                            online.factor.compactions,
                        );
                        compact_oldest(&mut online.frozen, &cc, &mut rng);
                        online.factor.compactions += 1;
                    }
                }
                while online.frozen.len() > online.cfg.max_epochs {
                    online.frozen.pop_front();
                }
            }
            b = e;
        }
        out
    }
}

/// Merge the oldest frozen epoch into its successor (the compaction
/// approximation): probe both feature maps at `cc.probes` seeded
/// Gaussian points, fit the old map in the successor's feature basis by
/// ridge least squares `M = (Φ₁ᵀΦ₁ + ε·I)⁻¹·Φ₁ᵀ·Φ₀`, and fold the old
/// accumulators through it: `S₁ += M·S₀`, `z₁ += M·z₀`. All merge math
/// runs in the f64 accumulator space (`Scalar::Accum`), so the merged
/// state is a pure function of the two epochs and the probe stream —
/// determinism survives. On the (ridge-guarded, practically
/// unreachable) failure of the Gram inversion the oldest epoch is
/// dropped instead — the same outcome the max_epochs trim would
/// eventually produce, and equally deterministic.
fn compact_oldest<T: Scalar<Accum = f64>>(
    frozen: &mut VecDeque<FrozenEpoch<T>>,
    cc: &CompactionConfig,
    rng: &mut Pcg64,
) {
    debug_assert!(frozen.len() >= 2, "compaction needs a successor");
    let old = frozen.pop_front().expect("compaction needs >= 2 epochs");
    let succ = frozen.front_mut().expect("compaction needs a successor");
    let d = old.bank.dim();
    let m = old.bank.n_features();
    let probes: Vec<Vec<f64>> =
        (0..cc.probes).map(|_| rng.gaussian_vec(d)).collect();
    let phi_old = old.bank.feature_matrix_t::<f64>(&probes);
    let phi_succ = succ.bank.feature_matrix_t::<f64>(&probes);
    let mut gram = phi_succ.transpose().matmul(&phi_succ);
    for i in 0..m {
        gram[(i, i)] += cc.ridge;
    }
    let Some(inv) = gram.inverse_spd() else {
        return;
    };
    let map = inv.matmul(&phi_succ.transpose().matmul(&phi_old));
    let s_merged = succ.state.state().add(&map.matmul(old.state.state()));
    let z_old = map.matvec(old.state.z());
    let z_merged: Vec<f64> = succ
        .state
        .z()
        .iter()
        .zip(&z_old)
        .map(|(a, b)| a + b)
        .collect();
    succ.state = CausalState::from_parts(s_merged, z_merged);
}

/// The per-precision half of a session: every head at one compile-time
/// storage precision. The single place the runtime [`Precision`] choice
/// meets the generic engine — constructed once per session, matched once
/// per entry point.
pub enum SessionHeads {
    F64(Vec<HeadSlot<f64>>),
    F32(Vec<HeadSlot<f32>>),
}

impl SessionHeads {
    pub fn len(&self) -> usize {
        match self {
            SessionHeads::F64(slots) => slots.len(),
            SessionHeads::F32(slots) => slots.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn precision(&self) -> Precision {
        match self {
            SessionHeads::F64(_) => Precision::F64,
            SessionHeads::F32(_) => Precision::F32,
        }
    }

    /// Per-head banks, precision-erased (banks are always f64 objects).
    pub fn banks(&self) -> Vec<&FeatureBank> {
        match self {
            SessionHeads::F64(slots) => slots.iter().map(|s| &s.bank).collect(),
            SessionHeads::F32(slots) => slots.iter().map(|s| &s.bank).collect(),
        }
    }
}

/// Build the per-precision head slots from freshly drawn banks.
fn fresh_slots<T: Scalar>(
    banks: Vec<FeatureBank>,
    n: usize,
    dv: usize,
    seed: u64,
    resample: Option<&ResampleConfig>,
) -> Vec<HeadSlot<T>> {
    banks
        .into_iter()
        .enumerate()
        .map(|(h, bank)| {
            let online = resample
                .map(|rc| OnlineState::fresh(rc.clone(), seed, h, bank.dim()));
            HeadSlot { bank, state: CausalState::new(n, dv), online }
        })
        .collect()
}

/// Per-head kernel-quality readout for the obs gauges: importance-weight
/// ESS, Σ̂ anisotropy, completed epochs, and resident bytes of the
/// retained frozen epochs. Pure reads — called only from serial
/// telemetry paths, never from the worker fan-out. The anisotropy comes
/// O(d) off the maintained factor when one exists; only static-bank
/// heads (and online heads before their first boundary) fall back to
/// the on-demand O(d³) [`bank_anisotropy`] proxy.
fn slot_quality<T: Scalar>(
    slot: &HeadSlot<T>,
    dv: usize,
) -> (f64, f64, u64, u64) {
    const F64_BYTES: usize = std::mem::size_of::<f64>();
    let frozen_bytes = slot.online.as_ref().map_or(0, |o| {
        o.frozen
            .iter()
            .map(|fe| {
                let n = fe.bank.n_features();
                (bank_floats(&fe.bank) + n * dv + n) * F64_BYTES
            })
            .sum::<usize>()
    }) as u64;
    let anisotropy = slot
        .online
        .as_ref()
        .and_then(OnlineState::factor_anisotropy)
        .unwrap_or_else(|| bank_anisotropy(&slot.bank));
    (
        slot.bank.effective_sample_size(),
        anisotropy,
        slot.epoch(),
        frozen_bytes,
    )
}

/// Advance every slot by one request segment, serially, heads in order.
fn step_slots<T: Scalar<Accum = f64>>(
    slots: &mut [HeadSlot<T>],
    inputs: &[Head],
    chunk: usize,
) -> Vec<Mat<T>> {
    slots
        .iter_mut()
        .zip(inputs)
        .map(|(slot, input)| slot.step(input, chunk))
        .collect()
}

/// f64 slots held by one bank: omegas, weights, √weights, optional Σ.
fn bank_floats(bank: &FeatureBank) -> usize {
    let (n, d) = (bank.n_features(), bank.dim());
    n * d + 2 * n + bank.norm_sigma().map_or(0, |s| s.rows() * s.cols())
}

/// Resident bytes of a slot vector: per-head bank (omegas, weights,
/// √weights, optional Σ) plus running state (`Scalar::Accum` = f64
/// accumulators in every precision), plus — for online heads — the
/// covariance accumulator, the maintained Cholesky factor (once one
/// exists) and every retained frozen epoch's bank+state.
fn slots_bytes<T: Scalar>(slots: &[HeadSlot<T>], dv: usize) -> usize {
    const F64_BYTES: usize = std::mem::size_of::<f64>();
    let state_floats = |n: usize| n * dv + n;
    slots
        .iter()
        .map(|h| {
            let n = h.bank.n_features();
            let mut floats = bank_floats(&h.bank) + state_floats(n);
            if let Some(online) = &h.online {
                let d = online.moment.dim();
                floats += d * d;
                if online.factor.chol.is_some() {
                    floats += d * d;
                }
                floats += online
                    .frozen
                    .iter()
                    .map(|fe| {
                        bank_floats(&fe.bank)
                            + state_floats(fe.bank.n_features())
                    })
                    .sum::<usize>();
            }
            floats * F64_BYTES
        })
        .sum()
}

/// Per-head factor-maintenance totals `(rank1 updates, refreshes,
/// compactions)` — the quantities [`Session::drain_epoch_telemetry`]
/// diffs against its `reported_chol` baseline. Static-bank heads report
/// zeros.
fn head_chol_totals(heads: &SessionHeads) -> Vec<(u64, u64, u64)> {
    fn totals<T: Scalar>(slots: &[HeadSlot<T>]) -> Vec<(u64, u64, u64)> {
        slots
            .iter()
            .map(|s| {
                s.online.as_ref().map_or((0, 0, 0), |o| {
                    (o.factor.rank1, o.factor.refreshes, o.factor.compactions)
                })
            })
            .collect()
    }
    match heads {
        SessionHeads::F64(slots) => totals(slots),
        SessionHeads::F32(slots) => totals(slots),
    }
}

/// One streaming user: per-head banks + causal states, a monotone
/// position counter, and byte accounting for the pool's budget.
pub struct Session {
    id: u64,
    seed: u64,
    position: u64,
    dv: usize,
    resample: Option<ResampleConfig>,
    heads: SessionHeads,
    /// Last epoch per head already surfaced to telemetry; epoch crossings
    /// happen inside the worker fan-out, so the serial paths diff against
    /// this to emit counters/events without touching worker code.
    reported_epochs: Vec<u64>,
    /// Last factor-maintenance totals per head already surfaced to
    /// telemetry, as `(rank1 updates, refreshes, compactions)` — same
    /// serial-diff scheme as `reported_epochs`, so the `rfa_chol_*` and
    /// `rfa_compactions` counters stay write-only for workers.
    reported_chol: Vec<(u64, u64, u64)>,
    /// The pool's observability handle (attached by the pool at create
    /// and restore). Write-only: nothing in the session reads it back.
    obs: Option<Arc<ServeObs>>,
}

impl Session {
    /// Fresh session: epoch-0 banks drawn via [`draw_head_banks`] from
    /// the session seed (bank h is a pure function of (seed, h)), all
    /// states zero. The one precision dispatch of the session's lifetime
    /// happens here. When `cfg.resample` is set, each head additionally
    /// carries fresh [`OnlineState`].
    pub(crate) fn new(id: u64, seed: u64, cfg: &ServeConfig) -> Self {
        let banks =
            draw_head_banks(&cfg.est, cfg.n_heads, &mut Pcg64::seed(seed));
        let n = cfg.est.m;
        let resample = cfg.resample.clone();
        let heads = match cfg.precision {
            Precision::F64 => SessionHeads::F64(fresh_slots(
                banks,
                n,
                cfg.dv,
                seed,
                resample.as_ref(),
            )),
            Precision::F32 => SessionHeads::F32(fresh_slots(
                banks,
                n,
                cfg.dv,
                seed,
                resample.as_ref(),
            )),
        };
        let reported_epochs = vec![0; heads.len()];
        let reported_chol = vec![(0, 0, 0); heads.len()];
        Self {
            id,
            seed,
            position: 0,
            dv: cfg.dv,
            resample,
            heads,
            reported_epochs,
            reported_chol,
            obs: None,
        }
    }

    /// Reassemble a session from restored parts (the snapshot path).
    /// Epochs completed before the snapshot were already reported by the
    /// pre-eviction incarnation, so telemetry resumes from the restored
    /// epoch counters rather than re-emitting old boundary events.
    pub(crate) fn from_parts(
        id: u64,
        seed: u64,
        position: u64,
        dv: usize,
        resample: Option<ResampleConfig>,
        heads: SessionHeads,
    ) -> Self {
        let reported_epochs = match &heads {
            SessionHeads::F64(slots) => {
                slots.iter().map(HeadSlot::epoch).collect()
            }
            SessionHeads::F32(slots) => {
                slots.iter().map(HeadSlot::epoch).collect()
            }
        };
        let reported_chol = head_chol_totals(&heads);
        Self {
            id,
            seed,
            position,
            dv,
            resample,
            heads,
            reported_epochs,
            reported_chol,
            obs: None,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Stream position: total rows processed so far.
    pub fn position(&self) -> u64 {
        self.position
    }

    /// The session's storage precision (a property of its head slots).
    pub fn precision(&self) -> Precision {
        self.heads.precision()
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    pub fn dv(&self) -> usize {
        self.dv
    }

    pub fn heads(&self) -> &SessionHeads {
        &self.heads
    }

    /// The session's resampling policy (`None` = static banks).
    pub fn resample_config(&self) -> Option<&ResampleConfig> {
        self.resample.as_ref()
    }

    /// Completed resample epochs per head (all zeros for static-bank
    /// sessions).
    pub fn head_epochs(&self) -> Vec<u64> {
        match &self.heads {
            SessionHeads::F64(slots) => {
                slots.iter().map(HeadSlot::epoch).collect()
            }
            SessionHeads::F32(slots) => {
                slots.iter().map(HeadSlot::epoch).collect()
            }
        }
    }

    pub(crate) fn advance(&mut self, rows: u64) {
        self.position += rows;
    }

    /// Hook the session up to its pool's observability handle and publish
    /// the initial per-head kernel-quality gauges. Serial paths only
    /// (create and restore).
    pub(crate) fn attach_obs(&mut self, obs: Arc<ServeObs>) {
        if obs.gauges_enabled() {
            for h in 0..self.heads.len() {
                let (ess, aniso, epochs, frozen) = self.head_quality(h);
                obs.set_head_gauges(self.id, h, ess, aniso, epochs, frozen);
            }
        }
        self.obs = Some(obs);
    }

    fn head_quality(&self, h: usize) -> (f64, f64, u64, u64) {
        match &self.heads {
            SessionHeads::F64(slots) => slot_quality(&slots[h], self.dv),
            SessionHeads::F32(slots) => slot_quality(&slots[h], self.dv),
        }
    }

    /// Surface resample-epoch crossings that happened since the last
    /// call: one counter bump + event per crossed boundary, then a
    /// refresh of the changed heads' kernel-quality gauges (timed as the
    /// `rfa_resample_ms` span). Epoch crossings occur inside the worker
    /// fan-out; this diff runs on serial paths only (end of
    /// [`Session::step`], end of a scheduler batch), which is what keeps
    /// event order and gauge registration thread-count-invariant. Pure
    /// reads of head state — outputs are unaffected (the write-only
    /// rule).
    pub(crate) fn drain_epoch_telemetry(&mut self) {
        let Some(obs) = self.obs.clone() else {
            return;
        };
        let epochs = self.head_epochs();
        let mut crossed = Vec::new();
        for (h, (&cur, reported)) in
            epochs.iter().zip(&mut self.reported_epochs).enumerate()
        {
            if cur == *reported {
                continue;
            }
            for e in *reported + 1..=cur {
                obs.resample_epochs.inc();
                obs.event(EventKind::ResampleEpoch {
                    session: self.id,
                    head: h,
                    epoch: e,
                });
            }
            *reported = cur;
            crossed.push(h);
        }
        // Factor-maintenance counters use the same serial diff: workers
        // only bump plain per-head totals; this turns the deltas into
        // shared counters and (for compaction merges) ring events.
        let chol = head_chol_totals(&self.heads);
        for (h, (&(rank1, refreshes, compactions), reported)) in
            chol.iter().zip(&mut self.reported_chol).enumerate()
        {
            obs.chol_rank1_updates.add(rank1 - reported.0);
            obs.chol_refreshes.add(refreshes - reported.1);
            for m in reported.2 + 1..=compactions {
                obs.compactions.inc();
                obs.event(EventKind::Compaction {
                    session: self.id,
                    head: h,
                    merges: m,
                });
            }
            *reported = (rank1, refreshes, compactions);
        }
        if !crossed.is_empty() && obs.gauges_enabled() {
            let _span = obs.span(&obs.resample_ms);
            for h in crossed {
                let (ess, aniso, ep, frozen) = self.head_quality(h);
                obs.set_head_gauges(self.id, h, ess, aniso, ep, frozen);
            }
        }
    }

    /// Start one request of `rows` positions: bumps the position counter
    /// and hands out the head slots for the scheduler's fan-out. Returns
    /// the stream position of the request's first row.
    pub(crate) fn begin_step(
        &mut self,
        rows: u64,
    ) -> (u64, &mut SessionHeads) {
        let start = self.position;
        self.position += rows;
        (start, &mut self.heads)
    }

    /// Resident bytes of this session (banks + running state).
    pub fn state_bytes(&self) -> usize {
        match &self.heads {
            SessionHeads::F64(slots) => slots_bytes(slots, self.dv),
            SessionHeads::F32(slots) => slots_bytes(slots, self.dv),
        }
    }

    /// Advance every head by one request segment, serially, heads in
    /// order; returns one output per head and bumps the position
    /// counter. The scheduler's threaded fan-out computes exactly this,
    /// head by head, on workers — outputs are bitwise identical.
    pub fn step(&mut self, inputs: &[Head], chunk: usize) -> Vec<StepOutput> {
        assert_eq!(inputs.len(), self.heads.len(), "one input per head");
        let rows = inputs.first().map_or(0, |h| h.v.rows());
        assert!(
            inputs.iter().all(|h| h.v.rows() == rows),
            "all heads of a request must cover the same positions"
        );
        let out: Vec<StepOutput> = match &mut self.heads {
            SessionHeads::F64(slots) => step_slots(slots, inputs, chunk)
                .into_iter()
                .map(StepOutput::F64)
                .collect(),
            SessionHeads::F32(slots) => step_slots(slots, inputs, chunk)
                .into_iter()
                .map(StepOutput::F32)
                .collect(),
        };
        self.advance(rows as u64);
        self.drain_epoch_telemetry();
        out
    }
}

/// Eviction/restore counters — a cheap point-in-time view over the
/// pool's [`ServeObs`] registry (the counters themselves live there, at
/// every [`crate::obs::ObsLevel`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct PoolStats {
    /// Sessions written out to snapshots to stay under the budget.
    pub evictions: u64,
    /// Sessions faulted back in from snapshots.
    pub restores: u64,
}

/// Owns every session, resident or evicted. Resident sessions live in
/// memory; evicted ones live as DKFT snapshots under
/// [`ServeConfig::snapshot_dir`] and are faulted back in on demand.
pub struct SessionPool {
    cfg: ServeConfig,
    resident: BTreeMap<u64, Session>,
    evicted: BTreeMap<u64, PathBuf>,
    /// id → last-used stamp; victim choice is min (stamp, id), so LRU
    /// order is deterministic.
    last_used: BTreeMap<u64, u64>,
    clock: u64,
    next_id: u64,
    /// Process-unique pool tag, part of every eviction-snapshot filename:
    /// session ids restart at 0 per pool, so two pools sharing a
    /// `snapshot_dir` must not overwrite each other's eviction files.
    /// (Eviction snapshots are a pool-private cache; durable archival
    /// goes through explicit [`super::save_session`] paths.)
    pool_tag: u64,
    /// The snapshot-IO backend; all durable traffic goes through it.
    store: Box<dyn SnapshotStore>,
    /// The last snapshot write failed and none has succeeded since.
    /// While set: eviction is suspended (residents overshoot the soft
    /// budget instead of risking data loss) and admission control
    /// rejects new sessions once resident bytes reach the budget.
    /// Control flow reads this field — the obs `rfa_degraded` gauge only
    /// mirrors it (the write-only rule).
    degraded: bool,
    /// Snapshot files whose unlink failed; retried at the next
    /// eviction/close/heal so a flaky FS can't accrete files invisibly.
    orphans: BTreeSet<PathBuf>,
    /// Observability: counters (always live — they back [`PoolStats`]
    /// and [`HealthReport`]), spans/gauges/events per its configured
    /// level. Shared with the scheduler and every session.
    obs: Arc<ServeObs>,
}

impl SessionPool {
    pub fn new(cfg: ServeConfig) -> Self {
        Self::with_store(cfg, Box::new(FsStore))
    }

    /// A pool over an explicit snapshot backend — how the chaos suite
    /// injects a [`super::store::FaultyStore`]. Observability verbosity
    /// comes from `RFA_OBS`; use [`Self::with_obs`] to pin it.
    pub fn with_store(cfg: ServeConfig, store: Box<dyn SnapshotStore>) -> Self {
        Self::with_obs(cfg, store, ObsConfig::from_env())
    }

    /// A pool with an explicit snapshot backend *and* observability
    /// configuration — how the determinism tests run the same workload
    /// at [`crate::obs::ObsLevel::Off`] and `Full` side by side.
    pub fn with_obs(
        cfg: ServeConfig,
        store: Box<dyn SnapshotStore>,
        obs_cfg: ObsConfig,
    ) -> Self {
        static POOL_COUNTER: AtomicU64 = AtomicU64::new(0);
        Self {
            cfg,
            resident: BTreeMap::new(),
            evicted: BTreeMap::new(),
            last_used: BTreeMap::new(),
            clock: 0,
            next_id: 0,
            pool_tag: POOL_COUNTER.fetch_add(1, Ordering::Relaxed),
            store,
            degraded: false,
            orphans: BTreeSet::new(),
            obs: ServeObs::new(obs_cfg),
        }
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The pool's observability handle: registry, event ring, exporters.
    pub fn obs(&self) -> &Arc<ServeObs> {
        &self.obs
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            evictions: self.obs.evictions.get(),
            restores: self.obs.restores.get(),
        }
    }

    /// Pool-level health: degraded flag, failure counter, orphan count.
    /// (`quarantined`/`deferred_budget` are scheduler-level; the
    /// scheduler's `health()` fills them in.)
    pub fn health(&self) -> HealthReport {
        HealthReport {
            degraded: self.degraded,
            quarantined: 0,
            deferred_budget: false,
            snapshot_failures: self.obs.snapshot_failures.get(),
            orphaned_snapshots: self.orphans.len(),
        }
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded
    }

    // Store-op wrappers: every outcome feeds the health counters, and a
    // write success is the (only) signal that clears degraded mode. The
    // obs layer sees the same outcomes — bytes/failure counters, the
    // snapshot-IO span, degraded-edge and store-fault events — but is
    // never consulted for the decision (write-only rule).
    fn store_write(
        &mut self,
        path: &Path,
        bytes: &[u8],
    ) -> Result<(), StoreError> {
        let _io = self.obs.span(&self.obs.snapshot_io_ms);
        match self.store.write(path, bytes) {
            Ok(()) => {
                self.obs.snapshot_bytes_written.add(bytes.len() as u64);
                if self.degraded {
                    self.obs.event(EventKind::DegradedExit);
                }
                self.degraded = false;
                Ok(())
            }
            Err(e) => {
                self.obs.snapshot_failures.inc();
                self.obs.event(EventKind::StoreFault {
                    op: "write",
                    path: path.display().to_string(),
                });
                if !self.degraded {
                    self.obs.degraded_transitions.inc();
                    self.obs.event(EventKind::DegradedEnter);
                }
                self.degraded = true;
                Err(e)
            }
        }
    }

    fn store_read(&mut self, path: &Path) -> Result<Vec<u8>, StoreError> {
        let _io = self.obs.span(&self.obs.snapshot_io_ms);
        match self.store.read(path) {
            Ok(bytes) => {
                self.obs.snapshot_bytes_read.add(bytes.len() as u64);
                Ok(bytes)
            }
            Err(e) => {
                self.obs.snapshot_failures.inc();
                self.obs.event(EventKind::StoreFault {
                    op: "read",
                    path: path.display().to_string(),
                });
                Err(e)
            }
        }
    }

    fn store_remove(&mut self, path: &Path) -> Result<(), StoreError> {
        self.store.remove(path).map_err(|e| {
            if !e.is_not_found() {
                self.obs.snapshot_failures.inc();
                self.obs.event(EventKind::StoreFault {
                    op: "remove",
                    path: path.display().to_string(),
                });
            }
            e
        })
    }

    /// Retry every recorded failed unlink; called from eviction, close
    /// and heal paths so orphans drain as soon as the FS recovers.
    fn retry_orphan_unlinks(&mut self) {
        if self.orphans.is_empty() {
            return;
        }
        let paths: Vec<PathBuf> = self.orphans.iter().cloned().collect();
        for path in paths {
            self.obs.orphan_retries.inc();
            let recovered = match self.store_remove(&path) {
                Ok(()) => {
                    self.orphans.remove(&path);
                    true
                }
                Err(e) if e.is_not_found() => {
                    self.orphans.remove(&path);
                    true
                }
                Err(_) => false,
            };
            self.obs.event(EventKind::OrphanRetry {
                path: path.display().to_string(),
                recovered,
            });
        }
    }

    /// Operator/scheduler heal probe: retry orphaned unlinks and
    /// re-enforce the budget. A successful eviction write clears
    /// degraded mode (the store-op hooks observe it); if the budget
    /// needs no eviction, a tiny probe write stands in — degraded mode
    /// must not outlive the outage just because nothing happened to be
    /// evicted.
    pub fn try_heal(&mut self) -> Result<()> {
        self.retry_orphan_unlinks();
        self.ensure_budget(&[])?;
        if self.degraded {
            let probe = self.cfg.snapshot_dir.join(format!(
                "pool{}-{}-health-probe.tmp",
                std::process::id(),
                self.pool_tag
            ));
            self.store_write(&probe, b"darkformer snapshot-store probe")
                .with_context(|| {
                    format!("health probe write {}", probe.display())
                })?;
            if let Err(e) = self.store_remove(&probe) {
                if !e.is_not_found() {
                    self.orphans.insert(probe);
                }
            }
        }
        self.refresh_gauges();
        Ok(())
    }

    /// Republish the pool-level gauges (resident/evicted counts, bytes,
    /// orphan count, degraded mirror). Called from serial lifecycle
    /// paths; a no-op below [`crate::obs::ObsLevel::Basic`].
    pub(crate) fn refresh_gauges(&self) {
        if !self.obs.gauges_enabled() {
            return;
        }
        self.obs.resident_sessions.set(self.resident.len() as f64);
        self.obs.evicted_sessions.set(self.evicted.len() as f64);
        self.obs.resident_bytes.set(self.resident_bytes() as f64);
        self.obs.orphaned_snapshots.set(self.orphans.len() as f64);
        self.obs.degraded.set(if self.degraded { 1.0 } else { 0.0 });
    }

    /// Allocate an id and create a fresh session for `seed`, evicting
    /// LRU sessions if the budget demands it.
    ///
    /// Degraded mode changes the budget behavior, not the API: while the
    /// snapshot store is unhealthy, admission control rejects new
    /// sessions once resident bytes already reach the (soft) budget, and
    /// an admitted session skips the eviction pass rather than risking
    /// another failed write — residents keep serving, memory overshoots.
    pub fn create_session(&mut self, seed: u64) -> Result<u64> {
        if let Some(rc) = &self.cfg.resample {
            rc.validate()?;
        }
        if self.degraded
            && self.cfg.memory_budget > 0
            && self.resident_bytes() >= self.cfg.memory_budget
        {
            bail!(
                "admission control: snapshot store is degraded and resident \
                 bytes ({}) already reach the budget ({}); heal the store or \
                 close sessions before admitting new ones",
                self.resident_bytes(),
                self.cfg.memory_budget
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut session = Session::new(id, seed, &self.cfg);
        session.attach_obs(self.obs.clone());
        self.resident.insert(id, session);
        self.touch(id);
        if !self.degraded {
            if let Err(e) = self.ensure_budget(&[id]) {
                // Roll the (still-fresh, stateless) session back so a failed
                // eviction write cannot leak an unreachable resident session.
                self.resident.remove(&id);
                self.last_used.remove(&id);
                return Err(e);
            }
        }
        self.refresh_gauges();
        Ok(id)
    }

    /// Whether `id` names a live session (resident or evicted).
    pub fn contains(&self, id: u64) -> bool {
        self.resident.contains_key(&id) || self.evicted.contains_key(&id)
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn evicted_count(&self) -> usize {
        self.evicted.len()
    }

    /// Total resident session bytes (the quantity the budget bounds).
    pub fn resident_bytes(&self) -> usize {
        self.resident.values().map(Session::state_bytes).sum()
    }

    /// Mutable access to a session, faulting it in from its snapshot if
    /// it was evicted and re-balancing the budget around it.
    pub fn session_mut(&mut self, id: u64) -> Result<&mut Session> {
        self.ensure_resident(id, &[id])?;
        Ok(self.resident.get_mut(&id).expect("just made resident"))
    }

    /// Make `id` resident (restoring from its snapshot if needed) and
    /// stamp it used; sessions in `pinned` are exempt from the eviction
    /// this may trigger.
    pub(crate) fn ensure_resident(
        &mut self,
        id: u64,
        pinned: &[u64],
    ) -> Result<()> {
        self.fault_in(id)?;
        self.ensure_budget(pinned)
    }

    /// Restore `id` from its snapshot if it is evicted (a no-op beyond a
    /// touch when it is already resident). Returns the classified
    /// [`StoreError`] so the scheduler's retry policy can distinguish
    /// transient from persistent failures; does *not* enforce the
    /// budget — callers re-balance once per batch.
    pub(crate) fn fault_in(&mut self, id: u64) -> Result<(), StoreError> {
        if self.resident.contains_key(&id) {
            self.touch(id);
            return Ok(());
        }
        // Leave the evicted entry in place until the load succeeds: a
        // transient IO failure must not orphan the session.
        let Some(path) = self.evicted.get(&id).cloned() else {
            return Err(StoreError::persistent(format!(
                "no session with id {id}"
            )));
        };
        let bytes = self
            .store_read(&path)
            .map_err(|e| e.context(format!("faulting in session {id}")))?;
        let mut session = match restored_session(&self.cfg, id, &path, &bytes)
        {
            Ok(s) => s,
            Err(e) => {
                // Parse/validation failures are persistent: the bytes on
                // disk will not get better by retrying.
                self.obs.snapshot_failures.inc();
                self.obs.event(EventKind::StoreFault {
                    op: "decode",
                    path: path.display().to_string(),
                });
                return Err(StoreError::persistent(format!(
                    "faulting in session {id}: {e:#}"
                )));
            }
        };
        session.attach_obs(self.obs.clone());
        // The snapshot is consumed: the resident session is now the only
        // truth, so a stale file can never shadow newer state. A failed
        // unlink is recorded and retried later, never silently dropped.
        self.evicted.remove(&id);
        if let Err(e) = self.store_remove(&path) {
            if !e.is_not_found() {
                self.orphans.insert(path.clone());
            }
        }
        self.resident.insert(id, session);
        self.obs.restores.inc();
        self.obs.event(EventKind::Restore {
            session: id,
            bytes: bytes.len() as u64,
        });
        self.touch(id);
        self.refresh_gauges();
        Ok(())
    }

    /// Evict one session now (snapshot + drop from memory). Exposed for
    /// orderly shutdown; the budget path calls it internally.
    pub fn evict(&mut self, id: u64) -> Result<()> {
        self.retry_orphan_unlinks();
        // Snapshot first, drop from memory only once the bytes are on
        // disk — a failed write must not lose the stream.
        let Some(session) = self.resident.get(&id) else {
            bail!("session {id} is not resident");
        };
        let path = self.snapshot_path(id);
        let bytes = snapshot::session_to_bytes(session)?;
        self.store_write(&path, &bytes)
            .with_context(|| format!("evicting session {id}"))?;
        self.resident.remove(&id);
        self.evicted.insert(id, path);
        self.last_used.remove(&id);
        self.obs.evictions.inc();
        self.obs.event(EventKind::Eviction {
            session: id,
            bytes: bytes.len() as u64,
        });
        self.refresh_gauges();
        Ok(())
    }

    /// End a session's life: drop its resident state, or — if it was
    /// evicted — remove the `evicted` entry *and* unlink its snapshot
    /// file, so closed sessions never accrete snapshot files on disk.
    /// The close always wins: an already-gone snapshot file is
    /// tolerated, and a failed unlink is recorded as an orphan (retried
    /// later, visible in [`SessionPool::health`]) rather than failing
    /// the close. An unknown id is an error.
    pub fn close_session(&mut self, id: u64) -> Result<()> {
        self.retry_orphan_unlinks();
        let was_resident = self.resident.remove(&id).is_some();
        self.last_used.remove(&id);
        if let Some(path) = self.evicted.remove(&id) {
            match self.store_remove(&path) {
                Ok(()) => {}
                Err(e) if e.is_not_found() => {}
                Err(_) => {
                    self.orphans.insert(path);
                }
            }
            self.refresh_gauges();
            return Ok(());
        }
        ensure!(was_resident, "no session with id {id}");
        self.refresh_gauges();
        Ok(())
    }

    /// Evict LRU non-pinned sessions until the budget holds (or nothing
    /// evictable remains).
    pub(crate) fn ensure_budget(&mut self, pinned: &[u64]) -> Result<()> {
        if self.cfg.memory_budget == 0 {
            return Ok(());
        }
        while self.resident_bytes() > self.cfg.memory_budget {
            let victim = self
                .last_used
                .iter()
                .filter(|&(id, _)| !pinned.contains(id))
                .min_by_key(|&(id, stamp)| (*stamp, *id))
                .map(|(id, _)| *id);
            let Some(victim) = victim else {
                break; // only pinned sessions left — allow overshoot
            };
            self.evict(victim)?;
        }
        Ok(())
    }

    /// Disjoint mutable borrows of several resident sessions, in `ids`
    /// order. Callers guarantee the ids are distinct and resident.
    pub(crate) fn sessions_mut(&mut self, ids: &[u64]) -> Vec<&mut Session> {
        let mut found: BTreeMap<u64, &mut Session> = self
            .resident
            .iter_mut()
            .filter(|&(id, _)| ids.contains(id))
            .map(|(id, s)| (*id, s))
            .collect();
        ids.iter()
            .map(|id| found.remove(id).expect("scheduled session resident"))
            .collect()
    }

    /// Where session `id`'s eviction snapshot lives (whether or not one
    /// currently exists). Public for tests that inject IO faults at the
    /// exact path the pool will write to.
    pub fn snapshot_path(&self, id: u64) -> PathBuf {
        self.cfg.snapshot_dir.join(format!(
            "pool{}-{}-session-{id}.dkft",
            std::process::id(),
            self.pool_tag
        ))
    }

    fn touch(&mut self, id: u64) {
        self.clock += 1;
        self.last_used.insert(id, self.clock);
    }
}

/// Parse snapshot bytes and validate them against the pool config — the
/// fallible middle of `fault_in`, split out so the caller can classify
/// any failure here as persistent.
fn restored_session(
    cfg: &ServeConfig,
    id: u64,
    path: &Path,
    bytes: &[u8],
) -> Result<Session> {
    let session = snapshot::session_from_bytes(bytes)
        .with_context(|| format!("restoring from {}", path.display()))?;
    ensure!(
        session.id() == id,
        "snapshot {} holds session {}, expected {id}",
        path.display(),
        session.id()
    );
    ensure!(
        session.n_heads() == cfg.n_heads
            && session.dv() == cfg.dv
            && session.precision() == cfg.precision,
        "snapshot geometry (heads={}, dv={}, {:?}) does not match the \
         pool config (heads={}, dv={}, {:?})",
        session.n_heads(),
        session.dv(),
        session.precision(),
        cfg.n_heads,
        cfg.dv,
        cfg.precision
    );
    ensure!(
        session.resample_config() == cfg.resample.as_ref(),
        "snapshot resample policy {:?} does not match the pool \
         config {:?}",
        session.resample_config(),
        cfg.resample
    );
    Ok(session)
}
