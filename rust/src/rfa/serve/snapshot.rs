//! Session snapshots over the DKFT tensor store.
//!
//! A snapshot is a self-contained [`Checkpoint`]: metadata (id, seed,
//! position, precision, geometry) as `u32` tensors, bank matrices and
//! running state as `f64` tensors — see the naming scheme in the
//! [`super`] module docs. Everything numeric is stored at full f64
//! width: the engine's `Scalar::Accum` contract keeps the running state
//! in f64 accumulators for *every* storage precision, so every
//! round-trip is exact-bits and a restored session continues its stream
//! bitwise identically — the resumability property
//! `rust/tests/rfa_serve.rs` pins.
//!
//! Precision dispatch follows the session-boundary rule: serialization
//! reads the session's [`SessionHeads`] once, restoration matches the
//! stored precision tag once, and everything per-head runs through the
//! generic [`insert_heads`]/[`read_heads`] bodies.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{Checkpoint, Tensor};
use crate::linalg::{Matrix, Scalar};
use crate::rfa::engine::CausalState;
use crate::rfa::features::FeatureBank;

use super::session::{HeadSlot, Precision, Session, SessionHeads};

/// Schema version stored under `session/version`.
pub const SNAPSHOT_VERSION: u32 = 1;

fn u64_tensor(v: u64) -> Tensor {
    Tensor::from_u32(vec![2], &[v as u32, (v >> 32) as u32])
}

fn read_u64(ck: &Checkpoint, name: &str) -> Result<u64> {
    let parts = ck.require_u32(name, &[2])?;
    Ok(parts[0] as u64 | (parts[1] as u64) << 32)
}

fn read_scalar_u32(ck: &Checkpoint, name: &str) -> Result<u32> {
    Ok(ck.require_u32(name, &[1])?[0])
}

/// Write one precision's head slots into the checkpoint — the generic
/// half of serialization. The `Accum = f64` bound *is* the format
/// guarantee: state tensors are f64 for every storage precision.
fn insert_heads<T: Scalar<Accum = f64>>(
    ck: &mut Checkpoint,
    slots: &[HeadSlot<T>],
    dv: usize,
) {
    for (h, slot) in slots.iter().enumerate() {
        let bank = slot.bank();
        let (n, d) = (bank.n_features(), bank.dim());
        ck.insert(
            format!("head{h}/bank/omegas"),
            Tensor::from_f64(vec![n, d], bank.omegas().data()),
        );
        ck.insert(
            format!("head{h}/bank/weights"),
            Tensor::from_f64(vec![n], bank.weights()),
        );
        if let Some(sigma) = bank.norm_sigma() {
            ck.insert(
                format!("head{h}/bank/sigma"),
                Tensor::from_f64(vec![d, d], sigma.data()),
            );
        }
        let state = slot.state();
        ck.insert(
            format!("head{h}/state"),
            Tensor::from_f64(vec![n, dv], state.state().data()),
        );
        ck.insert(format!("head{h}/z"), Tensor::from_f64(vec![n], state.z()));
    }
}

/// Read `n_heads` head slots back at storage precision `T` — the generic
/// half of restoration, validating every tensor's dtype and shape.
fn read_heads<T: Scalar<Accum = f64>>(
    ck: &Checkpoint,
    n_heads: usize,
    dv: usize,
) -> Result<Vec<HeadSlot<T>>> {
    let mut heads = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let omegas_t = ck.require(&format!("head{h}/bank/omegas"))?;
        if omegas_t.shape.len() != 2 {
            bail!(
                "head{h}/bank/omegas must be rank 2, got shape {:?}",
                omegas_t.shape
            );
        }
        let (n, d) = (omegas_t.shape[0], omegas_t.shape[1]);
        let omegas = Matrix::from_vec(
            n,
            d,
            ck.require_f64(&format!("head{h}/bank/omegas"), &[n, d])?,
        );
        let weights = ck.require_f64(&format!("head{h}/bank/weights"), &[n])?;
        let sigma_name = format!("head{h}/bank/sigma");
        let norm_sigma = if ck.get(&sigma_name).is_some() {
            Some(Matrix::from_vec(
                d,
                d,
                ck.require_f64(&sigma_name, &[d, d])?,
            ))
        } else {
            None
        };
        let bank = FeatureBank::from_parts(omegas, weights, norm_sigma);

        let s = ck.require_f64(&format!("head{h}/state"), &[n, dv])?;
        let z = ck.require_f64(&format!("head{h}/z"), &[n])?;
        let state = CausalState::from_parts(Matrix::from_vec(n, dv, s), z);
        heads.push(HeadSlot { bank, state });
    }
    Ok(heads)
}

/// Serialize a session into a checkpoint.
pub fn session_checkpoint(session: &Session) -> Checkpoint {
    let mut ck = Checkpoint::new();
    ck.insert(
        "session/version",
        Tensor::from_u32(vec![1], &[SNAPSHOT_VERSION]),
    );
    ck.insert("session/id", u64_tensor(session.id()));
    ck.insert("session/seed", u64_tensor(session.seed()));
    ck.insert("session/position", u64_tensor(session.position()));
    let precision = match session.precision() {
        Precision::F64 => 0u32,
        Precision::F32 => 1u32,
    };
    ck.insert("session/precision", Tensor::from_u32(vec![1], &[precision]));
    ck.insert(
        "session/n_heads",
        Tensor::from_u32(vec![1], &[session.n_heads() as u32]),
    );
    ck.insert(
        "session/dv",
        Tensor::from_u32(vec![1], &[session.dv() as u32]),
    );
    match session.heads() {
        SessionHeads::F64(slots) => insert_heads(&mut ck, slots, session.dv()),
        SessionHeads::F32(slots) => insert_heads(&mut ck, slots, session.dv()),
    }
    ck
}

/// Rebuild a session from a checkpoint, validating every tensor's dtype
/// and shape (descriptive errors, never panics, on malformed input).
pub fn session_from_checkpoint(ck: &Checkpoint) -> Result<Session> {
    let version = read_scalar_u32(ck, "session/version")?;
    if version != SNAPSHOT_VERSION {
        bail!("unsupported session snapshot version {version}");
    }
    let id = read_u64(ck, "session/id")?;
    let seed = read_u64(ck, "session/seed")?;
    let position = read_u64(ck, "session/position")?;
    let precision = match read_scalar_u32(ck, "session/precision")? {
        0 => Precision::F64,
        1 => Precision::F32,
        p => bail!("unknown precision tag {p} in session snapshot"),
    };
    let n_heads = read_scalar_u32(ck, "session/n_heads")? as usize;
    let dv = read_scalar_u32(ck, "session/dv")? as usize;
    // Sanity-bound the header before allocating anything sized by it: a
    // malformed (but CRC-valid) file must surface as an error, not an
    // abort inside a huge Vec::with_capacity.
    if n_heads > 4096 {
        bail!("implausible head count {n_heads} in session snapshot");
    }

    // The stored precision tag resolves to a compile-time Scalar exactly
    // once, here; everything per-head below is generic.
    let heads = match precision {
        Precision::F64 => {
            SessionHeads::F64(read_heads::<f64>(ck, n_heads, dv)?)
        }
        Precision::F32 => {
            SessionHeads::F32(read_heads::<f32>(ck, n_heads, dv)?)
        }
    };
    Ok(Session::from_parts(id, seed, position, dv, heads))
}

/// Snapshot a session to `path` (DKFT: magic, version, crc — see
/// [`crate::checkpoint`]).
pub fn save_session(session: &Session, path: &Path) -> Result<()> {
    session_checkpoint(session)
        .save(path)
        .with_context(|| format!("saving session {} snapshot", session.id()))
}

/// Load a session snapshot from `path`.
pub fn load_session(path: &Path) -> Result<Session> {
    let ck = Checkpoint::load(path)
        .with_context(|| format!("loading session snapshot {}", path.display()))?;
    session_from_checkpoint(&ck)
        .with_context(|| format!("restoring session from {}", path.display()))
}
