//! Session snapshots over the DKFT tensor store.
//!
//! A snapshot is a self-contained [`Checkpoint`]: metadata (id, seed,
//! position, precision, geometry, resample policy) as `u32`/`f64`
//! tensors, bank matrices and running state as `f64` tensors — see the
//! naming scheme in the [`super`] module docs. Everything numeric is
//! stored at full f64 width: the engine's `Scalar::Accum` contract keeps
//! the running state in f64 accumulators for *every* storage precision,
//! so every round-trip is exact-bits and a restored session continues
//! its stream bitwise identically — the resumability property
//! `rust/tests/rfa_serve.rs` pins. For online-resampling sessions this
//! extends to the whole epoch machinery: the epoch counter, the
//! covariance accumulator (an exact f64 sum) and every retained frozen
//! `(bank, S, z)` triple round-trip bit for bit, so evict→restore→
//! continue is bitwise across resample boundaries too.
//!
//! Version 2 of the schema adds the resample-policy and per-head online
//! tensors; version-1 files (written before resampling existed) still
//! load, as static-bank sessions. Version 3 adds the maintained
//! Cholesky factor and its counters (`head{h}/online/chol_*`,
//! `head{h}/online/compactions`) plus the optional compaction knob
//! (`session/resample/compaction/*`); both are read by presence, so
//! version-2 files load with a default [`FactorState`] (the next
//! boundary refreshes the factor from the accumulator — one O(d³)
//! catch-up that re-pins the identity floor to the then-current count)
//! and no compaction.
//!
//! Precision dispatch follows the session-boundary rule: serialization
//! reads the session's [`SessionHeads`] once, restoration matches the
//! stored precision tag once, and everything per-head runs through the
//! generic [`insert_heads`]/[`read_heads`] bodies.

use std::collections::VecDeque;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{Checkpoint, Tensor};
use crate::linalg::{Matrix, Scalar};
use crate::rfa::engine::CausalState;
use crate::rfa::features::FeatureBank;
use crate::rfa::gaussian::SecondMomentAccumulator;

use super::session::{
    CompactionConfig, FactorState, FrozenEpoch, HeadSlot, OnlineState,
    Precision, ResampleConfig, Session, SessionHeads,
};

/// Schema version stored under `session/version`. Versions 1 (static
/// banks only) and 2 (no maintained factor / compaction) are still
/// accepted on read.
pub const SNAPSHOT_VERSION: u32 = 3;

fn u64_tensor(v: u64) -> Tensor {
    Tensor::from_u32(vec![2], &[v as u32, (v >> 32) as u32])
}

fn read_u64(ck: &Checkpoint, name: &str) -> Result<u64> {
    let parts = ck.require_u32(name, &[2])?;
    Ok(parts[0] as u64 | (parts[1] as u64) << 32)
}

fn read_scalar_u32(ck: &Checkpoint, name: &str) -> Result<u32> {
    Ok(ck.require_u32(name, &[1])?[0])
}

/// Write one bank's tensors under `{prefix}/{omegas,weights,sigma}`.
fn insert_bank(ck: &mut Checkpoint, prefix: &str, bank: &FeatureBank) {
    let (n, d) = (bank.n_features(), bank.dim());
    ck.insert(
        format!("{prefix}/omegas"),
        Tensor::from_f64(vec![n, d], bank.omegas().data()),
    );
    ck.insert(
        format!("{prefix}/weights"),
        Tensor::from_f64(vec![n], bank.weights()),
    );
    if let Some(sigma) = bank.norm_sigma() {
        ck.insert(
            format!("{prefix}/sigma"),
            Tensor::from_f64(vec![d, d], sigma.data()),
        );
    }
}

/// Read one bank back from `{prefix}/{omegas,weights,sigma}`; returns
/// the bank plus its `(n, d)` geometry.
fn read_bank(
    ck: &Checkpoint,
    prefix: &str,
) -> Result<(FeatureBank, usize, usize)> {
    let omegas_t = ck.require(&format!("{prefix}/omegas"))?;
    if omegas_t.shape.len() != 2 {
        bail!(
            "{prefix}/omegas must be rank 2, got shape {:?}",
            omegas_t.shape
        );
    }
    let (n, d) = (omegas_t.shape[0], omegas_t.shape[1]);
    let omegas = Matrix::from_vec(
        n,
        d,
        ck.require_f64(&format!("{prefix}/omegas"), &[n, d])?,
    );
    let weights = ck.require_f64(&format!("{prefix}/weights"), &[n])?;
    let sigma_name = format!("{prefix}/sigma");
    let norm_sigma = if ck.get(&sigma_name).is_some() {
        Some(Matrix::from_vec(d, d, ck.require_f64(&sigma_name, &[d, d])?))
    } else {
        None
    };
    Ok((FeatureBank::from_parts(omegas, weights, norm_sigma), n, d))
}

/// Write one causal state's tensors under `{prefix}/state`, `{prefix}/z`.
fn insert_state<T: Scalar<Accum = f64>>(
    ck: &mut Checkpoint,
    prefix: &str,
    state: &CausalState<T>,
    dv: usize,
) {
    let n = state.n_features();
    ck.insert(
        format!("{prefix}/state"),
        Tensor::from_f64(vec![n, dv], state.state().data()),
    );
    ck.insert(format!("{prefix}/z"), Tensor::from_f64(vec![n], state.z()));
}

/// Read one causal state back from `{prefix}/state`, `{prefix}/z`.
fn read_state<T: Scalar<Accum = f64>>(
    ck: &Checkpoint,
    prefix: &str,
    n: usize,
    dv: usize,
) -> Result<CausalState<T>> {
    let s = ck.require_f64(&format!("{prefix}/state"), &[n, dv])?;
    let z = ck.require_f64(&format!("{prefix}/z"), &[n])?;
    Ok(CausalState::from_parts(Matrix::from_vec(n, dv, s), z))
}

/// Write one precision's head slots into the checkpoint — the generic
/// half of serialization. The `Accum = f64` bound *is* the format
/// guarantee: state tensors are f64 for every storage precision.
fn insert_heads<T: Scalar<Accum = f64>>(
    ck: &mut Checkpoint,
    slots: &[HeadSlot<T>],
    dv: usize,
) {
    for (h, slot) in slots.iter().enumerate() {
        insert_bank(ck, &format!("head{h}/bank"), slot.bank());
        insert_state(ck, &format!("head{h}"), slot.state(), dv);
        if let Some(online) = slot.online() {
            ck.insert(
                format!("head{h}/online/epoch"),
                u64_tensor(online.epoch()),
            );
            ck.insert(
                format!("head{h}/online/count"),
                u64_tensor(online.count()),
            );
            let cov = online.moment.sum();
            let d = cov.rows();
            ck.insert(
                format!("head{h}/online/cov_sum"),
                Tensor::from_f64(vec![d, d], cov.data()),
            );
            ck.insert(
                format!("head{h}/online/n_frozen"),
                Tensor::from_u32(vec![1], &[online.frozen.len() as u32]),
            );
            // v3: maintained-factor state. The factor matrix itself is
            // optional (None until the first boundary, or after a failed
            // refresh); the floor/counters always travel so telemetry
            // baselines and the doubling rule resume exactly.
            ck.insert(
                format!("head{h}/online/chol_floor"),
                u64_tensor(online.factor.floor),
            );
            ck.insert(
                format!("head{h}/online/chol_rank1"),
                u64_tensor(online.factor.rank1),
            );
            ck.insert(
                format!("head{h}/online/chol_refreshes"),
                u64_tensor(online.factor.refreshes),
            );
            ck.insert(
                format!("head{h}/online/compactions"),
                u64_tensor(online.factor.compactions),
            );
            if let Some(l) = &online.factor.chol {
                ck.insert(
                    format!("head{h}/online/chol_factor"),
                    Tensor::from_f64(vec![d, d], l.data()),
                );
            }
            for (j, fe) in online.frozen.iter().enumerate() {
                insert_bank(ck, &format!("head{h}/frozen{j}/bank"), fe.bank());
                insert_state(ck, &format!("head{h}/frozen{j}"), fe.state(), dv);
            }
        }
    }
}

/// Read `n_heads` head slots back at storage precision `T` — the generic
/// half of restoration, validating every tensor's dtype and shape.
/// `resample` carries the session's policy and seed when the snapshot
/// holds an online session; `None` restores static-bank heads.
fn read_heads<T: Scalar<Accum = f64>>(
    ck: &Checkpoint,
    n_heads: usize,
    dv: usize,
    resample: Option<(&ResampleConfig, u64)>,
) -> Result<Vec<HeadSlot<T>>> {
    let mut heads = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let (bank, n, d) = read_bank(ck, &format!("head{h}/bank"))?;
        let state = read_state::<T>(ck, &format!("head{h}"), n, dv)?;
        let online = match resample {
            None => None,
            Some((rc, seed)) => {
                let epoch = read_u64(ck, &format!("head{h}/online/epoch"))?;
                let count = read_u64(ck, &format!("head{h}/online/count"))?;
                let cov = Matrix::from_vec(
                    d,
                    d,
                    ck.require_f64(
                        &format!("head{h}/online/cov_sum"),
                        &[d, d],
                    )?,
                );
                let n_frozen = read_scalar_u32(
                    ck,
                    &format!("head{h}/online/n_frozen"),
                )? as usize;
                ensure!(
                    n_frozen <= rc.max_epochs,
                    "head{h} retains {n_frozen} frozen epochs, policy \
                     allows {}",
                    rc.max_epochs
                );
                let mut frozen = VecDeque::with_capacity(n_frozen);
                for j in 0..n_frozen {
                    let (fbank, fnn, _) =
                        read_bank(ck, &format!("head{h}/frozen{j}/bank"))?;
                    let fstate = read_state::<T>(
                        ck,
                        &format!("head{h}/frozen{j}"),
                        fnn,
                        dv,
                    )?;
                    frozen.push_back(FrozenEpoch { bank: fbank, state: fstate });
                }
                // v3 factor state, detected by presence so v2 files load
                // with the default (next boundary refreshes from the
                // accumulator).
                let floor_name = format!("head{h}/online/chol_floor");
                let factor = if ck.get(&floor_name).is_some() {
                    let chol_name = format!("head{h}/online/chol_factor");
                    let chol = if ck.get(&chol_name).is_some() {
                        Some(Matrix::from_vec(
                            d,
                            d,
                            ck.require_f64(&chol_name, &[d, d])?,
                        ))
                    } else {
                        None
                    };
                    FactorState {
                        chol,
                        floor: read_u64(ck, &floor_name)?,
                        rank1: read_u64(
                            ck,
                            &format!("head{h}/online/chol_rank1"),
                        )?,
                        refreshes: read_u64(
                            ck,
                            &format!("head{h}/online/chol_refreshes"),
                        )?,
                        compactions: read_u64(
                            ck,
                            &format!("head{h}/online/compactions"),
                        )?,
                    }
                } else {
                    FactorState::default()
                };
                Some(OnlineState::from_parts(
                    rc.clone(),
                    seed,
                    h,
                    epoch,
                    SecondMomentAccumulator::from_parts(cov, count),
                    factor,
                    frozen,
                ))
            }
        };
        heads.push(HeadSlot { bank, state, online });
    }
    Ok(heads)
}

/// Serialize a session into a checkpoint.
pub fn session_checkpoint(session: &Session) -> Checkpoint {
    let mut ck = Checkpoint::new();
    ck.insert(
        "session/version",
        Tensor::from_u32(vec![1], &[SNAPSHOT_VERSION]),
    );
    ck.insert("session/id", u64_tensor(session.id()));
    ck.insert("session/seed", u64_tensor(session.seed()));
    ck.insert("session/position", u64_tensor(session.position()));
    let precision = match session.precision() {
        Precision::F64 => 0u32,
        Precision::F32 => 1u32,
    };
    ck.insert("session/precision", Tensor::from_u32(vec![1], &[precision]));
    ck.insert(
        "session/n_heads",
        Tensor::from_u32(vec![1], &[session.n_heads() as u32]),
    );
    ck.insert(
        "session/dv",
        Tensor::from_u32(vec![1], &[session.dv() as u32]),
    );
    match session.resample_config() {
        Some(rc) => {
            ck.insert("session/resample", Tensor::from_u32(vec![1], &[1]));
            ck.insert(
                "session/resample/epoch_positions",
                u64_tensor(rc.epoch_positions),
            );
            ck.insert(
                "session/resample/max_epochs",
                Tensor::from_u32(vec![1], &[rc.max_epochs as u32]),
            );
            ck.insert(
                "session/resample/shrinkage",
                Tensor::from_f64(vec![1], &[rc.shrinkage]),
            );
            if let Some(cc) = &rc.compaction {
                ck.insert(
                    "session/resample/compaction/window",
                    Tensor::from_u32(vec![1], &[cc.window as u32]),
                );
                ck.insert(
                    "session/resample/compaction/probes",
                    Tensor::from_u32(vec![1], &[cc.probes as u32]),
                );
                ck.insert(
                    "session/resample/compaction/ridge",
                    Tensor::from_f64(vec![1], &[cc.ridge]),
                );
            }
        }
        None => {
            ck.insert("session/resample", Tensor::from_u32(vec![1], &[0]));
        }
    }
    match session.heads() {
        SessionHeads::F64(slots) => insert_heads(&mut ck, slots, session.dv()),
        SessionHeads::F32(slots) => insert_heads(&mut ck, slots, session.dv()),
    }
    ck
}

/// Rebuild a session from a checkpoint, validating every tensor's dtype
/// and shape (descriptive errors, never panics, on malformed input).
pub fn session_from_checkpoint(ck: &Checkpoint) -> Result<Session> {
    let version = read_scalar_u32(ck, "session/version")?;
    if !(1..=SNAPSHOT_VERSION).contains(&version) {
        bail!("unsupported session snapshot version {version}");
    }
    let id = read_u64(ck, "session/id")?;
    let seed = read_u64(ck, "session/seed")?;
    let position = read_u64(ck, "session/position")?;
    let precision = match read_scalar_u32(ck, "session/precision")? {
        0 => Precision::F64,
        1 => Precision::F32,
        p => bail!("unknown precision tag {p} in session snapshot"),
    };
    let n_heads = read_scalar_u32(ck, "session/n_heads")? as usize;
    let dv = read_scalar_u32(ck, "session/dv")? as usize;
    // Sanity-bound the header before allocating anything sized by it: a
    // malformed (but CRC-valid) file must surface as an error, not an
    // abort inside a huge Vec::with_capacity.
    if n_heads > 4096 {
        bail!("implausible head count {n_heads} in session snapshot");
    }
    // Version-1 files predate resampling; they are static-bank sessions.
    let resample = if version >= 2
        && read_scalar_u32(ck, "session/resample")? == 1
    {
        let epoch_positions =
            read_u64(ck, "session/resample/epoch_positions")?;
        let max_epochs =
            read_scalar_u32(ck, "session/resample/max_epochs")? as usize;
        if max_epochs > 4096 {
            bail!(
                "implausible retained-epoch cap {max_epochs} in session \
                 snapshot"
            );
        }
        let shrinkage =
            ck.require_f64("session/resample/shrinkage", &[1])?[0];
        // v3 compaction knob, by presence (v2 files simply lack it).
        let compaction = if ck.get("session/resample/compaction/window")
            .is_some()
        {
            Some(CompactionConfig {
                window: read_scalar_u32(
                    ck,
                    "session/resample/compaction/window",
                )? as usize,
                probes: read_scalar_u32(
                    ck,
                    "session/resample/compaction/probes",
                )? as usize,
                ridge: ck
                    .require_f64("session/resample/compaction/ridge", &[1])?
                    [0],
            })
        } else {
            None
        };
        let rc = ResampleConfig {
            epoch_positions,
            max_epochs,
            shrinkage,
            compaction,
        };
        rc.validate()
            .context("session snapshot carries an invalid resample policy")?;
        Some(rc)
    } else {
        None
    };

    // The stored precision tag resolves to a compile-time Scalar exactly
    // once, here; everything per-head below is generic.
    let online = resample.as_ref().map(|rc| (rc, seed));
    let heads = match precision {
        Precision::F64 => {
            SessionHeads::F64(read_heads::<f64>(ck, n_heads, dv, online)?)
        }
        Precision::F32 => {
            SessionHeads::F32(read_heads::<f32>(ck, n_heads, dv, online)?)
        }
    };
    Ok(Session::from_parts(id, seed, position, dv, resample, heads))
}

/// Serialize a session to DKFT wire bytes — the form the serve layer
/// hands to its [`super::store::SnapshotStore`] backend.
pub fn session_to_bytes(session: &Session) -> Result<Vec<u8>> {
    session_checkpoint(session).to_bytes().with_context(|| {
        format!("serializing session {} snapshot", session.id())
    })
}

/// Rebuild a session from DKFT wire bytes (the dual of
/// [`session_to_bytes`]), validating structure before anything numeric.
pub fn session_from_bytes(bytes: &[u8]) -> Result<Session> {
    let ck = Checkpoint::from_bytes(bytes)
        .context("parsing session snapshot")?;
    session_from_checkpoint(&ck)
}

/// Snapshot a session to `path` (DKFT: magic, version, crc — see
/// [`crate::checkpoint`]). Crash-safe via the checkpoint layer's
/// atomic write.
pub fn save_session(session: &Session, path: &Path) -> Result<()> {
    session_checkpoint(session)
        .save(path)
        .with_context(|| format!("saving session {} snapshot", session.id()))
}

/// Load a session snapshot from `path`.
pub fn load_session(path: &Path) -> Result<Session> {
    let ck = Checkpoint::load(path)
        .with_context(|| format!("loading session snapshot {}", path.display()))?;
    session_from_checkpoint(&ck)
        .with_context(|| format!("restoring session from {}", path.display()))
}
