//! Pluggable snapshot IO: durable filesystem writes and deterministic
//! fault injection.
//!
//! All serve-layer snapshot traffic flows through [`SnapshotStore`], so
//! the pool never touches `std::fs` directly. Production uses
//! [`FsStore`], whose writes are crash-safe via
//! [`crate::checkpoint::atomic_write`] (staging file + fsync + rename).
//! The chaos suite wraps any store in [`FaultyStore`], a scripted
//! injector whose fault schedule is a pure function of the operation
//! sequence (rule windows counted in store ops, seeded faults keyed by
//! op index) — never of wall-clock time — so every chaos run is
//! reproducible bit-for-bit and thread-count independent.
//!
//! Errors carry a transient/persistent classification
//! ([`StoreError::is_transient`]) that drives the scheduler's retry
//! policy: transient errors reset the quarantine streak, persistent
//! ones count toward it.

use std::collections::BTreeMap;
use std::fmt;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::checkpoint;
use crate::rng::Pcg64;

// --- errors ------------------------------------------------------------

/// A classified snapshot-storage error. Implements `std::error::Error`,
/// so it flows into `anyhow` chains unchanged; the classification
/// survives as text (`[transient]` / `[persistent]`) and as typed
/// accessors while the error is still concrete.
#[derive(Debug, Clone)]
pub struct StoreError {
    transient: bool,
    not_found: bool,
    msg: String,
}

impl StoreError {
    /// An error worth retrying (EINTR-like): the same op may succeed on
    /// the next tick without operator intervention.
    pub fn transient(msg: impl Into<String>) -> Self {
        Self { transient: true, not_found: false, msg: msg.into() }
    }

    /// An error that will keep happening until something outside the
    /// scheduler changes (bad media, corrupt snapshot, ENOSPC).
    pub fn persistent(msg: impl Into<String>) -> Self {
        Self { transient: false, not_found: false, msg: msg.into() }
    }

    /// Classify an `io::Error`: interrupted/contended kinds are
    /// transient, everything else (including ENOSPC and EIO) persistent.
    pub fn from_io(op: &str, path: &Path, e: io::Error) -> Self {
        let transient = matches!(
            e.kind(),
            io::ErrorKind::Interrupted
                | io::ErrorKind::WouldBlock
                | io::ErrorKind::TimedOut
        );
        Self {
            transient,
            not_found: e.kind() == io::ErrorKind::NotFound,
            msg: format!("{op} {}: {e}", path.display()),
        }
    }

    /// Prepend context, preserving the classification.
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.msg = format!("{ctx}: {}", self.msg);
        self
    }

    pub fn is_transient(&self) -> bool {
        self.transient
    }

    /// True when the underlying op failed because the path is absent —
    /// tolerated by unlink paths, fatal for reads.
    pub fn is_not_found(&self) -> bool {
        self.not_found
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let class = if self.transient { "transient" } else { "persistent" };
        write!(f, "{} [{class}]", self.msg)
    }
}

impl std::error::Error for StoreError {}

// --- the store trait ---------------------------------------------------

/// Whole-file snapshot IO, the only door between `rfa::serve` and
/// durable storage. Methods take `&self`; fault injectors use interior
/// mutability so a store can be shared with its control handle.
pub trait SnapshotStore: Send {
    /// Durably replace the contents of `path`. Implementations must be
    /// atomic: a failure (or crash) never leaves a torn file at `path`.
    fn write(&self, path: &Path, bytes: &[u8]) -> Result<(), StoreError>;

    /// Read the full contents of `path`.
    fn read(&self, path: &Path) -> Result<Vec<u8>, StoreError>;

    /// Delete `path`. Absence is reported (`is_not_found`), not hidden.
    fn remove(&self, path: &Path) -> Result<(), StoreError>;
}

/// Production store: real filesystem, crash-safe writes.
#[derive(Debug, Default, Clone, Copy)]
pub struct FsStore;

impl SnapshotStore for FsStore {
    fn write(&self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        checkpoint::atomic_write(path, bytes)
            .map_err(|e| StoreError::from_io("writing snapshot", path, e))
    }

    fn read(&self, path: &Path) -> Result<Vec<u8>, StoreError> {
        std::fs::read(path)
            .map_err(|e| StoreError::from_io("reading snapshot", path, e))
    }

    fn remove(&self, path: &Path) -> Result<(), StoreError> {
        std::fs::remove_file(path)
            .map_err(|e| StoreError::from_io("removing snapshot", path, e))
    }
}

// --- health ------------------------------------------------------------

/// Operator-facing health summary, assembled by
/// `SessionPool::health` / `BatchScheduler::health`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// The last snapshot *write* failed and none has succeeded since:
    /// eviction is suspended and admission control applies past the
    /// soft budget.
    pub degraded: bool,
    /// Sessions currently quarantined by the scheduler.
    pub quarantined: usize,
    /// A post-batch budget enforcement failed and is being retried at
    /// tick boundaries.
    pub deferred_budget: bool,
    /// Cumulative count of failed snapshot-store operations.
    pub snapshot_failures: u64,
    /// Snapshot files whose unlink failed; retried at the next
    /// eviction/close instead of being silently leaked.
    pub orphaned_snapshots: usize,
}

// --- fault injection ---------------------------------------------------

/// Which store operation a [`FaultRule`] matches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Write,
    Read,
    Remove,
}

/// What an armed rule does to the matched operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail with a transient-classified error; no filesystem effect.
    Transient,
    /// Fail with a persistent-classified error; no filesystem effect.
    Persistent,
    /// Fail persistently with an ENOSPC-shaped message.
    Enospc,
    /// Write ops only: leave half the payload at the *staging* path and
    /// report a crash — the final path is never touched, which is
    /// exactly the guarantee `atomic_write` makes about real crashes.
    /// Degrades to [`Fault::Persistent`] on non-write ops.
    TornWrite,
    /// Write ops only: flip one byte mid-payload and report *success* —
    /// the damage only surfaces later as a CRC failure at fault-in. The
    /// pristine bytes are kept for [`FaultHandle::repair`]. Degrades to
    /// [`Fault::Persistent`] on non-write ops.
    CorruptWrite,
}

/// One scripted fault: fires on matching operations numbered
/// `skip+1 ..= skip+fires` (counted per rule, over ops that match `op`
/// and `path_contains`). Purely op-sequence based — reproducible.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// Restrict to one operation kind; `None` matches all.
    pub op: Option<StoreOp>,
    /// Restrict to paths whose string form contains this needle.
    pub path_contains: Option<String>,
    /// Matching ops to let through before firing.
    pub skip: usize,
    /// How many subsequent matching ops to fault (`usize::MAX` = forever).
    pub fires: usize,
    pub fault: Fault,
}

impl FaultRule {
    /// Rule matching every op of `op` from the first occurrence on.
    pub fn on(op: StoreOp, fault: Fault) -> Self {
        Self { op: Some(op), path_contains: None, skip: 0, fires: usize::MAX, fault }
    }

    pub fn skip(mut self, skip: usize) -> Self {
        self.skip = skip;
        self
    }

    pub fn fires(mut self, fires: usize) -> Self {
        self.fires = fires;
        self
    }

    pub fn on_path(mut self, needle: impl Into<String>) -> Self {
        self.path_contains = Some(needle.into());
        self
    }
}

/// Seeded background fault stream: on store op `i`, a
/// `Pcg64::seed_stream(seed, i)` draw faults the op with probability
/// `1/fault_every`. Keyed by op index, so a schedule replays exactly.
/// `transient_only` confines the stream to retryable errors (no
/// quarantine, no degraded mode) — what the recovery bench wants.
#[derive(Debug, Clone, Copy)]
pub struct SeededFaults {
    pub seed: u64,
    pub fault_every: u64,
    pub transient_only: bool,
}

/// A fault that actually fired, for schedule-determinism assertions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FiredFault {
    pub op_index: u64,
    pub op: StoreOp,
    pub path: PathBuf,
    pub fault: Fault,
}

#[derive(Default)]
struct FaultState {
    /// (rule, how many matching ops seen so far).
    rules: Vec<(FaultRule, usize)>,
    seeded: Option<SeededFaults>,
    op_index: u64,
    fired: Vec<FiredFault>,
    /// Pristine payloads of `CorruptWrite` victims, for `repair`.
    pristine: BTreeMap<PathBuf, Vec<u8>>,
}

/// Deterministic scripted fault injector around any inner store.
///
/// Keep a [`FaultHandle`] (from [`FaultyStore::handle`]) before boxing
/// the store into the pool: it heals the schedule, repairs corrupted
/// files and exposes the fired-fault log mid-run.
pub struct FaultyStore {
    inner: Box<dyn SnapshotStore>,
    state: Arc<Mutex<FaultState>>,
}

/// Control handle for a [`FaultyStore`] already owned by a pool.
#[derive(Clone)]
pub struct FaultHandle {
    state: Arc<Mutex<FaultState>>,
}

fn lock(state: &Mutex<FaultState>) -> MutexGuard<'_, FaultState> {
    state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl FaultyStore {
    pub fn new(inner: Box<dyn SnapshotStore>, rules: Vec<FaultRule>) -> Self {
        Self {
            inner,
            state: Arc::new(Mutex::new(FaultState {
                rules: rules.into_iter().map(|r| (r, 0)).collect(),
                ..FaultState::default()
            })),
        }
    }

    pub fn seeded(
        inner: Box<dyn SnapshotStore>,
        seeded: SeededFaults,
        rules: Vec<FaultRule>,
    ) -> Self {
        let store = Self::new(inner, rules);
        lock(&store.state).seeded = Some(seeded);
        store
    }

    pub fn handle(&self) -> FaultHandle {
        FaultHandle { state: Arc::clone(&self.state) }
    }

    /// Consume one op index and decide whether (and how) to fault it.
    fn decide(&self, op: StoreOp, path: &Path) -> Option<Fault> {
        let mut st = lock(&self.state);
        let op_index = st.op_index;
        st.op_index += 1;
        let mut chosen = None;
        for (rule, matched) in &mut st.rules {
            let op_ok = rule.op.is_none_or(|o| o == op);
            let path_ok = rule
                .path_contains
                .as_deref()
                .is_none_or(|needle| path.to_string_lossy().contains(needle));
            if !(op_ok && path_ok) {
                continue;
            }
            *matched += 1;
            let in_window = *matched > rule.skip
                && *matched <= rule.skip.saturating_add(rule.fires);
            if chosen.is_none() && in_window {
                chosen = Some(rule.fault);
            }
        }
        if chosen.is_none() {
            if let Some(sf) = st.seeded {
                let mut rng = Pcg64::seed_stream(sf.seed, op_index);
                if sf.fault_every > 0 && rng.next_range(sf.fault_every) == 0 {
                    chosen = Some(if sf.transient_only {
                        Fault::Transient
                    } else {
                        match rng.next_range(3) {
                            0 => Fault::Transient,
                            1 => Fault::Persistent,
                            _ if op == StoreOp::Write => Fault::TornWrite,
                            _ => Fault::Transient,
                        }
                    });
                }
            }
        }
        if let Some(fault) = chosen {
            st.fired.push(FiredFault {
                op_index,
                op,
                path: path.to_path_buf(),
                fault,
            });
        }
        chosen
    }
}

impl FaultHandle {
    /// Stop injecting: clears every rule and the seeded stream. Already-
    /// corrupted files stay corrupted — see [`FaultHandle::repair`].
    pub fn heal(&self) {
        let mut st = lock(&self.state);
        st.rules.clear();
        st.seeded = None;
    }

    /// Replace the scripted rules (per-rule match counters reset). Lets
    /// a test build its pool and sessions over a clean store, then arm
    /// the fault schedule for exactly the ops it wants to reason about.
    pub fn script(&self, rules: Vec<FaultRule>) {
        lock(&self.state).rules = rules.into_iter().map(|r| (r, 0)).collect();
    }

    /// Install (or clear) the seeded background fault stream. The op
    /// index keeps counting across the swap, so a re-armed stream still
    /// keys its draws off absolute op positions.
    pub fn set_seeded(&self, seeded: Option<SeededFaults>) {
        lock(&self.state).seeded = seeded;
    }

    /// Undo `CorruptWrite` damage by rewriting the pristine payloads
    /// (direct filesystem writes — the operator fixing the media).
    pub fn repair(&self) {
        let pristine = std::mem::take(&mut lock(&self.state).pristine);
        for (path, bytes) in pristine {
            let _ = checkpoint::atomic_write(&path, &bytes);
        }
    }

    /// Total store ops observed so far.
    pub fn ops(&self) -> u64 {
        lock(&self.state).op_index
    }

    /// Log of every fault that fired, in op order.
    pub fn fired(&self) -> Vec<FiredFault> {
        lock(&self.state).fired.clone()
    }
}

impl SnapshotStore for FaultyStore {
    fn write(&self, path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        match self.decide(StoreOp::Write, path) {
            None => self.inner.write(path, bytes),
            Some(Fault::Transient) => Err(StoreError::transient(format!(
                "injected transient fault writing {}",
                path.display()
            ))),
            Some(Fault::Persistent) => Err(StoreError::persistent(format!(
                "injected write fault on {}",
                path.display()
            ))),
            Some(Fault::Enospc) => Err(StoreError::persistent(format!(
                "injected ENOSPC: no space left on device writing {}",
                path.display()
            ))),
            Some(Fault::TornWrite) => {
                let staging = checkpoint::staging_path(path);
                let _ = std::fs::write(&staging, &bytes[..bytes.len() / 2]);
                Err(StoreError::persistent(format!(
                    "injected crash mid-write: torn staging file at {}",
                    staging.display()
                )))
            }
            Some(Fault::CorruptWrite) => {
                lock(&self.state)
                    .pristine
                    .insert(path.to_path_buf(), bytes.to_vec());
                let mut damaged = bytes.to_vec();
                if let Some(b) = damaged.get_mut(bytes.len() / 2) {
                    *b ^= 0x01;
                }
                self.inner.write(path, &damaged)
            }
        }
    }

    fn read(&self, path: &Path) -> Result<Vec<u8>, StoreError> {
        match self.decide(StoreOp::Read, path) {
            None => self.inner.read(path),
            Some(Fault::Transient) => Err(StoreError::transient(format!(
                "injected transient fault reading {}",
                path.display()
            ))),
            Some(_) => Err(StoreError::persistent(format!(
                "injected read fault on {}",
                path.display()
            ))),
        }
    }

    fn remove(&self, path: &Path) -> Result<(), StoreError> {
        match self.decide(StoreOp::Remove, path) {
            None => self.inner.remove(path),
            Some(Fault::Transient) => Err(StoreError::transient(format!(
                "injected transient fault removing {}",
                path.display()
            ))),
            Some(_) => Err(StoreError::persistent(format!(
                "injected unlink fault on {}",
                path.display()
            ))),
        }
    }
}
