//! Monte-Carlo variance measurement for PRF estimators (Section 3 engine).
//!
//! The quantity of interest is the paper's expected Monte-Carlo variance
//!
//! ```text
//! V(psi) = E_{q,k ~ D}[ Var_omega[ kappa_hat_psi(q, k) ] ]
//! ```
//!
//! For an m-sample empirical-mean estimator, `Var[kappa_hat] = Var[Z] / m`
//! where `Z` is the single-draw integrand, so we estimate `Var[Z]` per
//! (q, k) pair with `n_omega` draws and average over pairs. For the
//! isotropic Gaussian case the second moment has the closed form used in
//! Appendix A, which the tests pin against.
//!
//! This module is the scalar *reference* engine. The production path is
//! [`crate::rfa::batch`]: same estimator, shared draw banks, hoisted
//! normalizers, `std::thread::scope` fan-out — benchmarked against this
//! one in `benches/variance.rs`.

use crate::rng::Pcg64;

use super::estimators::{PrfEstimator, Sampling};
use super::gaussian::MultivariateGaussian;

/// Expected Monte-Carlo variance `V(psi)` of the *m-sample* estimator.
pub fn expected_mc_variance(
    est: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    rng: &mut Pcg64,
) -> f64 {
    let mut acc = 0.0;
    for _ in 0..n_pairs {
        let q = input_dist.sample(rng);
        let k = input_dist.sample(rng);
        acc += single_draw_variance(est, &q, &k, n_omega, rng);
    }
    acc / (n_pairs as f64) / est.m as f64
}

/// `Var_omega[Z(q, k, omega)]` estimated from `n_omega` draws.
///
/// The pair normalizers (O(d²) Mahalanobis norms in the data-aware arm)
/// are hoisted out of the draw loop: each draw costs O(d). For the
/// bank-based, multi-core version of the whole pipeline see
/// [`crate::rfa::batch`].
pub fn single_draw_variance(
    est: &PrfEstimator,
    q: &[f64],
    k: &[f64],
    n_omega: usize,
    rng: &mut Pcg64,
) -> f64 {
    let (aq, ak) = est.pair_normalizers(q, k);
    // Welford for numerical stability: Z spans orders of magnitude.
    let mut mean = 0.0;
    let mut m2 = 0.0;
    for i in 0..n_omega {
        let omega = match &est.sampling {
            Sampling::Isotropic => {
                // Draw from N(0, I) through the estimator's own machinery:
                // single_term expects the matching distribution.
                est_draw_isotropic(est, rng)
            }
            _ => est_draw(est, rng),
        };
        let z = est.single_term_normalized(q, k, &omega, aq, ak);
        let delta = z - mean;
        mean += delta / (i + 1) as f64;
        m2 += delta * (z - mean);
    }
    m2 / (n_omega - 1) as f64
}

fn est_draw(est: &PrfEstimator, rng: &mut Pcg64) -> Vec<f64> {
    match &est.sampling {
        Sampling::Isotropic => est_draw_isotropic(est, rng),
        Sampling::Proposal(psi) => psi.sample(rng),
        Sampling::DataAware(ps) => ps.sample(rng),
    }
}

fn est_draw_isotropic(est: &PrfEstimator, rng: &mut Pcg64) -> Vec<f64> {
    use crate::rng::GaussianExt;
    rng.gaussian_vec(est.dim())
}

/// Paired comparison of two estimators' expected MC variance: the SAME
/// (q, k) pairs are used for both, removing the dominant noise source
/// (the heavy-tailed variation of Var[Z] across input pairs) from the
/// *ratio*. Returns `(V_a, V_b)` for the m-sample estimators.
pub fn paired_expected_mc_variance(
    est_a: &PrfEstimator,
    est_b: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    n_omega: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    let mut acc_a = 0.0;
    let mut acc_b = 0.0;
    for _ in 0..n_pairs {
        let q = input_dist.sample(rng);
        let k = input_dist.sample(rng);
        acc_a += single_draw_variance(est_a, &q, &k, n_omega, rng);
        acc_b += single_draw_variance(est_b, &q, &k, n_omega, rng);
    }
    let n = n_pairs as f64;
    (acc_a / n / est_a.m as f64, acc_b / n / est_b.m as f64)
}

/// Closed-form `Var_omega[Z]` for the isotropic estimator on a fixed pair:
/// `E[Z^2] = exp(2|q+k|^2 - |q|^2 - |k|^2)`, `E[Z] = exp(q.k)` (App. A).
pub fn isotropic_variance_closed_form(q: &[f64], k: &[f64]) -> f64 {
    let sum_sq: f64 = q.iter().zip(k).map(|(a, b)| (a + b) * (a + b)).sum();
    let q_sq: f64 = q.iter().map(|a| a * a).sum();
    let k_sq: f64 = k.iter().map(|a| a * a).sum();
    let dot: f64 = q.iter().zip(k).map(|(a, b)| a * b).sum();
    (2.0 * sum_sq - q_sq - k_sq).exp() - (2.0 * dot).exp()
}

/// Relative mean-squared error `E[((kappa_hat - kappa) / kappa)^2]` of the
/// m-sample estimator against its own target kernel — the approximation-
/// error metric for the `exp approx` table.
pub fn relative_mse(
    est: &PrfEstimator,
    input_dist: &MultivariateGaussian,
    n_pairs: usize,
    reps_per_pair: usize,
    rng: &mut Pcg64,
) -> f64 {
    let mut acc = 0.0;
    for _ in 0..n_pairs {
        let q = input_dist.sample(rng);
        let k = input_dist.sample(rng);
        let target = est.target(&q, &k);
        for _ in 0..reps_per_pair {
            let e = est.estimate(&q, &k, rng);
            let rel = (e - target) / target;
            acc += rel * rel;
        }
    }
    acc / (n_pairs * reps_per_pair) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rfa::gaussian::anisotropic_covariance;
    use crate::rfa::proposal::optimal_proposal;

    #[test]
    fn empirical_matches_closed_form_isotropic_variance() {
        let mut rng = Pcg64::seed(55);
        let q = vec![0.3, -0.1, 0.2];
        let k = vec![0.1, 0.2, -0.15];
        let est = PrfEstimator::new(3, 1, Sampling::Isotropic);
        let emp = single_draw_variance(&est, &q, &k, 400_000, &mut rng);
        let cf = isotropic_variance_closed_form(&q, &k);
        assert!((emp - cf).abs() / cf < 0.05, "emp={emp} cf={cf}");
    }

    #[test]
    fn variance_scales_inversely_with_m() {
        let mut rng = Pcg64::seed(56);
        let lambda = Matrix::identity(3).scale(0.15);
        let dist = MultivariateGaussian::new(lambda).unwrap();
        let est8 = PrfEstimator::new(3, 8, Sampling::Isotropic);
        let est64 = PrfEstimator::new(3, 64, Sampling::Isotropic);
        let v8 = expected_mc_variance(&est8, &dist, 40, 4000, &mut rng);
        let v64 = expected_mc_variance(&est64, &dist, 40, 4000, &mut rng);
        let ratio = v8 / v64;
        assert!((ratio - 8.0).abs() < 2.0, "ratio={ratio}");
    }

    /// Theorem 3.2 item (2): the optimal proposal strictly reduces expected
    /// MC variance versus isotropic sampling under anisotropic inputs.
    #[test]
    fn optimal_proposal_beats_isotropic() {
        let mut rng = Pcg64::seed(57);
        let d = 4;
        let lambda = anisotropic_covariance(d, 0.2, 0.8, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let sigma_star = optimal_proposal(&lambda).unwrap();
        let psi = MultivariateGaussian::new(sigma_star).unwrap();

        let iso = PrfEstimator::new(d, 16, Sampling::Isotropic);
        let opt = PrfEstimator::new(d, 16, Sampling::Proposal(psi));

        let v_iso = expected_mc_variance(&iso, &dist, 60, 3000, &mut rng);
        let v_opt = expected_mc_variance(&opt, &dist, 60, 3000, &mut rng);
        assert!(
            v_opt < v_iso,
            "optimal proposal should reduce variance: iso={v_iso} opt={v_opt}"
        );
    }

    #[test]
    fn paired_comparison_matches_unpaired_in_expectation() {
        let mut rng = Pcg64::seed(59);
        let lambda = anisotropic_covariance(3, 0.15, 0.5, &mut rng);
        let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
        let psi = MultivariateGaussian::new(
            optimal_proposal(&lambda).unwrap(),
        )
        .unwrap();
        let iso = PrfEstimator::new(3, 8, Sampling::Isotropic);
        let opt = PrfEstimator::new(3, 8, Sampling::Proposal(psi));
        let (v_iso, v_opt) =
            paired_expected_mc_variance(&iso, &opt, &dist, 80, 2000, &mut rng);
        assert!(v_iso > 0.0 && v_opt > 0.0);
        // Theorem 3.2(2) must hold under the paired estimator as well.
        assert!(v_opt < v_iso, "iso={v_iso} opt={v_opt}");
    }

    #[test]
    fn relative_mse_decreases_with_budget() {
        let mut rng = Pcg64::seed(58);
        let lambda = Matrix::identity(3).scale(0.1);
        let dist = MultivariateGaussian::new(lambda).unwrap();
        let small = PrfEstimator::new(3, 4, Sampling::Isotropic);
        let large = PrfEstimator::new(3, 64, Sampling::Isotropic);
        let e_small = relative_mse(&small, &dist, 30, 50, &mut rng);
        let e_large = relative_mse(&large, &dist, 30, 50, &mut rng);
        assert!(e_large < e_small, "small={e_small} large={e_large}");
    }
}
