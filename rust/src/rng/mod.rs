//! Deterministic, splittable random number generation.
//!
//! Every stochastic component of the coordinator (corpus synthesis, data
//! shuffling, seed derivation for the AOT train steps, the Monte Carlo
//! estimators in [`crate::rfa`]) draws from this PCG64-based generator so
//! that experiments are bit-reproducible from a single root seed.

mod pcg;

pub use pcg::Pcg64;

/// Gaussian sampling extension for any RNG producing uniform `f64`s.
pub trait GaussianExt {
    /// Standard normal draw via Box–Muller.
    fn gaussian(&mut self) -> f64;

    /// `n` iid standard normal draws.
    fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }
}

impl GaussianExt for Pcg64 {
    fn gaussian(&mut self) -> f64 {
        // Box–Muller; cache the second variate.
        if let Some(z) = self.take_cached_gaussian() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cache_gaussian(r * theta.sin());
        r * theta.cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut rng = Pcg64::seed(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::seed(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seed(3);
        let n = 200_000;
        let xs = rng.gaussian_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let kurt = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n as f64
            / var.powi(2);
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis={kurt}");
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg64::seed(9);
        let mut s1 = root.split();
        let mut s2 = root.split();
        let a: Vec<u64> = (0..32).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..32).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn range_is_unbiased_over_small_bound() {
        let mut rng = Pcg64::seed(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_range(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }
}
