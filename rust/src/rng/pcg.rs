//! PCG-XSL-RR 128/64: O'Neill's PCG64 member. 128-bit LCG state with an
//! xorshift-low + random-rotate output permutation — fast, tiny state,
//! excellent statistical quality for simulation workloads.

const MULTIPLIER: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG64 generator. `Clone` is intentional: cloning freezes a stream for
/// replay (used by the data loader's resumable shuffling).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    cached: Option<f64>,
}

impl Pcg64 {
    /// Seed a generator. The stream id is derived from the seed so two
    /// generators with different seeds never share a sequence.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream id.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | seed as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc, cached: None };
        rng.state = rng.state.wrapping_mul(MULTIPLIER).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULTIPLIER).wrapping_add(rng.inc);
        // A few warm-up rounds decorrelate similar seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child stream; advances this generator.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Self::seed_stream(seed, stream)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift with
    /// rejection (unbiased).
    pub fn next_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_range bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_range(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    pub(crate) fn cache_gaussian(&mut self, z: f64) {
        self.cached = Some(z);
    }

    pub(crate) fn take_cached_gaussian(&mut self) -> Option<f64> {
        self.cached.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(13);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn clone_replays_stream() {
        let mut rng = Pcg64::seed(21);
        rng.next_u64();
        let mut replay = rng.clone();
        let a: Vec<u64> = (0..16).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..16).map(|_| replay.next_u64()).collect();
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "bound must be positive")]
    fn zero_bound_panics() {
        Pcg64::seed(0).next_range(0);
    }
}
