//! Artifact manifests: canonical parameter order and model metadata.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ser::{parse, Json};

/// One parameter leaf in canonical (sorted-name) order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-(config, variant) manifest emitted by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub config: String,
    pub params: Vec<ParamSpec>,
    pub programs: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let variant = v
            .field("variant")
            .and_then(Json::as_str)
            .context("manifest missing variant")?
            .to_string();
        let config = v
            .field("config")
            .and_then(Json::as_str)
            .context("manifest missing config")?
            .to_string();
        let mut params = Vec::new();
        for p in v
            .field("params")
            .and_then(Json::as_arr)
            .context("manifest missing params")?
        {
            let name = p
                .field("name")
                .and_then(Json::as_str)
                .context("param missing name")?
                .to_string();
            let shape = p
                .field("shape")
                .and_then(Json::as_arr)
                .context("param missing shape")?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = p
                .field("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string();
            params.push(ParamSpec { name, shape, dtype });
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        // Canonical order is sorted-by-name; verify rather than trust.
        for w in params.windows(2) {
            if w[0].name >= w[1].name {
                bail!(
                    "manifest params not sorted: {} >= {}",
                    w[0].name,
                    w[1].name
                );
            }
        }
        let programs = v
            .field("programs")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|p| p.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Self { variant, config, params, programs })
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Total trainable element count (for reporting).
    pub fn total_elements(&self) -> usize {
        self.params.iter().map(ParamSpec::element_count).sum()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

/// Model metadata (`meta.json` at the config level).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub m_features: usize,
    pub r_proj: usize,
    pub variants: Vec<String>,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let get = |k: &str| -> Result<usize> {
            v.field(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta missing {k}"))
        };
        Ok(Self {
            name: v
                .field("name")
                .and_then(Json::as_str)
                .context("meta missing name")?
                .to_string(),
            vocab_size: get("vocab_size")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            seq_len: get("seq_len")?,
            batch_size: get("batch_size")?,
            m_features: get("m_features")?,
            r_proj: get("r_proj")?,
            variants: v
                .field("variants")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|p| p.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }

    /// Token-batch element count for this model: `batch * (seq_len + 1)`.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch_size * (self.seq_len + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
 "variant": "darkformer",
 "config": "tiny",
 "params": [
  {"name": "emb", "shape": [256, 64], "dtype": "f32"},
  {"name": "final_norm", "shape": [64], "dtype": "f32"}
 ],
 "programs": ["eval_step", "init", "train_step"]
}"#;

    #[test]
    fn parses_manifest() {
        let v = parse(MANIFEST).unwrap();
        let m = Manifest::from_json(&v).unwrap();
        assert_eq!(m.variant, "darkformer");
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.params[0].element_count(), 256 * 64);
        assert_eq!(m.total_elements(), 256 * 64 + 64);
        assert_eq!(m.param_index("final_norm"), Some(1));
        assert_eq!(m.programs.len(), 3);
    }

    #[test]
    fn rejects_unsorted_params() {
        let text = MANIFEST.replace("emb", "zzz");
        let v = parse(&text).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }

    #[test]
    fn rejects_empty_params() {
        let v = parse(r#"{"variant":"x","config":"y","params":[]}"#).unwrap();
        assert!(Manifest::from_json(&v).is_err());
    }
}
