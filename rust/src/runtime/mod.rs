//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! This is the only boundary between the Rust coordinator and XLA. The
//! compile path (`make artifacts`, Python) writes `*.hlo.txt` plus a
//! `manifest.json` per (model config, variant); everything here is
//! manifest-driven so the coordinator never hard-codes parameter layouts.
//!
//! Interchange is HLO *text*: jax >= 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md section 1 and /opt/xla-example).

mod manifest;
#[cfg(feature = "pjrt")]
mod program;

pub use manifest::{Manifest, ModelMeta, ParamSpec};
#[cfg(feature = "pjrt")]
pub use program::{literal_to_tensor, tensor_to_literal, Program, Runtime};
