//! PJRT client wrapper: compile HLO text, execute, untuple results.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{DType, Tensor};

/// Shared PJRT client. One per process; programs borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client (the testbed backend; see DESIGN.md §6 for
    /// the TPU deployment mapping).
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and JIT-compile it for this client.
    pub fn load_program(&self, path: &Path) -> Result<Program> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Program {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }
}

/// A compiled executable. All artifacts are lowered with
/// `return_tuple=True`, so execution always returns one tuple literal
/// which [`Program::run`] decomposes.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_ms: f64,
}

impl Program {
    /// Execute with host literals; returns the untupled output literals.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?;
        if outs.is_empty() || outs[0].is_empty() {
            bail!("{}: no outputs", self.name);
        }
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        tuple.decompose_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))
    }
}

/// Convert a PJRT literal to a host [`Tensor`] (checkpoint format).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    match ty {
        xla::ElementType::F32 => {
            let v: Vec<f32> =
                lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::from_f32(dims, &v))
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> =
                lit.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(Tensor::from_i32(dims, &v))
        }
        other => bail!("unsupported literal dtype {other:?}"),
    }
}

/// Convert a host [`Tensor`] back to a PJRT literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match t.dtype {
        DType::F32 => {
            let v = t.as_f32()?;
            xla::Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e:?}"))?
        }
        DType::I32 => {
            let v = t.as_i32()?;
            xla::Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e:?}"))?
        }
        DType::U32 => bail!("u32 tensors only appear as scalars; use Literal::scalar"),
        DType::F64 => bail!(
            "f64 tensors are host-side only (rfa::serve snapshots); the \
             PJRT path is f32"
        ),
    };
    Ok(lit)
}

/// Scalar literal helpers (shape `()`, matching the lowered signatures).
pub mod scalars {
    pub fn f32(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn i32(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn u32(v: u32) -> xla::Literal {
        xla::Literal::scalar(v)
    }
}

/// Read a scalar f32 from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_round_trip_f32() {
        let t = Tensor::from_f32(vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_literal_round_trip_i32() {
        let t = Tensor::from_i32(vec![4], &[1, -2, 3, -4]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_helpers() {
        let lit = scalars::f32(1.5);
        assert_eq!(scalar_f32(&lit).unwrap(), 1.5);
    }
}
