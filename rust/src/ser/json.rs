//! JSON parser/writer. See module docs in `mod.rs`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
///
/// Objects keep a `BTreeMap` plus an insertion-order key list so that
/// round-tripping a manifest does not reorder fields.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field access helper: `v.field("a")?.field("b")`.
    pub fn field(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Trailing whitespace is allowed, trailing content
/// is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn keyword(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            obj.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for completeness.
                        let c = if (0xd800..0xdc00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": true}"#).unwrap();
        assert_eq!(v.field("d"), Some(&Json::Bool(true)));
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].field("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse(r#""line\n\ttab \"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("line\n\ttab \"q\" é 😀"));
    }

    #[test]
    fn preserves_object_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn writer_round_trips() {
        let src = r#"{"name":"x","shape":[2,3],"ok":true,"v":null,"f":1.5}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'single':1}").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
 "variant": "darkformer",
 "params": [{"name": "emb", "shape": [256, 64], "dtype": "f32"}]
}"#;
        let v = parse(src).unwrap();
        let p = &v.field("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.field("name").unwrap().as_str(), Some("emb"));
        let shape: Vec<usize> = p
            .field("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![256, 64]);
    }
}
