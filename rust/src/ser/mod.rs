//! Minimal serialization substrate: JSON value model, parser and writer.
//!
//! The offline build environment ships no serde, so the (small) JSON needs
//! of the system — AOT manifests, experiment metadata, metric records —
//! are covered by this hand-rolled implementation. It supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) and preserves object insertion order, which keeps manifests
//! diff-stable.

mod json;

pub use json::{parse, Json, JsonError, JsonObj};
