//! Byte-level byte-pair encoding: trainer + encoder/decoder + vocab I/O.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A trained BPE model. Token ids: `0..256` are raw bytes; `256..vocab`
/// are merge products in creation order.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merges[i] = (left, right) produced token `256 + i`.
    merges: Vec<(u32, u32)>,
    /// pair -> merged id, for encoding.
    merge_map: HashMap<(u32, u32), u32>,
    /// Rank of each merge (lower = earlier = higher priority).
    rank: HashMap<(u32, u32), u32>,
    /// token id -> byte expansion.
    decode_table: Vec<Vec<u8>>,
}

impl Bpe {
    pub fn from_merges(merges: Vec<(u32, u32)>) -> Self {
        let mut decode_table: Vec<Vec<u8>> =
            (0..256u32).map(|b| vec![b as u8]).collect();
        let mut merge_map = HashMap::new();
        let mut rank = HashMap::new();
        for (i, &(l, r)) in merges.iter().enumerate() {
            let id = 256 + i as u32;
            let mut bytes = decode_table[l as usize].clone();
            bytes.extend_from_slice(&decode_table[r as usize]);
            decode_table.push(bytes);
            merge_map.insert((l, r), id);
            rank.insert((l, r), i as u32);
        }
        Self { merges, merge_map, rank, decode_table }
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Encode text by repeatedly applying the highest-priority merge —
    /// the canonical BPE inference procedure.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(u32::from).collect();
        loop {
            // Find the lowest-rank applicable pair.
            let mut best: Option<(u32, usize)> = None;
            for i in 0..ids.len().saturating_sub(1) {
                if let Some(&r) = self.rank.get(&(ids[i], ids[i + 1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((r, _)) = best else { break };
            let pair = self.merges[r as usize];
            let merged = self.merge_map[&pair];
            // Apply this merge everywhere in one pass.
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                    out.push(merged);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        ids
    }

    /// Decode ids back to bytes (exact inverse of encode) and lossily to
    /// UTF-8 for display.
    pub fn decode_bytes(&self, ids: &[u32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &id in ids {
            out.extend_from_slice(&self.decode_table[id as usize]);
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        String::from_utf8_lossy(&self.decode_bytes(ids)).into_owned()
    }

    /// Serialize: line-oriented `DKBPE v1`, then `left right` per merge.
    pub fn save(&self, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(file);
        writeln!(w, "DKBPE v1 {}", self.merges.len())?;
        for &(l, r) in &self.merges {
            writeln!(w, "{l} {r}")?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut lines = BufReader::new(file).lines();
        let header = lines.next().context("empty bpe file")??;
        let parts: Vec<&str> = header.split_whitespace().collect();
        if parts.len() != 3 || parts[0] != "DKBPE" || parts[1] != "v1" {
            bail!("bad bpe header: {header:?}");
        }
        let n: usize = parts[2].parse()?;
        let mut merges = Vec::with_capacity(n);
        for line in lines {
            let line = line?;
            let mut it = line.split_whitespace();
            let l: u32 = it.next().context("missing left id")?.parse()?;
            let r: u32 = it.next().context("missing right id")?.parse()?;
            merges.push((l, r));
        }
        if merges.len() != n {
            bail!("expected {n} merges, found {}", merges.len());
        }
        Ok(Self::from_merges(merges))
    }
}

/// Trains merges by greedy highest-count pair selection over a corpus.
pub struct BpeTrainer {
    pub vocab_size: usize,
}

impl BpeTrainer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size >= 256, "vocab must cover all bytes");
        Self { vocab_size }
    }

    /// Train on a corpus reader. Streams the input once, then iterates
    /// merges in memory on the token sequence.
    pub fn train(&self, reader: impl Read) -> Result<Bpe> {
        let mut text = Vec::new();
        BufReader::new(reader).read_to_end(&mut text)?;
        let mut ids: Vec<u32> = text.iter().map(|&b| u32::from(b)).collect();
        let n_merges = self.vocab_size - 256;
        let mut merges = Vec::with_capacity(n_merges);

        for step in 0..n_merges {
            let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
            for w in ids.windows(2) {
                // Don't merge across newlines: keeps document boundaries.
                if w[0] == u32::from(b'\n') || w[1] == u32::from(b'\n') {
                    continue;
                }
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(&(l, r), &c)| (c, std::cmp::Reverse((l, r))))
            else {
                break;
            };
            if count < 2 {
                break; // Nothing left worth merging.
            }
            let new_id = 256 + step as u32;
            merges.push(pair);
            // Replace in the working sequence.
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        Ok(Bpe::from_merges(merges))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train_on(text: &str, vocab: usize) -> Bpe {
        BpeTrainer::new(vocab).train(text.as_bytes()).unwrap()
    }

    #[test]
    fn round_trips_ascii() {
        let bpe = train_on("the cat sat on the mat. the cat sat.", 300);
        let text = "the mat sat on the cat.";
        let ids = bpe.encode(text);
        assert_eq!(bpe.decode(&ids), text);
    }

    #[test]
    fn round_trips_unicode() {
        let bpe = train_on("héllo wörld héllo wörld héllo", 280);
        let text = "héllo wörld — naïve 😀";
        assert_eq!(bpe.decode(&bpe.encode(text)), text);
    }

    #[test]
    fn compresses_repeated_text() {
        let corpus = "the quick brown fox jumps over the lazy dog. ".repeat(50);
        let bpe = train_on(&corpus, 512);
        let ids = bpe.encode("the quick brown fox");
        assert!(
            ids.len() < "the quick brown fox".len() / 2,
            "got {} tokens",
            ids.len()
        );
    }

    #[test]
    fn byte_fallback_for_unseen_input() {
        let bpe = train_on("aaaa bbbb aaaa bbbb", 270);
        let text = "zzz \u{1F980} qqq";
        assert_eq!(bpe.decode(&bpe.encode(text)), text);
    }

    #[test]
    fn vocab_size_bounded() {
        let bpe = train_on("ab ab ab ab cd cd cd cd", 512);
        // Tiny corpus: trainer stops early, never exceeds the cap.
        assert!(bpe.vocab_size() <= 512);
        assert!(bpe.vocab_size() > 256);
    }

    #[test]
    fn save_load_round_trip() {
        let bpe = train_on("the cat sat on the mat. the cat sat.", 300);
        let dir = std::env::temp_dir().join("dkf_bpe_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.bpe");
        bpe.save(&path).unwrap();
        let loaded = Bpe::load(&path).unwrap();
        let text = "the cat sat on the mat";
        assert_eq!(bpe.encode(text), loaded.encode(text));
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn merges_do_not_cross_newlines() {
        let bpe = train_on("ab\nab\nab\nab\nab\nab", 300);
        let ids = bpe.encode("ab\nab");
        // "b\n" and "\na" must never be a single token.
        for &id in &ids {
            let bytes = bpe.decode_bytes(&[id]);
            if bytes.len() > 1 {
                assert!(
                    !bytes.contains(&b'\n'),
                    "token {id} spans newline: {bytes:?}"
                );
            }
        }
    }

    #[test]
    fn load_rejects_corrupt_header() {
        let dir = std::env::temp_dir().join("dkf_bpe_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bpe");
        std::fs::write(&path, "NOTBPE v9 0\n").unwrap();
        assert!(Bpe::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }
}
