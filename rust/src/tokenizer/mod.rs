//! Byte-level BPE tokenizer substrate.
//!
//! The paper benchmarks next-token prediction on C4; our substitute corpus
//! (see [`crate::data`]) still needs a real tokenizer so the model sees a
//! realistic token distribution (Zipf-ish unigram stats, merges spanning
//! morphemes). This is a from-scratch byte-level BPE: 256 byte tokens +
//! learned merges, greedy longest-merge encoding, exact round-trip
//! decoding.

mod bpe;

pub use bpe::{Bpe, BpeTrainer};
