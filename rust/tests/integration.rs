//! Integration tests over the real AOT artifacts (tiny config).
//!
//! These exercise the full L3 -> PJRT -> HLO path: init determinism,
//! training-loss descent, checkpoint restore, finetuning across variants
//! (missing-parameter fill), and qkv-only freezing — the invariants the
//! experiment harnesses rely on.
//!
//! Skipped gracefully when `make artifacts` has not run.

use std::path::{Path, PathBuf};

use darkformer::config::{ExperimentConfig, TrainMode};
use darkformer::coordinator::{Trainer, Workbench};
use darkformer::rng::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    p.join("tiny/darkformer/manifest.json").exists().then_some(p)
}

fn tmp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("dkf_integration").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn workbench(artifacts: &Path, out: &Path) -> Workbench {
    Workbench::prepare(artifacts, "tiny", 400, 42, &out.join("_cache"))
        .expect("workbench")
}

fn cfg(artifacts: &Path, variant: &str, out: &Path) -> ExperimentConfig {
    ExperimentConfig {
        artifacts_dir: artifacts.to_path_buf(),
        model_config: "tiny".into(),
        variant: variant.into(),
        out_dir: out.to_path_buf(),
        corpus_docs: 400,
        ..Default::default()
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("SKIP: no artifacts — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn init_is_deterministic_and_matches_manifest() {
    let arts = require_artifacts!();
    let out = tmp_out("init_det");
    let wb = workbench(&arts, &out);
    let trainer =
        Trainer::new(cfg(&arts, "darkformer", &out), &wb).expect("trainer");
    let s1 = trainer.initial_state().expect("init 1");
    let s2 = trainer.initial_state().expect("init 2");
    assert_eq!(s1.n_params(), s1.manifest.n_params());
    for (a, b) in s1.params.iter().zip(&s2.params) {
        assert_eq!(a, b, "same seed must give identical init");
    }
    // DARKFormer's M starts at identity (the Performer-equivalent point).
    let m = s1.param("layer00.attn.m_proj").expect("m_proj exists");
    let vals = m.as_f32().unwrap();
    let (h, r, dh) = (m.shape[0], m.shape[1], m.shape[2]);
    for head in 0..h {
        for i in 0..r {
            for j in 0..dh {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert_eq!(vals[head * r * dh + i * dh + j], expect);
            }
        }
    }
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let arts = require_artifacts!();
    let out = tmp_out("descent");
    let wb = workbench(&arts, &out);
    let trainer =
        Trainer::new(cfg(&arts, "darkformer", &out), &wb).expect("trainer");
    let mut state = trainer.initial_state().expect("init");
    let mut rng = Pcg64::seed(1);
    let batch = wb.dataset.train_batch(wb.meta.batch_size, &mut rng);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for i in 0..15 {
        let (loss, acc, gnorm) = trainer
            .train_step(&mut state, &batch, 100 + i, 3e-3)
            .expect("step");
        assert!(loss.is_finite() && gnorm.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first - 0.5,
        "overfitting one batch must cut loss: {first} -> {last}"
    );
    assert_eq!(state.step, 15);
}

#[test]
fn full_run_writes_metrics_and_checkpoint() {
    let arts = require_artifacts!();
    let out = tmp_out("full_run");
    let wb = workbench(&arts, &out);
    let mut c = cfg(&arts, "performer", &out);
    c.steps = 6;
    c.eval_every = 3;
    let trainer = Trainer::new(c, &wb).expect("trainer");
    let report = trainer.run().expect("run");
    assert_eq!(report.steps, 6);
    assert!(report.final_loss.is_finite());
    assert!(report.eval_loss.unwrap().is_finite());
    assert!(report.metrics_path.exists());
    assert!(report.checkpoint_path.exists());
    let records =
        darkformer::metrics::MetricLogger::read_all(&report.metrics_path)
            .expect("metrics parse");
    assert_eq!(records.len(), 6);
    assert!(records.windows(2).all(|w| w[1].step == w[0].step + 1));
}

#[test]
fn checkpoint_restore_resumes_training() {
    let arts = require_artifacts!();
    let out = tmp_out("restore");
    let wb = workbench(&arts, &out);
    let mut c = cfg(&arts, "exact", &out.join("a"));
    c.steps = 4;
    let trainer = Trainer::new(c, &wb).expect("trainer");
    let report = trainer.run().expect("run");

    // Restart from the checkpoint; loss should continue from the trained
    // region, i.e. the first step's loss is close to the last one above.
    let mut c2 = cfg(&arts, "exact", &out.join("b"));
    c2.steps = 2;
    c2.init_checkpoint = Some(report.checkpoint_path.clone());
    let trainer2 = Trainer::new(c2, &wb).expect("trainer2");
    let mut state = trainer2.initial_state().expect("restore");
    let mut rng = Pcg64::seed(2);
    let batch = wb.dataset.train_batch(wb.meta.batch_size, &mut rng);
    let (loss, _, _) =
        trainer2.train_step(&mut state, &batch, 7, 1e-3).expect("step");
    assert!(
        loss < report.final_loss + 1.0,
        "restored loss {loss} should be near trained loss {}",
        report.final_loss
    );
}

#[test]
fn finetune_exact_checkpoint_into_darkformer_fills_m_proj() {
    let arts = require_artifacts!();
    let out = tmp_out("crossvariant");
    let wb = workbench(&arts, &out);
    let mut c = cfg(&arts, "exact", &out.join("pre"));
    c.steps = 3;
    let report = Trainer::new(c, &wb).expect("t").run().expect("pretrain");

    let mut c2 = cfg(&arts, "darkformer", &out.join("ft"));
    c2.steps = 2;
    c2.init_checkpoint = Some(report.checkpoint_path);
    let trainer = Trainer::new(c2, &wb).expect("t2");
    let state = trainer.initial_state().expect("cross-variant restore");
    // m_proj came from the darkformer init fallback => identity.
    let m = state.param("layer00.attn.m_proj").unwrap().as_f32().unwrap();
    assert_eq!(m[0], 1.0);
    assert_eq!(m[1], 0.0);
    // Shared weights came from the exact checkpoint (trained, not init).
    let mut c3 = cfg(&arts, "darkformer", &out.join("fresh"));
    c3.steps = 1;
    let fresh_trainer = Trainer::new(c3, &wb).expect("t3");
    let fresh = fresh_trainer.initial_state().expect("fresh init");
    assert_ne!(
        state.param("emb").unwrap(),
        fresh.param("emb").unwrap(),
        "emb should come from the trained checkpoint, not fresh init"
    );
}

#[test]
fn qkv_only_mode_freezes_non_attention_params() {
    let arts = require_artifacts!();
    let out = tmp_out("qkv");
    let wb = workbench(&arts, &out);
    let mut c = cfg(&arts, "darkformer", &out);
    c.mode = TrainMode::QkvOnly;
    let trainer = Trainer::new(c, &wb).expect("trainer");
    let mut state = trainer.initial_state().expect("init");
    let emb_before = state.param("emb").unwrap().clone();
    let wq_before = state.param("layer00.attn.wq").unwrap().clone();
    let mut rng = Pcg64::seed(3);
    let batch = wb.dataset.train_batch(wb.meta.batch_size, &mut rng);
    for i in 0..3 {
        trainer
            .train_step(&mut state, &batch, 50 + i, 1e-2)
            .expect("step");
    }
    assert_eq!(
        state.param("emb").unwrap(),
        &emb_before,
        "embedding must be frozen in qkv mode"
    );
    assert_ne!(
        state.param("layer00.attn.wq").unwrap(),
        &wq_before,
        "wq must train in qkv mode"
    );
}

#[test]
fn eval_is_deterministic() {
    let arts = require_artifacts!();
    let out = tmp_out("eval_det");
    let wb = workbench(&arts, &out);
    let trainer =
        Trainer::new(cfg(&arts, "performer", &out), &wb).expect("trainer");
    let state = trainer.initial_state().expect("init");
    let (l1, a1) = trainer.evaluate(&state, 2).expect("eval 1");
    let (l2, a2) = trainer.evaluate(&state, 2).expect("eval 2");
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
}
