//! Property suite for the incremental Cholesky kernels
//! (`rust/src/linalg/chol.rs`): rank-1/rank-k updated factors must match
//! from-scratch factorization within tolerance across adversarial
//! dimensions and block sizes — including underdetermined counts with
//! the serving layer's λ identity floor — update/downdate must be
//! mutually inverse, and SPD rejection (both `cholesky()`'s and the
//! downdate's) must be a clean refusal that leaves the factor untouched.

use darkformer::linalg::Matrix;
use darkformer::rfa::gaussian::SecondMomentAccumulator;
use darkformer::rng::{GaussianExt, Pcg64};

/// Adversarial dimension sweep shared by the property tests.
const DIMS: [usize; 7] = [1, 2, 3, 5, 8, 16, 32];

/// A well-conditioned random SPD matrix: `G·Gᵀ + d·I`.
fn random_spd(d: usize, rng: &mut Pcg64) -> Matrix {
    let g = Matrix::from_vec(d, d, rng.gaussian_vec(d * d));
    let mut a = g.matmul(&g.transpose());
    for i in 0..d {
        a[(i, i)] += d as f64;
    }
    a
}

/// `A + Σᵢ xᵢ·xᵢᵀ`, materialized directly.
fn add_outer(a: &Matrix, xs: &[Vec<f64>]) -> Matrix {
    let d = a.rows();
    let mut out = a.clone();
    for x in xs {
        for i in 0..d {
            for j in 0..d {
                out[(i, j)] += x[i] * x[j];
            }
        }
    }
    out
}

/// Max |L₁ − L₂| over the lower triangle (the strict upper triangle is
/// outside the kernels' contract).
fn lower_diff(l1: &Matrix, l2: &Matrix) -> f64 {
    let d = l1.rows();
    let mut worst = 0.0f64;
    for i in 0..d {
        for j in 0..=i {
            worst = worst.max((l1[(i, j)] - l2[(i, j)]).abs());
        }
    }
    worst
}

#[test]
fn rank1_update_matches_from_scratch() {
    let mut rng = Pcg64::seed(0xC401);
    for d in DIMS {
        for trial in 0..4 {
            let a = random_spd(d, &mut rng);
            let x = rng.gaussian_vec(d);
            let mut l = a.cholesky().expect("random SPD must factor");
            l.cholesky_update_rank1(&x);
            // The lower Cholesky factor with positive diagonal is
            // unique, so the updated factor must match the from-scratch
            // factor of A + x·xᵀ entry for entry.
            let scratch = add_outer(&a, std::slice::from_ref(&x))
                .cholesky()
                .expect("updated matrix stays SPD");
            let diff = lower_diff(&l, &scratch);
            assert!(
                diff < 1e-9,
                "d={d} trial={trial}: rank-1 update drifted {diff:e} \
                 from the from-scratch factor"
            );
        }
    }
}

#[test]
fn rank_k_update_matches_from_scratch() {
    let mut rng = Pcg64::seed(0xC402);
    for d in DIMS {
        // Block sizes below, at, and well above the dimension — the
        // serving layer's inter-epoch blocks land anywhere in this range.
        for k in [1, d.saturating_sub(1).max(1), d, 2 * d + 3] {
            let a = random_spd(d, &mut rng);
            let xs: Vec<Vec<f64>> =
                (0..k).map(|_| rng.gaussian_vec(d)).collect();
            let mut l = a.cholesky().expect("random SPD must factor");
            l.cholesky_update(&xs);
            let scratch = add_outer(&a, &xs)
                .cholesky()
                .expect("updated matrix stays SPD");
            let diff = lower_diff(&l, &scratch);
            // Tolerance scales mildly with the accumulated update mass.
            let tol = 1e-9 * (1.0 + k as f64);
            assert!(
                diff < tol,
                "d={d} k={k}: rank-k update drifted {diff:e} from the \
                 from-scratch factor"
            );
        }
    }
}

/// The serving layer's exact maintenance loop, underdetermined: freeze
/// the identity floor at a count *below* the dimension (the raw moment
/// is rank deficient — only the λ floor keeps U factorable), then stream
/// further keys as `√(1-λ)·k` rank-1 updates and compare against a
/// from-scratch factorization of `U = (1-λ)·C + λ·floor·I` every step.
#[test]
fn streamed_maintenance_matches_from_scratch_underdetermined() {
    let mut rng = Pcg64::seed(0xC403);
    for (d, floor_count) in [(6, 2), (8, 3), (16, 5), (32, 7)] {
        for lambda in [1e-3, 0.05, 0.5] {
            let mut acc = SecondMomentAccumulator::new(d);
            let keys: Vec<Vec<f64>> =
                (0..floor_count).map(|_| rng.gaussian_vec(d)).collect();
            for k in &keys {
                acc.accumulate(k);
            }
            let unnorm = |acc: &SecondMomentAccumulator| {
                let mut u = acc.sum().scale(1.0 - lambda);
                for i in 0..d {
                    u[(i, i)] += lambda * floor_count as f64;
                }
                u
            };
            let mut l = unnorm(&acc)
                .cholesky()
                .expect("λ floor must keep U SPD while underdetermined");
            let up_scale = (1.0 - lambda).sqrt();
            for step in 0..3 * d {
                let key = rng.gaussian_vec(d);
                acc.accumulate(&key);
                let x: Vec<f64> =
                    key.iter().map(|&v| up_scale * v).collect();
                l.cholesky_update_rank1(&x);
                let scratch = unnorm(&acc)
                    .cholesky()
                    .expect("U stays SPD as observations accrue");
                let diff = lower_diff(&l, &scratch);
                assert!(
                    diff < 1e-8,
                    "d={d} λ={lambda} step={step}: maintained factor \
                     drifted {diff:e} from scratch"
                );
            }
        }
    }
}

#[test]
fn downdate_inverts_update() {
    let mut rng = Pcg64::seed(0xC404);
    for d in DIMS {
        let a = random_spd(d, &mut rng);
        let reference = a.cholesky().expect("random SPD must factor");
        let x = rng.gaussian_vec(d);
        let mut l = reference.clone();
        l.cholesky_update_rank1(&x);
        assert!(
            l.cholesky_downdate_rank1(&x),
            "d={d}: downdating an immediately preceding update must \
             succeed"
        );
        let diff = lower_diff(&l, &reference);
        assert!(
            diff < 1e-9,
            "d={d}: update∘downdate drifted {diff:e} from the original \
             factor"
        );
    }
}

#[test]
fn refused_downdate_leaves_factor_bitwise_unchanged() {
    let mut rng = Pcg64::seed(0xC405);
    for d in DIMS {
        let a = random_spd(d, &mut rng);
        let l = a.cholesky().expect("random SPD must factor");
        // x long enough that A − x·xᵀ is indefinite: ‖x‖² beyond the
        // largest possible eigenvalue (trace bounds it).
        let trace: f64 = (0..d).map(|i| a[(i, i)]).sum();
        let scale = (2.0 * trace).sqrt();
        let mut x = vec![0.0; d];
        x[d - 1] = scale; // late pivot: earlier pivots may pass first
        let mut attempted = l.clone();
        assert!(
            !attempted.cholesky_downdate_rank1(&x),
            "d={d}: indefinite downdate must be refused"
        );
        // Refusal is a clean no-op: every bit of the factor survives.
        assert_eq!(
            attempted.data(),
            l.data(),
            "d={d}: refused downdate touched the factor"
        );
    }
}

#[test]
fn spd_rejection_preserved() {
    // The base factorization still refuses indefinite input…
    let indefinite = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
    assert!(indefinite.cholesky().is_none());
    // …and an update never breaks SPD: updating the refused matrix's
    // SPD shift keeps factoring.
    let mut shifted = indefinite;
    for i in 0..2 {
        shifted[(i, i)] += 3.0;
    }
    let mut l = shifted.cholesky().expect("shifted matrix is SPD");
    l.cholesky_update_rank1(&[10.0, -7.0]);
    let rebuilt = l.matmul(&l.transpose());
    let expected = add_outer(&shifted, &[vec![10.0, -7.0]]);
    assert!(
        rebuilt.max_abs_diff(&expected) < 1e-9,
        "update must keep L·Lᵀ = A + x·xᵀ"
    );
}
