//! Property suite for the `linalg::simd` dispatch layer.
//!
//! The dispatch contract is that every microkernel is **bitwise-identical**
//! to its portable reference in `simd::fallback` on every ISA the machine
//! can route to. This suite pins that with `to_bits` equality:
//!
//! * kernel-level, across adversarial lengths (empty, below lane width,
//!   exact multiples of 4/8/16, one off either side of each) and
//!   offset-by-one (unaligned) slices, both precisions;
//! * `Mat`-level, forced-scalar vs detected-ISA over the contraction
//!   kernels (`matmul`, `matmul_transb`, `matmul_transa`, `col_sums`,
//!   `matvec_accum`) on degenerate and tail-heavy shapes;
//! * the blocked `transpose` against the index permutation it claims to be;
//! * the feature map end to end under both dispatch modes.
//!
//! The effective ISA is a process-global atomic, so every test that forces
//! it serializes on one mutex (poison-tolerant: an assert failure in one
//! test must not wedge the rest).

use std::sync::{Mutex, MutexGuard, OnceLock};

use darkformer::linalg::simd::{self, fallback, Isa};
use darkformer::linalg::{Matrix, Matrix32};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::{FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

/// Lengths around every lane boundary the kernels split on: 4 (f64×256),
/// 8 (f32×256 / f64×512), 16 (f32×512), plus larger head/body/tail mixes.
const LENS: &[usize] = &[
    0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 255, 256, 257,
];

fn isa_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Every ISA this machine can actually execute (always includes Scalar;
/// unsupported variants are filtered rather than silently sanitized so
/// each loop iteration tests a distinct code path).
fn usable_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Neon, Isa::Avx2, Isa::Avx512]
        .into_iter()
        .filter(|&i| simd::supported(i))
        .collect()
}

/// Run `f` once per supported ISA with the dispatcher held on it.
fn with_each_isa(mut f: impl FnMut(Isa)) {
    let _guard = isa_lock();
    let prev = simd::set_isa(Isa::Scalar);
    for isa in usable_isas() {
        simd::set_isa(isa);
        f(isa);
    }
    simd::set_isa(prev);
}

fn gen64(n: usize, seed: u64) -> Vec<f64> {
    Pcg64::seed(seed).gaussian_vec(n)
}

fn gen32(n: usize, seed: u64) -> Vec<f32> {
    gen64(n, seed).iter().map(|&x| x as f32).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// --------------------------------------------------- kernel-level pins

#[test]
fn dot_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let a = gen64(n + 1, 11 + n as u64);
            let b = gen64(n + 1, 77 + n as u64);
            let a32 = gen32(n + 1, 13 + n as u64);
            let b32 = gen32(n + 1, 79 + n as u64);
            for off in [0usize, 1] {
                let (x, y) = (&a[off..off + n], &b[off..off + n]);
                assert_eq!(
                    simd::dot_f64(x, y).to_bits(),
                    fallback::dot_f64(x, y).to_bits(),
                    "dot_f64 n={n} off={off} isa={isa:?}"
                );
                let (x, y) = (&a32[off..off + n], &b32[off..off + n]);
                assert_eq!(
                    simd::dot_f32(x, y).to_bits(),
                    fallback::dot_f32(x, y).to_bits(),
                    "dot_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn dot4_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let a = gen64(n + 1, 211 + n as u64);
            let bs: Vec<Vec<f64>> =
                (0..4).map(|j| gen64(n + 1, 300 + j + n as u64)).collect();
            let a32 = gen32(n + 1, 213 + n as u64);
            let bs32: Vec<Vec<f32>> =
                (0..4).map(|j| gen32(n + 1, 400 + j + n as u64)).collect();
            for off in [0usize, 1] {
                let e = off + n;
                let b = [&bs[0][off..e], &bs[1][off..e], &bs[2][off..e], &bs[3][off..e]];
                let got = simd::dot4_f64(&a[off..e], b);
                let want = fallback::dot4_f64(&a[off..e], b);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "dot4_f64 n={n} off={off} isa={isa:?}"
                );
                let b32 =
                    [&bs32[0][off..e], &bs32[1][off..e], &bs32[2][off..e], &bs32[3][off..e]];
                let got = simd::dot4_f32(&a32[off..e], b32);
                let want = fallback::dot4_f32(&a32[off..e], b32);
                assert_eq!(
                    bits32(&got),
                    bits32(&want),
                    "dot4_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn axpy_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let base = gen64(n + 1, 501 + n as u64);
            let x = gen64(n + 1, 601 + n as u64);
            let base32 = gen32(n + 1, 503 + n as u64);
            let x32 = gen32(n + 1, 603 + n as u64);
            for off in [0usize, 1] {
                let e = off + n;
                let mut got = base[off..e].to_vec();
                let mut want = got.clone();
                simd::axpy_f64(&mut got, 0.37, &x[off..e]);
                fallback::axpy_f64(&mut want, 0.37, &x[off..e]);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "axpy_f64 n={n} off={off} isa={isa:?}"
                );
                let mut got = base32[off..e].to_vec();
                let mut want = got.clone();
                simd::axpy_f32(&mut got, 0.37, &x32[off..e]);
                fallback::axpy_f32(&mut want, 0.37, &x32[off..e]);
                assert_eq!(
                    bits32(&got),
                    bits32(&want),
                    "axpy_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn axpy4_matches_fallback_bitwise() {
    let a4 = [0.31f64, -1.7, 0.002, 4.5];
    let a4_32 = [0.31f32, -1.7, 0.002, 4.5];
    with_each_isa(|isa| {
        for &n in LENS {
            let base = gen64(n + 1, 701 + n as u64);
            let xs: Vec<Vec<f64>> =
                (0..4).map(|j| gen64(n + 1, 800 + j + n as u64)).collect();
            let base32 = gen32(n + 1, 703 + n as u64);
            let xs32: Vec<Vec<f32>> =
                (0..4).map(|j| gen32(n + 1, 900 + j + n as u64)).collect();
            for off in [0usize, 1] {
                let e = off + n;
                let x = [&xs[0][off..e], &xs[1][off..e], &xs[2][off..e], &xs[3][off..e]];
                let mut got = base[off..e].to_vec();
                let mut want = got.clone();
                simd::axpy4_f64(&mut got, a4, x);
                fallback::axpy4_f64(&mut want, a4, x);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "axpy4_f64 n={n} off={off} isa={isa:?}"
                );
                let x32 =
                    [&xs32[0][off..e], &xs32[1][off..e], &xs32[2][off..e], &xs32[3][off..e]];
                let mut got = base32[off..e].to_vec();
                let mut want = got.clone();
                simd::axpy4_f32(&mut got, a4_32, x32);
                fallback::axpy4_f32(&mut want, a4_32, x32);
                assert_eq!(
                    bits32(&got),
                    bits32(&want),
                    "axpy4_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn accum_row_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let base = gen64(n + 1, 1001 + n as u64);
            let row = gen64(n + 1, 1101 + n as u64);
            let row32 = gen32(n + 1, 1103 + n as u64);
            for off in [0usize, 1] {
                let e = off + n;
                let mut got = base[off..e].to_vec();
                let mut want = got.clone();
                simd::accum_row_f64(&mut got, &row[off..e]);
                fallback::accum_row_f64(&mut want, &row[off..e]);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "accum_row_f64 n={n} off={off} isa={isa:?}"
                );
                let mut got = base[off..e].to_vec();
                let mut want = got.clone();
                simd::accum_row_f32(&mut got, &row32[off..e]);
                fallback::accum_row_f32(&mut want, &row32[off..e]);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "accum_row_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn dot_seq_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let a = gen64(n + 1, 1201 + n as u64);
            let b = gen64(n + 1, 1301 + n as u64);
            let a32 = gen32(n + 1, 1203 + n as u64);
            let b32 = gen32(n + 1, 1303 + n as u64);
            for off in [0usize, 1] {
                let e = off + n;
                assert_eq!(
                    simd::dot_seq_f64(&a[off..e], &b[off..e]).to_bits(),
                    fallback::dot_seq_f64(&a[off..e], &b[off..e]).to_bits(),
                    "dot_seq_f64 n={n} off={off} isa={isa:?}"
                );
                assert_eq!(
                    simd::dot_seq_f32(&a32[off..e], &b32[off..e]).to_bits(),
                    fallback::dot_seq_f32(&a32[off..e], &b32[off..e]).to_bits(),
                    "dot_seq_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

#[test]
fn feature_finish_matches_fallback_bitwise() {
    with_each_isa(|isa| {
        for &n in LENS {
            let row = gen64(n + 1, 1401 + n as u64);
            let row32 = gen32(n + 1, 1403 + n as u64);
            // Positive weights as the real bank produces (sqrt of w_i > 0).
            let sqrt_w: Vec<f64> = gen64(n + 1, 1501 + n as u64)
                .iter()
                .map(|x| x.abs() + 0.5)
                .collect();
            for off in [0usize, 1] {
                let e = off + n;
                let mut got = row[off..e].to_vec();
                let mut want = got.clone();
                simd::feature_finish_f64(&mut got, 0.25, &sqrt_w[off..e]);
                fallback::feature_finish_f64(&mut want, 0.25, &sqrt_w[off..e]);
                assert_eq!(
                    bits64(&got),
                    bits64(&want),
                    "feature_finish_f64 n={n} off={off} isa={isa:?}"
                );
                let mut got = row32[off..e].to_vec();
                let mut want = got.clone();
                simd::feature_finish_f32(&mut got, 0.25, &sqrt_w[off..e]);
                fallback::feature_finish_f32(&mut want, 0.25, &sqrt_w[off..e]);
                assert_eq!(
                    bits32(&got),
                    bits32(&want),
                    "feature_finish_f32 n={n} off={off} isa={isa:?}"
                );
            }
        }
    });
}

// ------------------------------------------------------ Mat-level pins

/// (m, k, n) shapes: degenerate, all-tails, and mixes that cross the
/// matmul KT=64/JT=256 tile edges and the 4-wide register blocks.
const MAT_SHAPES: &[(usize, usize, usize)] = &[
    (0, 0, 0),
    (1, 1, 1),
    (3, 5, 7),
    (17, 63, 65),
    (8, 65, 257),
    (63, 255, 33),
];

fn mat64(r: usize, c: usize, seed: u64) -> Matrix {
    Matrix::from_vec(r, c, gen64(r * c, seed))
}

#[test]
fn mat_contractions_dispatch_vs_scalar_bitwise() {
    let _guard = isa_lock();
    let prev = simd::set_isa(Isa::Scalar);
    for (i, &(m, k, n)) in MAT_SHAPES.iter().enumerate() {
        let s = 2000 + 10 * i as u64;
        let a = mat64(m, k, s);
        let b = mat64(k, n, s + 1);
        let bt = mat64(n, k, s + 2);
        let at = mat64(k, m, s + 3);
        let x = gen64(k, s + 4);
        let (a32, b32, bt32, at32) = (
            Matrix32::from_f64(&a),
            Matrix32::from_f64(&b),
            Matrix32::from_f64(&bt),
            Matrix32::from_f64(&at),
        );
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();

        simd::set_isa(Isa::Scalar);
        let scalar = (
            a.matmul(&b),
            a.matmul_transb(&bt),
            at.matmul_transa(&b),
            a.col_sums(),
            a.matvec_accum(&x),
        );
        let scalar32 = (
            a32.matmul(&b32),
            a32.matmul_transb(&bt32),
            at32.matmul_transa(&b32),
            a32.col_sums(),
            a32.matvec_accum(&x32),
        );

        simd::set_isa(simd::detected_isa());
        let ctx = format!("shape ({m},{k},{n}) isa={:?}", simd::isa());
        assert_eq!(bits64(a.matmul(&b).data()), bits64(scalar.0.data()), "matmul {ctx}");
        assert_eq!(
            bits64(a.matmul_transb(&bt).data()),
            bits64(scalar.1.data()),
            "matmul_transb {ctx}"
        );
        assert_eq!(
            bits64(at.matmul_transa(&b).data()),
            bits64(scalar.2.data()),
            "matmul_transa {ctx}"
        );
        assert_eq!(bits64(&a.col_sums()), bits64(&scalar.3), "col_sums {ctx}");
        assert_eq!(bits64(&a.matvec_accum(&x)), bits64(&scalar.4), "matvec_accum {ctx}");
        assert_eq!(
            bits32(a32.matmul(&b32).data()),
            bits32(scalar32.0.data()),
            "matmul f32 {ctx}"
        );
        assert_eq!(
            bits32(a32.matmul_transb(&bt32).data()),
            bits32(scalar32.1.data()),
            "matmul_transb f32 {ctx}"
        );
        assert_eq!(
            bits32(at32.matmul_transa(&b32).data()),
            bits32(scalar32.2.data()),
            "matmul_transa f32 {ctx}"
        );
        assert_eq!(bits64(&a32.col_sums()), bits64(&scalar32.3), "col_sums f32 {ctx}");
        assert_eq!(
            bits64(&a32.matvec_accum(&x32)),
            bits64(&scalar32.4),
            "matvec_accum f32 {ctx}"
        );
    }
    simd::set_isa(prev);
}

#[test]
fn blocked_transpose_is_pure_permutation() {
    // ISA-independent (pure permutation), so no dispatch lock needed.
    for &(r, c) in &[(0usize, 0usize), (1, 9), (9, 1), (5, 0), (33, 65), (64, 64), (31, 257)] {
        let a = mat64(r, c, 3000 + (r * 1000 + c) as u64);
        let t = a.transpose();
        assert_eq!((t.rows(), t.cols()), (c, r));
        for i in 0..r {
            for j in 0..c {
                assert_eq!(
                    t.data()[j * r + i].to_bits(),
                    a.data()[i * c + j].to_bits(),
                    "transpose permutation ({r}x{c}) at ({i},{j})"
                );
            }
        }
        let back = t.transpose();
        assert_eq!(bits64(back.data()), bits64(a.data()), "transpose involution ({r}x{c})");

        let a32 = Matrix32::from_f64(&a);
        let t32 = a32.transpose();
        assert_eq!((t32.rows(), t32.cols()), (c, r));
        for i in 0..r {
            for j in 0..c {
                assert_eq!(
                    t32.data()[j * r + i].to_bits(),
                    a32.data()[i * c + j].to_bits(),
                    "transpose f32 permutation ({r}x{c}) at ({i},{j})"
                );
            }
        }
        assert_eq!(
            bits32(t32.transpose().data()),
            bits32(a32.data()),
            "transpose f32 involution ({r}x{c})"
        );
    }
}

// ------------------------------------------------- end-to-end + policy

#[test]
fn feature_map_bitwise_across_dispatch_modes() {
    let _guard = isa_lock();
    // M=33 (odd) forces tail iterations in every projection kernel.
    let est = PrfEstimator::new(8, 33, Sampling::Isotropic);
    let mut rng = Pcg64::seed(0xfeed);
    let bank = FeatureBank::draw(&est, &mut rng);
    let xs: Vec<Vec<f64>> = (0..17)
        .map(|_| rng.gaussian_vec(8).iter().map(|v| 0.2 * v).collect())
        .collect();

    let prev = simd::set_isa(Isa::Scalar);
    let phi64_scalar = bank.feature_matrix(&xs);
    let phi32_scalar = bank.feature_matrix32(&xs);
    simd::set_isa(simd::detected_isa());
    let phi64_simd = bank.feature_matrix(&xs);
    let phi32_simd = bank.feature_matrix32(&xs);
    simd::set_isa(prev);

    assert_eq!(bits64(phi64_scalar.data()), bits64(phi64_simd.data()), "feature map f64");
    assert_eq!(bits32(phi32_scalar.data()), bits32(phi32_simd.data()), "feature map f32");
}

#[test]
fn set_isa_sanitizes_and_reports() {
    let _guard = isa_lock();
    let prev = simd::set_isa(Isa::Scalar);
    assert_eq!(simd::isa(), Isa::Scalar);
    assert_eq!(simd::active_isa(), "scalar");
    for target in [Isa::Neon, Isa::Avx2, Isa::Avx512] {
        simd::set_isa(Isa::Scalar);
        let returned = simd::set_isa(target);
        assert_eq!(returned, Isa::Scalar, "set_isa returns the previous ISA");
        let expect = if simd::supported(target) { target } else { Isa::Scalar };
        assert_eq!(simd::isa(), expect, "unsupported {target:?} must sanitize to Scalar");
    }
    assert!(simd::supported(Isa::Scalar), "Scalar is supported everywhere");
    assert!(
        simd::supported(simd::detected_isa()),
        "detection only reports executable ISAs"
    );
    simd::set_isa(prev);
}
