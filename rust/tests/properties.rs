//! Randomized property tests over coordinator invariants.
//!
//! The offline environment has no proptest crate, so these are seeded
//! randomized sweeps (many cases per property, deterministic from a root
//! seed — failures reproduce exactly). Each property mirrors what
//! proptest would assert: round-trips, ordering invariants, and
//! robustness of parsers to hostile input.

use darkformer::checkpoint::{Checkpoint, Tensor};
use darkformer::config::LrSchedule;
use darkformer::data::{CorpusGenerator, CorpusSpec, TokenDataset};
use darkformer::metrics::SpikeDetector;
use darkformer::rng::{GaussianExt, Pcg64};
use darkformer::ser::{parse, Json};
use darkformer::tokenizer::BpeTrainer;

// ---------------------------------------------------------------------
// Checkpoint: random tensors round-trip bit-exactly
// ---------------------------------------------------------------------

#[test]
fn prop_checkpoint_round_trips_random_tensors() {
    let mut rng = Pcg64::seed(0xc0ffee);
    let dir = std::env::temp_dir().join("dkf_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..20 {
        let mut ck = Checkpoint::new();
        let n_tensors = 1 + rng.next_range(6) as usize;
        for t in 0..n_tensors {
            let rank = rng.next_range(4) as usize;
            let shape: Vec<usize> =
                (0..rank).map(|_| 1 + rng.next_range(8) as usize).collect();
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n)
                .map(|_| {
                    // Include special values.
                    match rng.next_range(10) {
                        0 => 0.0,
                        1 => -0.0,
                        2 => f32::MIN_POSITIVE,
                        3 => f32::MAX,
                        _ => (rng.gaussian() * 100.0) as f32,
                    }
                })
                .collect();
            ck.insert(format!("t{t}"), Tensor::from_f32(shape, &data));
        }
        let path = dir.join(format!("case{case}.dkft"));
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), ck.len());
        for name in ck.names() {
            assert_eq!(loaded.get(name), ck.get(name), "case {case} {name}");
        }
    }
}

#[test]
fn prop_checkpoint_detects_random_single_byte_corruption() {
    let mut rng = Pcg64::seed(0xbad);
    let dir = std::env::temp_dir().join("dkf_prop_ckpt2");
    std::fs::create_dir_all(&dir).unwrap();
    let mut ck = Checkpoint::new();
    let data: Vec<f32> = (0..256).map(|i| i as f32).collect();
    ck.insert("w", Tensor::from_f32(vec![16, 16], &data));
    let path = dir.join("corrupt.dkft");
    ck.save(&path).unwrap();
    let orig = std::fs::read(&path).unwrap();
    for _ in 0..30 {
        let mut bytes = orig.clone();
        // Flip one random byte after the magic.
        let idx = 4 + rng.next_range((bytes.len() - 4) as u64) as usize;
        let flip = 1 + rng.next_range(255) as u8;
        bytes[idx] ^= flip;
        std::fs::write(&path, &bytes).unwrap();
        let res = Checkpoint::load(&path);
        // Either the CRC catches it (error) or the flipped byte was in the
        // stored CRC itself (also error). Never a silent wrong read.
        assert!(res.is_err(), "byte {idx} flip {flip:#x} undetected");
    }
}

// ---------------------------------------------------------------------
// BPE: random unicode strings round-trip through encode/decode
// ---------------------------------------------------------------------

#[test]
fn prop_bpe_round_trips_random_strings() {
    let mut gen = CorpusGenerator::new(CorpusSpec::default(), 3);
    let corpus = gen.documents(150);
    let bpe = BpeTrainer::new(400).train(corpus.as_bytes()).unwrap();
    let mut rng = Pcg64::seed(0xbbe);
    let alphabet: Vec<char> =
        "abcdefghijklmnop qrstuvwxyz.,!?éü😀\n\t0123456789".chars().collect();
    for case in 0..100 {
        let len = rng.next_range(200) as usize;
        let s: String = (0..len)
            .map(|_| alphabet[rng.next_range(alphabet.len() as u64) as usize])
            .collect();
        let ids = bpe.encode(&s);
        assert_eq!(bpe.decode(&ids), s, "case {case}");
        // All ids in range.
        assert!(ids.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    }
}

// ---------------------------------------------------------------------
// JSON: writer output re-parses to the same value
// ---------------------------------------------------------------------

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.next_range(4) } else { rng.next_range(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.gaussian() * 1e3).round() / 8.0),
        3 => {
            let len = rng.next_range(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    char::from_u32(32 + rng.next_range(90) as u32).unwrap()
                })
                .collect();
            Json::Str(s + "\"\\\n✓")
        }
        4 => Json::Arr(
            (0..rng.next_range(4)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => {
            let mut obj = darkformer::ser::JsonObj::new();
            for i in 0..rng.next_range(4) {
                obj.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(obj)
        }
    }
}

#[test]
fn prop_json_write_parse_round_trip() {
    let mut rng = Pcg64::seed(0x15a);
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string_compact();
        let back = parse(&text).unwrap_or_else(|e| {
            panic!("case {case}: wrote unparseable JSON {text:?}: {e}")
        });
        assert_eq!(back, v, "case {case}: {text}");
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let mut rng = Pcg64::seed(0x6a7);
    for _ in 0..500 {
        let len = rng.next_range(64) as usize;
        let bytes: Vec<u8> =
            (0..len).map(|_| rng.next_range(128) as u8).collect();
        if let Ok(s) = String::from_utf8(bytes) {
            let _ = parse(&s); // Must return, never panic.
        }
    }
}

// ---------------------------------------------------------------------
// LR schedules: bounded, warmup-monotone, decay-monotone
// ---------------------------------------------------------------------

#[test]
fn prop_lr_schedules_bounded_and_shaped() {
    let mut rng = Pcg64::seed(0x5c4ed);
    for _ in 0..50 {
        let total = 50 + rng.next_range(500);
        let warmup = rng.next_range(total / 2);
        let final_frac = rng.next_f64() * 0.5;
        for sched in [
            LrSchedule::Constant,
            LrSchedule::WarmupCosine { warmup_steps: warmup, final_frac },
            LrSchedule::WarmupLinear { warmup_steps: warmup, final_frac },
        ] {
            let mut prev_warm = 0.0;
            let mut prev_decay = f64::INFINITY;
            for step in 0..total {
                let m = sched.multiplier(step, total);
                assert!(
                    (0.0..=1.0 + 1e-9).contains(&m),
                    "multiplier out of range: {m} ({sched:?})"
                );
                if step < warmup {
                    assert!(m >= prev_warm - 1e-12, "warmup must ramp up");
                    prev_warm = m;
                } else if !matches!(sched, LrSchedule::Constant) {
                    assert!(m <= prev_decay + 1e-12, "decay must not rise");
                    prev_decay = m;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dataset: windows always in range, valid/train disjoint
// ---------------------------------------------------------------------

#[test]
fn prop_dataset_windows_in_bounds_across_seeds() {
    let mut gen = CorpusGenerator::new(CorpusSpec::default(), 5);
    let corpus = gen.documents(200);
    let bpe = BpeTrainer::new(300).train(corpus.as_bytes()).unwrap();
    for seq_len in [8, 16, 32] {
        let ds = TokenDataset::from_text(&corpus, &bpe, seq_len, 0.1).unwrap();
        for seed in 0..20 {
            let mut rng = Pcg64::seed(seed);
            let b = ds.train_batch(4, &mut rng);
            assert_eq!(b.len(), 4 * (seq_len + 1));
            assert!(b.iter().all(|&t| t >= 0));
            assert!(b
                .iter()
                .all(|&t| (t as usize) < bpe.vocab_size()));
        }
    }
}

// ---------------------------------------------------------------------
// Spike detector: event count <= spiking steps <= total; no spikes on
// monotone non-increasing sequences
// ---------------------------------------------------------------------

#[test]
fn prop_spike_detector_counts_consistent() {
    let mut rng = Pcg64::seed(0xde7ec7);
    for _ in 0..50 {
        let mut det = SpikeDetector::new(0.2, 0.5);
        let n = 100 + rng.next_range(200) as usize;
        let mut loss = 5.0;
        let mut total = 0;
        for _ in 0..n {
            // Random walk with occasional big jumps.
            if rng.next_f64() < 0.05 {
                loss *= 1.0 + rng.next_f64() * 4.0;
            } else {
                loss *= 0.98 + rng.next_f64() * 0.04;
            }
            det.observe(loss);
            total += 1;
        }
        assert!(det.events() <= det.spiking_steps());
        assert!(det.spiking_steps() <= total);
        assert!((0.0..=1.0).contains(&det.spike_fraction()));
    }
}

#[test]
fn prop_no_spikes_on_monotone_decreasing_loss() {
    let mut rng = Pcg64::seed(0x900d);
    for _ in 0..20 {
        let mut det = SpikeDetector::new(0.3, 0.3);
        let mut loss = 10.0 * (1.0 + rng.next_f64());
        for _ in 0..300 {
            assert!(!det.observe(loss));
            loss *= 0.99 - rng.next_f64() * 0.005;
        }
        assert_eq!(det.events(), 0);
    }
}
