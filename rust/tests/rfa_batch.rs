//! Property tests for the batched PRF engine (PR acceptance criteria):
//!
//! (a) batched feature-map estimation equals the scalar `estimate()`
//!     oracle under a shared seed, to 1e-12, for all three `Sampling`
//!     modes;
//! (b) causal linear attention matches a brute-force masked-softmax
//!     reference within MC tolerance (and the prefix-sum forward matches
//!     the quadratic aggregation over the estimated gram exactly);
//! (c) the threaded variance engine is deterministic for a fixed seed and
//!     independent of the thread count.

use darkformer::linalg::Matrix;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{attention, batch, variance, FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-300)
}

fn sampling_modes(d: usize, rng: &mut Pcg64) -> Vec<(&'static str, Sampling)> {
    let psi_cov = anisotropic_covariance(d, 1.2, 0.5, rng);
    let sigma = anisotropic_covariance(d, 0.7, 0.6, rng);
    vec![
        ("isotropic", Sampling::Isotropic),
        (
            "proposal",
            Sampling::Proposal(MultivariateGaussian::new(psi_cov).unwrap()),
        ),
        (
            "data_aware",
            Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
        ),
    ]
}

// ---------------------------------------------------------------------
// (a) batched == scalar oracle, all three sampling modes, many cases
// ---------------------------------------------------------------------

#[test]
fn prop_batched_estimate_equals_scalar_oracle_all_modes() {
    let mut meta_rng = Pcg64::seed(0xbadc0de);
    for d in [2usize, 3, 5, 8] {
        for (mode, sampling) in sampling_modes(d, &mut meta_rng) {
            let est = PrfEstimator::new(d, 24, sampling);
            for case in 0..10 {
                let seed = 5000 + d as u64 * 100 + case;
                let q: Vec<f64> = meta_rng
                    .gaussian_vec(d)
                    .iter()
                    .map(|x| 0.4 * x)
                    .collect();
                let k: Vec<f64> = meta_rng
                    .gaussian_vec(d)
                    .iter()
                    .map(|x| 0.4 * x)
                    .collect();

                let mut rng_scalar = Pcg64::seed(seed);
                let scalar = est.estimate(&q, &k, &mut rng_scalar);

                let mut rng_bank = Pcg64::seed(seed);
                let bank = FeatureBank::draw(&est, &mut rng_bank);
                let batched = bank.estimate(&q, &k);

                assert!(
                    rel_err(batched, scalar) < 1e-12,
                    "{mode} d={d} case={case}: batched={batched} scalar={scalar}"
                );
                // Both paths must also have consumed the rng identically.
                assert_eq!(
                    rng_scalar.next_u64(),
                    rng_bank.next_u64(),
                    "{mode} d={d}: rng streams diverged"
                );
            }
        }
    }
}

#[test]
fn prop_gram_matches_scalar_oracle_pairwise() {
    // The whole-gram contraction agrees with the scalar oracle on every
    // (q_i, k_j) pair under a shared bank seed.
    let mut rng = Pcg64::seed(0x6ea1);
    let d = 4;
    for (mode, sampling) in sampling_modes(d, &mut rng) {
        let est = PrfEstimator::new(d, 16, sampling);
        let qs: Vec<Vec<f64>> = (0..6)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.3 * x).collect())
            .collect();
        let ks: Vec<Vec<f64>> = (0..6)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.3 * x).collect())
            .collect();
        let mut bank_rng = Pcg64::seed(777);
        let bank = FeatureBank::draw(&est, &mut bank_rng);
        let gram = bank.gram(&qs, &ks);
        for (i, q) in qs.iter().enumerate() {
            for (j, k) in ks.iter().enumerate() {
                // The bank's own per-pair path is oracle-equal (above), so
                // compare the gram against it. √w splitting and matmul
                // reassociation cost a few ulps, hence 1e-10.
                let direct = bank.estimate(q, k);
                assert!(
                    rel_err(gram[(i, j)], direct) < 1e-10,
                    "{mode}: gram[{i},{j}]={} direct={}",
                    gram[(i, j)],
                    direct
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// (b) causal linear attention vs brute-force masked softmax
// ---------------------------------------------------------------------

#[test]
fn prop_causal_linear_attention_matches_masked_softmax() {
    // Brute-force reference: out_l = Σ_{j≤l} softmax(q·k)_j · v_j,
    // computed entry by entry. PRF attention with a generous budget must
    // agree within MC tolerance.
    let mut rng = Pcg64::seed(0xa77e);
    let (l, d, dv, m) = (32, 4, 3, 2048);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let bank = FeatureBank::draw(&est, &mut rng);
    let q: Vec<Vec<f64>> = (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.25 * x).collect())
        .collect();
    let k: Vec<Vec<f64>> = (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.25 * x).collect())
        .collect();
    let v = Matrix::from_rows(
        &(0..l)
            .map(|_| rng.gaussian_vec(dv).iter().map(|x| 0.5 * x).collect())
            .collect::<Vec<Vec<f64>>>(),
    );

    // Hand-rolled masked softmax (independent of attention.rs).
    let mut reference = Matrix::zeros(l, dv);
    for i in 0..l {
        let mut weights = Vec::with_capacity(i + 1);
        for j in 0..=i {
            let dot: f64 = q[i].iter().zip(&k[j]).map(|(a, b)| a * b).sum();
            weights.push(dot.exp());
        }
        let denom: f64 = weights.iter().sum();
        for (j, w) in weights.iter().enumerate() {
            for c in 0..dv {
                reference[(i, c)] += w / denom * v[(j, c)];
            }
        }
    }

    let approx = attention::prf_attention(&bank, &q, &k, &v, true);
    let diff = approx.max_abs_diff(&reference);
    assert!(diff < 0.15, "PRF causal attention off by {diff}");

    // And the library's own exact reference agrees with the hand-rolled
    // one tightly (stable-softmax rewrite is mathematically identical).
    let exact = attention::softmax_attention(
        &Matrix::from_rows(&q),
        &Matrix::from_rows(&k),
        &v,
        true,
    );
    assert!(exact.max_abs_diff(&reference) < 1e-10);
}

#[test]
fn prop_causal_prefix_state_equals_quadratic_aggregation() {
    // Deterministic identity (no MC): the O(L·n) prefix-sum forward equals
    // brute-force aggregation over the bank's estimated kernel gram, for
    // isotropic AND data-aware banks.
    let mut rng = Pcg64::seed(0x1dea);
    let d = 5;
    for (mode, sampling) in sampling_modes(d, &mut rng) {
        let (l, dv) = (17, 4);
        let est = PrfEstimator::new(d, 32, sampling);
        let bank = FeatureBank::draw(&est, &mut rng);
        let q: Vec<Vec<f64>> = (0..l)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.3 * x).collect())
            .collect();
        let k: Vec<Vec<f64>> = (0..l)
            .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.3 * x).collect())
            .collect();
        let v = Matrix::from_rows(
            &(0..l)
                .map(|_| rng.gaussian_vec(dv))
                .collect::<Vec<Vec<f64>>>(),
        );
        let fast = attention::prf_attention(&bank, &q, &k, &v, true);
        let gram = bank.gram(&q, &k);
        let mut reference = Matrix::zeros(l, dv);
        for i in 0..l {
            let mut denom = 0.0;
            for j in 0..=i {
                denom += gram[(i, j)];
                for c in 0..dv {
                    reference[(i, c)] += gram[(i, j)] * v[(j, c)];
                }
            }
            for c in 0..dv {
                reference[(i, c)] /= denom;
            }
        }
        assert!(
            fast.max_abs_diff(&reference) < 1e-9,
            "{mode}: prefix-sum vs quadratic diff={}",
            fast.max_abs_diff(&reference)
        );
    }
}

#[test]
fn causal_linear_attention_runs_at_l2048() {
    // Acceptance smoke: the causal forward handles L=2048 and stays
    // finite and normalized (v = const ⇒ out = const).
    let mut rng = Pcg64::seed(0x2048);
    let (l, d, dv, m) = (2048, 16, 8, 32);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let bank = FeatureBank::draw(&est, &mut rng);
    let q: Vec<Vec<f64>> = (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.1 * x).collect())
        .collect();
    let k: Vec<Vec<f64>> = (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| 0.1 * x).collect())
        .collect();
    let v = Matrix::from_vec(l, dv, vec![0.5; l * dv]);
    let out = attention::prf_attention(&bank, &q, &k, &v, true);
    assert_eq!((out.rows(), out.cols()), (l, dv));
    for i in 0..l {
        for c in 0..dv {
            assert!(
                (out[(i, c)] - 0.5).abs() < 1e-9,
                "row {i}: attention must be an average of constant values"
            );
        }
    }
}

// ---------------------------------------------------------------------
// (c) threaded variance engine: deterministic, thread-count independent
// ---------------------------------------------------------------------

#[test]
fn prop_threaded_variance_deterministic_and_thread_count_independent() {
    let mut meta_rng = Pcg64::seed(0xdeed);
    let d = 6;
    for (mode, sampling) in sampling_modes(d, &mut meta_rng) {
        let est = PrfEstimator::new(d, 8, sampling);
        let lambda = Matrix::identity(d).scale(0.15);
        let dist = MultivariateGaussian::new(lambda).unwrap();
        let run = |threads: usize| {
            let mut rng = Pcg64::seed(0x5eed5);
            batch::expected_mc_variance_threaded(
                &est, &dist, 23, 500, threads, &mut rng,
            )
        };
        let v1 = run(1);
        for threads in [2usize, 3, 4, 7, 32] {
            let v = run(threads);
            assert_eq!(
                v.to_bits(),
                v1.to_bits(),
                "{mode}: threads={threads} gave {v}, single-thread {v1}"
            );
        }
        // Repeat with the same seed: bit-identical again.
        assert_eq!(run(4).to_bits(), v1.to_bits(), "{mode}: not deterministic");
        assert!(v1.is_finite() && v1 > 0.0);
    }
}

#[test]
fn prop_paired_threaded_variance_thread_count_independent() {
    let mut meta_rng = Pcg64::seed(0xfaded);
    let d = 4;
    let lambda = anisotropic_covariance(d, 0.2, 0.6, &mut meta_rng);
    let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    let iso = PrfEstimator::new(d, 8, Sampling::Isotropic);
    let dark = PrfEstimator::new(
        d,
        8,
        Sampling::DataAware(MultivariateGaussian::new(lambda).unwrap()),
    );
    let run = |threads: usize| {
        let mut rng = Pcg64::seed(0xabc);
        batch::paired_expected_mc_variance_threaded(
            &iso, &dark, &dist, 17, 400, threads, &mut rng,
        )
    };
    let (a1, b1) = run(1);
    for threads in [2usize, 5, 16] {
        let (a, b) = run(threads);
        assert_eq!(a.to_bits(), a1.to_bits());
        assert_eq!(b.to_bits(), b1.to_bits());
    }
    assert!(a1 > 0.0 && b1 > 0.0);
}

#[test]
fn batched_variance_statistically_matches_scalar_engine() {
    // Same estimand, different draw streams: the two engines must agree
    // within generous MC slack.
    let mut rng = Pcg64::seed(0x57a7);
    let d = 4;
    let lambda = Matrix::identity(d).scale(0.12);
    let dist = MultivariateGaussian::new(lambda).unwrap();
    let est = PrfEstimator::new(d, 8, Sampling::Isotropic);
    let scalar =
        variance::expected_mc_variance(&est, &dist, 80, 2000, &mut rng);
    let batched = batch::expected_mc_variance_batched(
        &est, &dist, 80, 2000, &mut rng,
    );
    let ratio = scalar / batched;
    // Across-pair Var[Z] variation is heavy-tailed and the engines sample
    // different pairs, so the bound is deliberately loose — this guards
    // against estimand mix-ups (m-scaling, normalizer bugs), not noise.
    assert!(
        (0.1..10.0).contains(&ratio),
        "engines disagree: scalar={scalar} batched={batched}"
    );
}

#[test]
fn theorem_3_2_holds_under_batched_engine() {
    // The paired batched engine reproduces the paper's ordering: the
    // optimal proposal strictly reduces variance under anisotropy.
    let mut rng = Pcg64::seed(0x0311);
    let d = 4;
    let lambda = anisotropic_covariance(d, 0.2, 0.8, &mut rng);
    let dist = MultivariateGaussian::new(lambda.clone()).unwrap();
    let psi = MultivariateGaussian::new(
        darkformer::rfa::optimal_proposal(&lambda).unwrap(),
    )
    .unwrap();
    let iso = PrfEstimator::new(d, 16, Sampling::Isotropic);
    let opt = PrfEstimator::new(d, 16, Sampling::Proposal(psi));
    let (v_iso, v_opt) = batch::paired_expected_mc_variance_batched(
        &iso, &opt, &dist, 60, 3000, &mut rng,
    );
    assert!(
        v_opt < v_iso,
        "optimal proposal should reduce variance: iso={v_iso} opt={v_opt}"
    );
}
