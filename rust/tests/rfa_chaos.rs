//! Chaos harness for `rfa::serve`: scripted and seeded fault schedules
//! against the full serving stack, swept over worker thread counts and
//! precisions, pinning the three robustness properties of the failure
//! semantics contract (see the `rfa/serve` module docs):
//!
//! 1. **No request is ever lost.** Every submitted request ends as a
//!    completed response or a typed `FailedStep` — under every
//!    schedule, on every path.
//! 2. **Quarantine is schedule-deterministic.** For a fixed fault
//!    schedule, the quarantined-session set, the abandoned-request
//!    count and the fired-fault log are identical across worker thread
//!    counts.
//! 3. **Post-heal recovery is bitwise.** After healing the store,
//!    repairing corrupt-write damage, unquarantining and resubmitting
//!    the abandoned requests in seq order, each session's reassembled
//!    output stream is bitwise identical to a never-faulted serial
//!    reference.
//!
//! Alongside the sweep, targeted tests pin the degraded-mode admission
//! control, orphaned-unlink accounting, quarantine submit gating, and
//! the never-a-torn-final-file guarantee of crash-safe snapshot writes.
//!
//! Set `RFA_CHAOS_RESAMPLE=aggressive` to run every schedule with an
//! aggressive online-resampling + frozen-epoch-compaction config (tiny
//! epochs, window-1-adjacent compaction), so fault injection also
//! covers the epoch state machine: the maintained Cholesky factor, the
//! frozen-epoch ring and the merge counter all ride through eviction,
//! fault-in, quarantine and replay. Under that knob the bitwise
//! reference is a clean (never-faulted, single-threaded) pool run —
//! the engine-built serial reference has no epoch boundaries and is
//! not a valid oracle for a resampling session.

use std::path::PathBuf;

use darkformer::checkpoint::{staging_path, Checkpoint};
use darkformer::linalg::Matrix;
use darkformer::rfa::engine::{
    draw_head_banks, multi_head_causal_attention,
    multi_head_causal_attention32, EngineConfig, Head,
};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::serve::{
    BatchScheduler, CompactionConfig, DrainOutcome, Fault, FaultHandle,
    FaultRule, FaultyStore, FsStore, Precision, ResampleConfig, RetryPolicy,
    SeededFaults, ServeConfig, SessionPool, StepRequest, StepResponse,
    StoreOp,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 4;
const M: usize = 16;
const N_HEADS: usize = 2;
const DV: usize = 3;
const CHUNK: usize = 8;
const N_REQUESTS: usize = 4;
const L: usize = CHUNK * N_REQUESTS;

/// Session seeds for the three simulated users of every chaos run.
const SESSION_SEEDS: [u64; 3] = [101, 202, 303];

fn iso_est() -> PrfEstimator {
    PrfEstimator::new(D, M, Sampling::Isotropic)
}

/// Fresh per-test snapshot directory (tests run concurrently in one
/// process; stale files from an earlier run must not leak in).
fn snapshot_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rfa_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The `RFA_CHAOS_RESAMPLE` knob: `aggressive` turns on tiny-epoch
/// online resampling with window-1-adjacent frozen-epoch compaction, so
/// every chaos schedule exercises the epoch state machine (maintained
/// factor, frozen ring, merge counter) through eviction/fault-in/replay.
/// Epoch length 5 is deliberately coprime to the chunk size 8: epoch
/// boundaries land mid-request, so snapshot/restore crosses them.
fn chaos_resample() -> Option<ResampleConfig> {
    match std::env::var("RFA_CHAOS_RESAMPLE").as_deref() {
        Ok("aggressive") => Some(ResampleConfig {
            epoch_positions: 5,
            max_epochs: 3,
            shrinkage: 0.05,
            compaction: Some(CompactionConfig {
                window: 2,
                probes: 24,
                ridge: 1e-6,
            }),
        }),
        _ => None,
    }
}

fn cfg(
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    dir: PathBuf,
) -> ServeConfig {
    ServeConfig {
        est: iso_est(),
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: dir,
        resample: chaos_resample(),
    }
}

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

/// The full L-position stream for one simulated user, one entry per head.
fn stream_inputs(input_seed: u64) -> Vec<Head> {
    let mut rng = Pcg64::seed(input_seed);
    (0..N_HEADS)
        .map(|_| Head {
            q: rows(L, D, 0.3, &mut rng),
            k: rows(L, D, 0.3, &mut rng),
            v: Matrix::from_rows(&rows(L, DV, 1.0, &mut rng)),
        })
        .collect()
}

/// Rows `[b, e)` of every head — one streaming request segment.
fn slice_heads(heads: &[Head], b: usize, e: usize) -> Vec<Head> {
    heads
        .iter()
        .map(|h| Head {
            q: h.q[b..e].to_vec(),
            k: h.k[b..e].to_vec(),
            v: h.v.row_block(b, e),
        })
        .collect()
}

/// Serial single-tenant reference: same bank seeding as the pool, one
/// monolithic multi-head forward over the whole stream, widened to f64
/// (widening is exact, so f64 equality is bitwise equality).
fn serial_reference(
    bank_seed: u64,
    heads: &[Head],
    precision: Precision,
) -> Vec<Matrix> {
    let banks =
        draw_head_banks(&iso_est(), N_HEADS, &mut Pcg64::seed(bank_seed));
    let cfg = EngineConfig { chunk: CHUNK, threads: 1 };
    match precision {
        Precision::F64 => multi_head_causal_attention(&banks, heads, &cfg),
        Precision::F32 => {
            multi_head_causal_attention32(&banks, heads, &cfg)
                .into_iter()
                .map(|m| m.to_f64())
                .collect()
        }
    }
}

/// Reassemble drained responses into per-session, per-head output
/// matrices in stream order, asserting in-order application.
fn reassemble_streams(
    mut responses: Vec<StepResponse>,
    ids: &[u64],
) -> Vec<Vec<Matrix>> {
    responses.sort_by_key(|r| r.seq);
    let mut per_session: Vec<Vec<Vec<f64>>> =
        vec![vec![Vec::new(); N_HEADS]; ids.len()];
    let mut next_pos: Vec<u64> = vec![0; ids.len()];
    for resp in &responses {
        let s = ids.iter().position(|id| *id == resp.session_id).unwrap();
        assert_eq!(
            resp.start_position, next_pos[s],
            "session {} saw out-of-order application",
            resp.session_id
        );
        next_pos[s] += resp.outputs[0].rows() as u64;
        for (h, out) in resp.outputs.iter().enumerate() {
            per_session[s][h].extend_from_slice(out.to_f64().data());
        }
    }
    per_session
        .into_iter()
        .map(|heads| {
            heads
                .into_iter()
                .map(|data| Matrix::from_vec(L, DV, data))
                .collect()
        })
        .collect()
}

/// Resident bytes of one fresh session at `precision` — the probe every
/// chaos pool sizes its one-session budget with (a tight budget keeps
/// eviction/fault-in churn, and therefore store traffic, constant).
fn one_session_bytes(precision: Precision, tag: &str) -> usize {
    let dir = snapshot_dir(tag);
    let mut pool = SessionPool::new(cfg(precision, 1, 0, dir));
    let id = pool.create_session(1).unwrap();
    pool.session_mut(id).unwrap().state_bytes()
}

/// Tight retry windows so chaos runs quarantine (and terminate) fast.
fn tight_policy() -> RetryPolicy {
    RetryPolicy {
        quarantine_persistent: 2,
        quarantine_any: 6,
        backoff_base: 1,
        backoff_cap: 2,
    }
}

/// The fired-fault log with pool-unique path prefixes stripped (each run
/// uses its own pool tag and snapshot dir), leaving only the
/// schedule-relevant identity: op index, op, fault kind, which session.
fn normalize_fired(handle: &FaultHandle) -> Vec<(u64, StoreOp, Fault, String)> {
    handle
        .fired()
        .iter()
        .map(|f| {
            let name = f.path.file_name().unwrap().to_string_lossy();
            let target = name
                .split_once("-session-")
                .map(|(_, s)| format!("session-{s}"))
                .unwrap_or_else(|| "probe".to_string());
            (f.op_index, f.op, f.fault, target)
        })
        .collect()
}

/// Everything one faulted run produced, for cross-run determinism and
/// bitwise-recovery assertions.
struct ChaosRun {
    /// Per-session, per-head output rows, reassembled post-heal.
    streams: Vec<Vec<Matrix>>,
    /// Sessions quarantined during the faulted drain, ascending.
    quarantined: Vec<u64>,
    /// Normalized fired-fault log (see [`normalize_fired`]).
    fired: Vec<(u64, StoreOp, Fault, String)>,
    /// Requests abandoned to quarantine during the faulted drain.
    abandoned: usize,
}

/// Drive the full three-session workload through a faulty store, then
/// heal, repair, unquarantine, resubmit the abandoned requests in seq
/// order and drain to completion. Asserts the no-loss property and the
/// no-torn-snapshot property inline; returns the rest for the caller.
fn run_chaos(
    precision: Precision,
    threads: usize,
    rules: Vec<FaultRule>,
    seeded: Option<SeededFaults>,
    tag: &str,
) -> ChaosRun {
    let budget = one_session_bytes(precision, &format!("{tag}_probe"));
    let dir = snapshot_dir(tag);
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_store(
        cfg(precision, threads, budget, dir.clone()),
        Box::new(store),
    );
    let ids: Vec<u64> = SESSION_SEEDS
        .iter()
        .map(|s| pool.create_session(*s).unwrap())
        .collect();
    // Sessions exist (the budget already evicted two); only now arm the
    // schedule, so the scripted op counts start at the workload's start.
    handle.script(rules);
    handle.set_seeded(seeded);
    let mut sched = BatchScheduler::with_policy(pool, tight_policy());
    let streams: Vec<Vec<Head>> =
        (0..ids.len() as u64).map(|s| stream_inputs(7000 + s)).collect();
    let mut submitted = 0usize;
    for r in 0..N_REQUESTS {
        for (id, stream) in ids.iter().zip(&streams) {
            let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
            sched.submit(StepRequest { session_id: *id, heads }).unwrap();
            submitted += 1;
        }
    }
    let DrainOutcome { mut responses, mut failures, error } =
        sched.run_until_idle();
    assert!(
        error.is_none(),
        "schedule {tag}: drain must quarantine, not stall: {error:?}"
    );
    // Property 1: nothing lost — every submitted request either
    // completed or surfaced as a typed failure.
    assert_eq!(
        responses.len() + failures.len(),
        submitted,
        "schedule {tag} lost requests"
    );
    let quarantined = sched.quarantined_sessions();
    assert_eq!(
        quarantined.is_empty(),
        failures.is_empty(),
        "schedule {tag}: failed steps and quarantine appear together"
    );
    let abandoned = failures.len();

    // Heal the store, repair corrupt-write damage, release quarantined
    // sessions and replay their abandoned requests in seq order.
    handle.heal();
    handle.set_seeded(None);
    handle.repair();
    for &id in &quarantined {
        sched.unquarantine(id).unwrap();
    }
    failures.sort_by_key(|f| f.seq);
    for f in failures {
        sched.submit(f.request).unwrap();
    }
    responses.extend(sched.run_until_idle().into_result().unwrap());
    assert_eq!(responses.len(), submitted, "schedule {tag}: replay lost work");
    assert!(sched.quarantined_sessions().is_empty());

    // Atomic-write guarantee: whatever the schedule injected, no final
    // snapshot path ever holds a torn file — every *.dkft parses and
    // passes its CRC (torn-write artifacts only ever live at *.tmp).
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|e| e == "dkft") {
            Checkpoint::load(&path).unwrap_or_else(|e| {
                panic!("torn snapshot at {}: {e:#}", path.display())
            });
        }
    }

    ChaosRun {
        streams: reassemble_streams(responses, &ids),
        quarantined,
        fired: normalize_fired(&handle),
        abandoned,
    }
}

/// The scripted schedules the sweep runs: transient blips, a
/// path-targeted persistent outage, a write outage (ENOSPC then a torn
/// crash), a silent corruption, and a seeded mixed background stream.
fn schedules() -> Vec<(&'static str, Vec<FaultRule>, Option<SeededFaults>)> {
    vec![
        (
            "transient_reads",
            vec![FaultRule::on(StoreOp::Read, Fault::Transient).fires(5)],
            None,
        ),
        (
            "persistent_read_s1",
            vec![FaultRule::on(StoreOp::Read, Fault::Persistent)
                .on_path("session-1.dkft")],
            None,
        ),
        (
            "write_outage",
            vec![
                FaultRule::on(StoreOp::Write, Fault::Enospc).fires(3),
                FaultRule::on(StoreOp::Write, Fault::TornWrite)
                    .skip(3)
                    .fires(1),
            ],
            None,
        ),
        (
            "corrupt_first_evict",
            vec![FaultRule::on(StoreOp::Write, Fault::CorruptWrite).fires(1)],
            None,
        ),
        (
            "seeded_mixed",
            Vec::new(),
            Some(SeededFaults {
                seed: 0xC0FFEE,
                fault_every: 3,
                transient_only: false,
            }),
        ),
    ]
}

/// The sweep: every schedule × both precisions × worker threads {1, 4}.
/// Pins properties 1–3 of the module contract in one pass.
#[test]
fn chaos_sweep_no_loss_deterministic_and_bitwise_after_heal() {
    for &precision in &[Precision::F64, Precision::F32] {
        let ptag = match precision {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        };
        // The bitwise oracle. Without the resample knob the engine-built
        // serial reference applies; with it, epoch boundaries redraw the
        // banks mid-stream, so the oracle is a clean never-faulted pool
        // run (single-threaded — the contract makes thread count, faults
        // and eviction all invisible to the output bits).
        let expected: Vec<Vec<Matrix>> = if chaos_resample().is_some() {
            run_chaos(precision, 1, Vec::new(), None, &format!("ref_{ptag}"))
                .streams
        } else {
            SESSION_SEEDS
                .iter()
                .enumerate()
                .map(|(s, seed)| {
                    serial_reference(
                        *seed,
                        &stream_inputs(7000 + s as u64),
                        precision,
                    )
                })
                .collect()
        };
        for (name, rules, seeded) in schedules() {
            let runs: Vec<ChaosRun> = [1usize, 4]
                .iter()
                .map(|&threads| {
                    run_chaos(
                        precision,
                        threads,
                        rules.clone(),
                        seeded,
                        &format!("{name}_{ptag}_t{threads}"),
                    )
                })
                .collect();
            // Property 2: for a fixed schedule, the quarantine set, the
            // abandoned count and the fired-fault log are pure functions
            // of the schedule — the worker count must not show through.
            assert_eq!(
                runs[0].quarantined, runs[1].quarantined,
                "schedule {name}/{ptag}: quarantine set depends on threads"
            );
            assert_eq!(
                runs[0].abandoned, runs[1].abandoned,
                "schedule {name}/{ptag}: abandoned count depends on threads"
            );
            assert_eq!(
                runs[0].fired, runs[1].fired,
                "schedule {name}/{ptag}: fired-fault log depends on threads"
            );
            // Property 3: post-heal, every session's reassembled stream
            // is bitwise the never-faulted serial reference.
            for (t, run) in runs.iter().enumerate() {
                for (s, heads) in run.streams.iter().enumerate() {
                    for (h, out) in heads.iter().enumerate() {
                        assert_eq!(
                            out.data(),
                            expected[s][h].data(),
                            "schedule {name}/{ptag} threads run {t}: \
                             session {s} head {h} diverged after heal"
                        );
                    }
                }
            }
        }
    }
}

/// A quarantined session rejects new submits, surfaces its backlog as
/// typed failures, and replays in order after `unquarantine`.
#[test]
fn quarantine_blocks_submits_until_unquarantined() {
    let budget = one_session_bytes(Precision::F64, "qsubmit_probe");
    let dir = snapshot_dir("qsubmit");
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_store(
        cfg(Precision::F64, 1, budget, dir),
        Box::new(store),
    );
    let s0 = pool.create_session(11).unwrap();
    let s1 = pool.create_session(22).unwrap(); // evicts s0
    handle.script(vec![FaultRule::on(StoreOp::Read, Fault::Persistent)
        .on_path("session-0.dkft")]);
    let policy =
        RetryPolicy { quarantine_persistent: 1, ..RetryPolicy::default() };
    let mut sched = BatchScheduler::with_policy(pool, policy);
    let streams = [stream_inputs(8100), stream_inputs(8200)];
    for (id, stream) in [s0, s1].iter().zip(&streams) {
        for r in 0..2 {
            let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
            sched.submit(StepRequest { session_id: *id, heads }).unwrap();
        }
    }
    let outcome = sched.run_until_idle();
    assert!(outcome.error.is_none());
    assert!(!outcome.is_clean());
    assert_eq!(sched.quarantined_sessions(), vec![s0]);
    assert!(sched.is_quarantined(s0));
    // Isolation: every healthy request still completed.
    assert_eq!(outcome.responses.len(), 2);
    assert!(outcome.responses.iter().all(|r| r.session_id == s1));
    assert_eq!(outcome.failures.len(), 2);
    assert!(outcome.failures.iter().all(|f| f.session_id == s0));
    assert!(
        outcome.failures[0].error.contains("quarantined"),
        "got: {}",
        outcome.failures[0].error
    );
    // Submits to a quarantined session are rejected with the story.
    let heads = slice_heads(&streams[0], 0, CHUNK);
    let err =
        sched.submit(StepRequest { session_id: s0, heads }).unwrap_err();
    assert!(format!("{err:#}").contains("quarantined"), "got {err:#}");
    assert_eq!(sched.health().quarantined, 1);
    // Unquarantining a healthy session is an error, not a no-op.
    assert!(sched.unquarantine(s1).is_err());
    // Heal + unquarantine: the abandoned requests replay in seq order,
    // resuming the stream exactly where it never started.
    handle.heal();
    sched.unquarantine(s0).unwrap();
    assert_eq!(sched.health().quarantined, 0);
    let mut failures = outcome.failures;
    failures.sort_by_key(|f| f.seq);
    for f in failures {
        sched.submit(f.request).unwrap();
    }
    let mut replay = sched.run_until_idle().into_result().unwrap();
    assert_eq!(replay.len(), 2);
    assert!(replay.iter().all(|r| r.session_id == s0));
    replay.sort_by_key(|r| r.seq);
    assert_eq!(replay[0].start_position, 0);
    assert_eq!(replay[1].start_position, CHUNK as u64);
}

/// Degraded mode: a failed eviction write rolls back the admit and trips
/// degraded mode; while degraded and at budget, admission control
/// rejects without touching the store; a heal probe clears it.
#[test]
fn degraded_pool_applies_admission_control_and_heals() {
    let budget = one_session_bytes(Precision::F64, "admission_probe");
    let dir = snapshot_dir("admission");
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_store(
        cfg(Precision::F64, 1, budget, dir),
        Box::new(store),
    );
    let s0 = pool.create_session(1).unwrap();
    handle.script(vec![FaultRule::on(StoreOp::Write, Fault::Enospc)]);
    // Admitting a second session needs an eviction write, which fails:
    // the admit rolls back whole and the pool enters degraded mode.
    let err = pool.create_session(2).unwrap_err();
    assert!(format!("{err:#}").contains("evicting session"), "got {err:#}");
    assert!(pool.is_degraded());
    assert_eq!(pool.resident_count(), 1);
    // While degraded at budget, admission is rejected outright — no
    // further doomed writes are even attempted.
    let ops_before = handle.ops();
    let err = pool.create_session(3).unwrap_err();
    assert!(
        format!("{err:#}").contains("admission control"),
        "got {err:#}"
    );
    assert_eq!(
        handle.ops(),
        ops_before,
        "a degraded admit must not touch the store"
    );
    let health = pool.health();
    assert!(health.degraded);
    assert!(health.snapshot_failures >= 1);
    assert_eq!(health.orphaned_snapshots, 0);
    // Residents keep serving while degraded.
    pool.session_mut(s0).unwrap();
    // Heal the media; the probe write in try_heal clears degraded mode.
    handle.heal();
    pool.try_heal().unwrap();
    assert!(!pool.is_degraded());
    // Admission works again, and the eviction write now succeeds.
    let s2 = pool.create_session(4).unwrap();
    assert!(pool.contains(s0) && pool.contains(s2));
    assert_eq!(pool.resident_count(), 1);
    assert_eq!(pool.evicted_count(), 1);
}

/// A failed snapshot unlink is recorded as an orphan (visible in the
/// health report) and drained by the next heal — never silently leaked.
#[test]
fn orphaned_unlinks_are_retried_and_reported() {
    let budget = one_session_bytes(Precision::F64, "orphan_probe");
    let dir = snapshot_dir("orphan");
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_store(
        cfg(Precision::F64, 1, budget, dir),
        Box::new(store),
    );
    let s0 = pool.create_session(1).unwrap();
    let _s1 = pool.create_session(2).unwrap(); // evicts s0
    let snap0 = pool.snapshot_path(s0);
    assert!(snap0.exists());
    // Every unlink fails: faulting s0 back in restores fine but cannot
    // consume the snapshot file — it must be recorded, not leaked.
    handle.script(vec![FaultRule::on(StoreOp::Remove, Fault::Persistent)]);
    pool.session_mut(s0).unwrap();
    assert!(snap0.exists(), "the injected unlink failure left the file");
    assert_eq!(pool.health().orphaned_snapshots, 1);
    assert!(pool.health().snapshot_failures >= 1);
    // Heal; the next heal pass drains the orphan list.
    handle.heal();
    pool.try_heal().unwrap();
    assert_eq!(pool.health().orphaned_snapshots, 0);
    assert!(!snap0.exists(), "a healed orphan must finally be unlinked");
}

/// The injected mid-write crash leaves only a staging file: the final
/// path is never torn, the session survives resident, and a later
/// healthy write replaces the staging leftovers atomically.
#[test]
fn torn_write_crash_keeps_the_final_path_clean() {
    let budget = one_session_bytes(Precision::F64, "torn_probe");
    let dir = snapshot_dir("torn");
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_store(
        cfg(Precision::F64, 1, budget, dir),
        Box::new(store),
    );
    let s0 = pool.create_session(1).unwrap();
    let _s1 = pool.create_session(2).unwrap(); // evicts s0
    let snap0 = pool.snapshot_path(s0);
    // Fault s0 back in (consumes its snapshot, evicts s1 for budget).
    pool.session_mut(s0).unwrap();
    assert!(!snap0.exists());
    handle
        .script(vec![FaultRule::on(StoreOp::Write, Fault::TornWrite).fires(1)]);
    let err = pool.evict(s0).unwrap_err();
    assert!(format!("{err:#}").contains("torn staging"), "got {err:#}");
    let staging = staging_path(&snap0);
    assert!(staging.exists(), "the injected crash leaves a staging file");
    assert!(!snap0.exists(), "a torn write must never touch the final path");
    assert!(pool.is_degraded());
    assert_eq!(
        pool.resident_count(),
        1,
        "a failed evict must keep the session resident"
    );
    // The rule is exhausted; a heal pass probes the store and recovers.
    pool.try_heal().unwrap();
    assert!(!pool.is_degraded());
    pool.evict(s0).unwrap();
    assert!(snap0.exists());
    assert!(!staging.exists(), "a completed write consumes the staging file");
    Checkpoint::load(&snap0).unwrap();
    // And the snapshot round-trips: the session faults back in.
    pool.session_mut(s0).unwrap();
}
