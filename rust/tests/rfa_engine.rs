//! Property tests for the chunked multi-head attention engine (PR
//! acceptance criteria):
//!
//! (a) the chunk-blocked causal forward matches the per-position
//!     `causal_linear_attention` reference (shared bank, shared seed) for
//!     chunk sizes {1, 7, 64, L}, for isotropic AND data-aware banks;
//! (b) the f32 hot path agrees with the f64 path at L=512 within the
//!     documented tolerance (see the `rfa::engine` module docs for the
//!     f32-accumulation policy the tolerance rests on);
//! (c) the multi-head engine is deterministic, thread-count independent,
//!     and equal to running each head alone;
//! (d) the lower-triangle causal softmax reference is unchanged by the
//!     dead-upper-triangle skip.

use darkformer::linalg::{Matrix, Matrix32};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{attention, engine, FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

// ---------------------------------------------------------------------
// (a) chunked == per-position reference across chunk sizes
// ---------------------------------------------------------------------

#[test]
fn prop_chunked_causal_matches_per_position_all_chunk_sizes() {
    let mut rng = Pcg64::seed(0xc0ffee);
    let d = 5;
    let sigma = anisotropic_covariance(d, 0.7, 0.5, &mut rng);
    let modes = [
        ("isotropic", Sampling::Isotropic),
        (
            "data_aware",
            Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
        ),
    ];
    for (mode, sampling) in modes {
        let (l, dv, m) = (96usize, 4, 32);
        let est = PrfEstimator::new(d, m, sampling);
        // Shared bank, shared seed: both paths see identical features.
        let bank = FeatureBank::draw(&est, &mut Pcg64::seed(0x5eed));
        let q = rows(l, d, 0.3, &mut rng);
        let k = rows(l, d, 0.3, &mut rng);
        let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
        let phi_q = bank.feature_matrix(&q);
        let phi_k = bank.feature_matrix(&k);
        let reference =
            attention::causal_linear_attention(&phi_q, &phi_k, &v);
        for chunk in [1usize, 7, 64, l] {
            let blocked = engine::chunked_causal_linear_attention(
                &phi_q, &phi_k, &v, chunk,
            );
            // Same dense contractions in a different association order:
            // agreement to fp noise, far below any statistical scale.
            assert!(
                blocked.max_abs_diff(&reference) < 1e-12,
                "{mode} chunk={chunk}: diff={}",
                blocked.max_abs_diff(&reference)
            );
        }
    }
}

#[test]
fn prop_chunk_size_invariance() {
    // Any two chunkings agree with each other (not just with the
    // reference), including sizes that do not divide L.
    let mut rng = Pcg64::seed(0xb10c);
    let (l, d, dv, m) = (61usize, 4, 3, 24);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let bank = FeatureBank::draw(&est, &mut rng);
    let phi_q = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
    let phi_k = bank.feature_matrix(&rows(l, d, 0.3, &mut rng));
    let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
    let base = engine::chunked_causal_linear_attention(&phi_q, &phi_k, &v, 8);
    for chunk in [2usize, 13, 60, 61, 200] {
        let other = engine::chunked_causal_linear_attention(
            &phi_q, &phi_k, &v, chunk,
        );
        assert!(
            other.max_abs_diff(&base) < 1e-12,
            "chunk={chunk} diverged: {}",
            other.max_abs_diff(&base)
        );
    }
}

// ---------------------------------------------------------------------
// (b) f32 path vs f64 at L=512
// ---------------------------------------------------------------------

#[test]
fn prop_f32_engine_matches_f64_at_l512() {
    // Documented tolerance: with f32 chunk-local compute and f64 running
    // accumulators (engine module docs), per-entry error is dominated by
    // the f32 grams/readouts — O(√(n)·ε₃₂) relative on O(1) outputs.
    // 1e-3 absolute gives ~20× slack over the ~5e-5 typically observed.
    const TOL_F32_VS_F64: f64 = 1e-3;
    let mut rng = Pcg64::seed(0xf32f64);
    let (l, d, dv, m) = (512usize, 8, 8, 64);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let bank = FeatureBank::draw(&est, &mut rng);
    let q = rows(l, d, 0.2, &mut rng);
    let k = rows(l, d, 0.2, &mut rng);
    let v = Matrix::from_rows(&rows(l, dv, 1.0, &mut rng));
    let cfg = engine::EngineConfig { chunk: 32, threads: 1 };
    let out64 = engine::prf_attention_chunked(&bank, &q, &k, &v, &cfg);
    let out32 = engine::prf_attention_chunked32(
        &bank,
        &q,
        &k,
        &Matrix32::from_f64(&v),
        &cfg,
    );
    let diff = out64.max_abs_diff(&out32.to_f64());
    assert!(
        diff < TOL_F32_VS_F64,
        "f32 path drifted from f64 at L=512: {diff}"
    );
}

// ---------------------------------------------------------------------
// (c) multi-head: deterministic, thread-count independent, == per-head
// ---------------------------------------------------------------------

#[test]
fn prop_multi_head_thread_count_independent_and_head_local() {
    let mut rng = Pcg64::seed(0x8ead);
    let (n_heads, l, d, dv, m) = (5usize, 40, 4, 3, 16);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let banks = engine::draw_head_banks(&est, n_heads, &mut Pcg64::seed(42));
    let heads: Vec<engine::Head> = (0..n_heads)
        .map(|_| engine::Head {
            q: rows(l, d, 0.3, &mut rng),
            k: rows(l, d, 0.3, &mut rng),
            v: Matrix::from_rows(&rows(l, dv, 1.0, &mut rng)),
        })
        .collect();
    let run = |threads: usize| {
        let cfg = engine::EngineConfig { chunk: 8, threads };
        engine::multi_head_causal_attention(&banks, &heads, &cfg)
    };
    let single = run(1);
    assert_eq!(single.len(), n_heads);
    for threads in [2usize, 3, 7, 16] {
        let multi = run(threads);
        for (h, (a, b)) in single.iter().zip(&multi).enumerate() {
            assert_eq!(a, b, "head {h} differs at threads={threads}");
        }
    }
    // Each head equals its standalone single-head forward.
    let cfg = engine::EngineConfig { chunk: 8, threads: 1 };
    for (h, head) in heads.iter().enumerate() {
        let solo = engine::prf_attention_chunked(
            &banks[h], &head.q, &head.k, &head.v, &cfg,
        );
        assert_eq!(single[h], solo, "head {h}: multi-head != standalone");
    }
    // f32 multi-head: same thread-count independence (bitwise).
    let run32 = |threads: usize| {
        let cfg = engine::EngineConfig { chunk: 8, threads };
        engine::multi_head_causal_attention32(&banks, &heads, &cfg)
    };
    let single32 = run32(1);
    let multi32 = run32(4);
    for (h, (a, b)) in single32.iter().zip(&multi32).enumerate() {
        assert_eq!(a, b, "f32 head {h} differs across thread counts");
    }
}

// ---------------------------------------------------------------------
// (d) causal softmax reference: triangle skip changes nothing
// ---------------------------------------------------------------------

#[test]
fn prop_causal_softmax_reference_values_unchanged() {
    // The lower-triangle-only causal path must reproduce the full-gram
    // masked computation exactly (scores come from the same dot kernel).
    let mut rng = Pcg64::seed(0x7121);
    let (lq, lk, d, dv) = (19usize, 19, 5, 4);
    let q = Matrix::from_rows(&rows(lq, d, 0.4, &mut rng));
    let k = Matrix::from_rows(&rows(lk, d, 0.4, &mut rng));
    let v = Matrix::from_rows(&rows(lk, dv, 1.0, &mut rng));
    let fast = attention::softmax_attention(&q, &k, &v, true);
    // Full-gram reference, masked after the fact.
    let scores = q.matmul_transb(&k);
    let mut reference = Matrix::zeros(lq, dv);
    for i in 0..lq {
        let limit = (i + 1).min(lk);
        let mut max = f64::NEG_INFINITY;
        for j in 0..limit {
            max = max.max(scores[(i, j)]);
        }
        let mut denom = 0.0;
        for j in 0..limit {
            let w = (scores[(i, j)] - max).exp();
            denom += w;
            for c in 0..dv {
                reference[(i, c)] += w * v[(j, c)];
            }
        }
        for c in 0..dv {
            reference[(i, c)] /= denom;
        }
    }
    assert_eq!(fast, reference, "triangle skip altered the causal baseline");
}

#[test]
fn chunked_engine_streams_long_sequences() {
    // Streaming smoke at L=8192: 512-row segments fed through one
    // CausalState (sub-chunked at 64 internally). Constant values must
    // come back exactly constant, which exercises the full state-fold +
    // normalization path at length.
    let mut rng = Pcg64::seed(0x10ae);
    let (l, d, dv, m, segment) = (8192usize, 8, 4, 16, 512);
    let est = PrfEstimator::new(d, m, Sampling::Isotropic);
    let bank = FeatureBank::draw(&est, &mut rng);
    let mut state = engine::CausalState::new(m, dv);
    let mut rows_done = 0;
    while rows_done < l {
        let e = (rows_done + segment).min(l);
        let c = e - rows_done;
        let q = rows(c, d, 0.1, &mut rng);
        let k = rows(c, d, 0.1, &mut rng);
        let v = Matrix::from_vec(c, dv, vec![0.5; c * dv]);
        let phi_q = bank.feature_matrix(&q);
        let phi_k = bank.feature_matrix(&k);
        let out = state.forward(&phi_q, &phi_k, &v, 64);
        for r in 0..c {
            for x in out.row(r) {
                assert!(
                    (x - 0.5).abs() < 1e-9,
                    "row {} drifted: {x}",
                    rows_done + r
                );
            }
        }
        rows_done = e;
    }
}
