//! Golden-value regression suite for the generic `Scalar` refactor: the
//! generic `Mat<f64>` path must reproduce the pre-refactor f64 stack
//! bitwise, and the generic `Mat<f32>` path must reproduce the
//! pre-refactor `CausalState32` semantics bitwise (including the
//! once-per-chunk f32 state rounding).
//!
//! The golden values are **frozen transliterations of the pre-refactor
//! implementations**, carried verbatim in this file rather than as
//! captured literals (the refactoring environment had no Rust toolchain
//! to execute the pre-refactor build; a transliterated reference is the
//! same pin, and it stays meaningful for every future input). The frozen
//! code deliberately avoids the crate's linalg kernels:
//!
//! * `dot4`/`dot8` are byte-for-byte copies of the pre-refactor
//!   `dot_unrolled`/`dot32` unrolled kernels (their accumulator split is
//!   part of the bit pattern);
//! * dense contractions use naive ascending-index loops, which the
//!   pre-refactor tiled kernels documented (and tested) as
//!   bitwise-identical — per output element the accumulation order is
//!   the same ascending `k`;
//! * the f64 and f32 forward bodies below are line-by-line
//!   transliterations of the two (now deleted) duplicated
//!   `forward_chunk` bodies and the two `feature_matrix{,32}` bodies,
//!   association order included (e.g. the `z` fold adds the *completed*
//!   chunk column-sum, never per-row increments).
//!
//! Pinned at L=512 for chunk ∈ {1, 7, 64} and heads ∈ {1, 4}, exactly
//! the acceptance grid of the refactor issue, for isotropic and
//! data-aware banks — and under **both dispatch modes** (forced-scalar
//! fallback and the detected SIMD ISA), which is the end-to-end half of
//! the `linalg::simd` bitwise contract (the kernel-level half lives in
//! `linalg_simd.rs`).

use std::sync::{Mutex, OnceLock};

use darkformer::linalg::simd::{self, Isa};
use darkformer::rfa::engine::{
    draw_head_banks, multi_head_causal_attention,
    multi_head_causal_attention32, EngineConfig, Head,
};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{anisotropic_covariance, MultivariateGaussian};
use darkformer::rfa::{FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

const L: usize = 512;
const D: usize = 8;
const DV: usize = 4;
const M: usize = 32;
const BANK_SEED: u64 = 0x601d;
const INPUT_SEED: u64 = 0x5eed;

// ---------------------------------------------------------------------
// Frozen kernels (pre-refactor `dot_unrolled` / `dot32`, verbatim)
// ---------------------------------------------------------------------

fn dot4(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

fn dot8(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for (a, (&x, &y)) in acc.iter_mut().zip(xa.iter().zip(xb)) {
            *a += x * y;
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
        + tail
}

// ---------------------------------------------------------------------
// Frozen pre-refactor feature maps
// ---------------------------------------------------------------------

/// Pre-refactor `FeatureBank::normalizer` (unchanged by the refactor but
/// transliterated anyway so the frozen path shares nothing with the
/// crate's compute code).
fn frozen_normalizer(bank: &FeatureBank, x: &[f64]) -> f64 {
    match bank.norm_sigma() {
        Some(sigma) => {
            let sx: Vec<f64> = (0..sigma.rows())
                .map(|r| {
                    sigma.row(r).iter().zip(x).map(|(a, b)| a * b).sum()
                })
                .collect();
            0.5 * x.iter().zip(&sx).map(|(a, b)| a * b).sum::<f64>()
        }
        None => 0.5 * x.iter().map(|a| a * a).sum::<f64>(),
    }
}

/// Pre-refactor `feature_matrix`: `X·Ωᵀ` row dots (dot4), f64 exp, √w
/// scaling. Returns a flat row-major `l×n` buffer.
fn frozen_feature_matrix64(bank: &FeatureBank, xs: &[Vec<f64>]) -> Vec<f64> {
    let n = bank.n_features();
    let sqrt_w: Vec<f64> = bank.weights().iter().map(|w| w.sqrt()).collect();
    let mut phi = vec![0.0f64; xs.len() * n];
    for (li, x) in xs.iter().enumerate() {
        let a = frozen_normalizer(bank, x);
        for i in 0..n {
            let p = dot4(x, bank.omegas().row(i));
            phi[li * n + i] = (p - a).exp() * sqrt_w[i];
        }
    }
    phi
}

/// Pre-refactor `feature_matrix32`: f32 projection (dot8 over rounded
/// inputs and omegas), f64 normalizer/exp, f32 store.
fn frozen_feature_matrix32(bank: &FeatureBank, xs: &[Vec<f64>]) -> Vec<f32> {
    let (n, d) = (bank.n_features(), bank.dim());
    let sqrt_w: Vec<f64> = bank.weights().iter().map(|w| w.sqrt()).collect();
    let omegas32: Vec<f32> =
        bank.omegas().data().iter().map(|&x| x as f32).collect();
    let mut phi = vec![0.0f32; xs.len() * n];
    for (li, x) in xs.iter().enumerate() {
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let a = frozen_normalizer(bank, x);
        for i in 0..n {
            let p = dot8(&x32, &omegas32[i * d..(i + 1) * d]);
            phi[li * n + i] = ((p as f64 - a).exp() * sqrt_w[i]) as f32;
        }
    }
    phi
}

// ---------------------------------------------------------------------
// Frozen pre-refactor chunked causal forwards
// ---------------------------------------------------------------------

/// Pre-refactor f64 `CausalState::forward`: chunk blocking over an f64
/// state, tiled contractions replaced by their documented
/// bitwise-identical ascending-index forms. `phi_q`/`phi_k` are `l×n`
/// and `v` is `l×dv`, all flat row-major.
fn frozen_forward64(
    phi_q: &[f64],
    phi_k: &[f64],
    v: &[f64],
    l: usize,
    n: usize,
    dv: usize,
    chunk: usize,
) -> Vec<f64> {
    let chunk = chunk.max(1);
    let mut s = vec![0.0f64; n * dv];
    let mut z = vec![0.0f64; n];
    let mut out = vec![0.0f64; l * dv];
    let mut b = 0;
    while b < l {
        let e = (b + chunk).min(l);
        // Inter-chunk: out_c = Φ(Q_c)·S (ascending k per element, as the
        // tiled matmul accumulated), denom = Φ(Q_c)·z (sequential, as
        // `matvec` computed it).
        let mut denom = vec![0.0f64; e - b];
        for t in b..e {
            let qrow = &phi_q[t * n..(t + 1) * n];
            for c in 0..dv {
                let mut acc = 0.0f64;
                for (k, &q) in qrow.iter().enumerate() {
                    acc += q * s[k * dv + c];
                }
                out[t * dv + c] = acc;
            }
            denom[t - b] = qrow.iter().zip(&z).map(|(a, bb)| a * bb).sum();
        }
        // Intra-chunk masked gram: position t sees keys j ≤ t.
        for t in b..e {
            let qrow = &phi_q[t * n..(t + 1) * n];
            let mut acc = 0.0f64;
            for j in b..=t {
                let g = dot4(qrow, &phi_k[j * n..(j + 1) * n]);
                acc += g;
                for c in 0..dv {
                    out[t * dv + c] += g * v[j * dv + c];
                }
            }
            denom[t - b] += acc;
        }
        // State fold: the chunk summaries are completed first (ascending
        // row, from zero), then folded into the running state with one
        // addition each — `s += matmul_transa(...)`, `z += col_sums()`.
        let mut summary = vec![0.0f64; n * dv];
        let mut col_sums = vec![0.0f64; n];
        for r in b..e {
            let krow = &phi_k[r * n..(r + 1) * n];
            for (i, &a) in krow.iter().enumerate() {
                for c in 0..dv {
                    summary[i * dv + c] += a * v[r * dv + c];
                }
            }
            for (cs, &a) in col_sums.iter_mut().zip(krow) {
                *cs += a;
            }
        }
        for (si, &x) in s.iter_mut().zip(&summary) {
            *si += x;
        }
        for (zi, &x) in z.iter_mut().zip(&col_sums) {
            *zi += x;
        }
        // Normalize the chunk's rows.
        for t in b..e {
            let d = denom[t - b];
            for c in 0..dv {
                out[t * dv + c] /= d;
            }
        }
        b = e;
    }
    out
}

/// Pre-refactor f32 `CausalState32::forward`: f32 chunk-local compute,
/// f64 running `S`/`z` and denominators, state rounded to f32 once per
/// chunk, outputs normalized in f64 and stored f32.
fn frozen_forward32(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    l: usize,
    n: usize,
    dv: usize,
    chunk: usize,
) -> Vec<f32> {
    let chunk = chunk.max(1);
    let mut s = vec![0.0f64; n * dv];
    let mut z = vec![0.0f64; n];
    let mut out = vec![0.0f32; l * dv];
    let mut b = 0;
    while b < l {
        let e = (b + chunk).min(l);
        // One rounding of the running state per chunk.
        let s32: Vec<f32> = s.iter().map(|&x| x as f32).collect();
        let z32: Vec<f32> = z.iter().map(|&x| x as f32).collect();
        // Inter-chunk readout in f32 (ascending k, as the f32 tiled
        // matmul accumulated); denominators accumulate in f64 over the
        // rounded z.
        let mut denom = vec![0.0f64; e - b];
        for t in b..e {
            let qrow = &phi_q[t * n..(t + 1) * n];
            for c in 0..dv {
                let mut acc = 0.0f32;
                for (k, &q) in qrow.iter().enumerate() {
                    acc += q * s32[k * dv + c];
                }
                out[t * dv + c] = acc;
            }
            denom[t - b] = qrow
                .iter()
                .zip(&z32)
                .map(|(&a, &bb)| a as f64 * bb as f64)
                .sum();
        }
        // Intra-chunk masked gram in f32; per-row totals in f64.
        for t in b..e {
            let qrow = &phi_q[t * n..(t + 1) * n];
            let mut acc = 0.0f64;
            for j in b..=t {
                let g = dot8(qrow, &phi_k[j * n..(j + 1) * n]);
                acc += g as f64;
                for c in 0..dv {
                    out[t * dv + c] += g * v[j * dv + c];
                }
            }
            denom[t - b] += acc;
        }
        // Chunk summaries in f32 / col sums in f64 (both completed
        // first, ascending row), folded into the f64 state once.
        let mut summary = vec![0.0f32; n * dv];
        let mut col_sums = vec![0.0f64; n];
        for r in b..e {
            let krow = &phi_k[r * n..(r + 1) * n];
            for (i, &a) in krow.iter().enumerate() {
                for c in 0..dv {
                    summary[i * dv + c] += a * v[r * dv + c];
                }
            }
            for (cs, &a) in col_sums.iter_mut().zip(krow) {
                *cs += a as f64;
            }
        }
        for (si, &x) in s.iter_mut().zip(&summary) {
            *si += x as f64;
        }
        for (zi, &x) in z.iter_mut().zip(&col_sums) {
            *zi += x;
        }
        // Normalize in f64, store f32.
        for t in b..e {
            let d = denom[t - b];
            for c in 0..dv {
                out[t * dv + c] = (out[t * dv + c] as f64 / d) as f32;
            }
        }
        b = e;
    }
    out
}

// ---------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn estimators() -> Vec<(&'static str, PrfEstimator)> {
    let sigma = anisotropic_covariance(D, 0.7, 0.5, &mut Pcg64::seed(17));
    vec![
        ("isotropic", PrfEstimator::new(D, M, Sampling::Isotropic)),
        (
            "data_aware",
            PrfEstimator::new(
                D,
                M,
                Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
            ),
        ),
    ]
}

/// Run `body` twice: once on the forced-scalar fallback, once on the
/// detected ISA. The effective ISA is a process-global atomic, so the
/// pinned tests serialize on one poison-tolerant lock (an assert failure
/// under one mode must not wedge the other tests).
fn with_both_dispatch_modes(mut body: impl FnMut(&'static str)) {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let _guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    let prev = simd::set_isa(Isa::Scalar);
    body("scalar");
    simd::set_isa(simd::detected_isa());
    body("dispatched");
    simd::set_isa(prev);
}

fn head_inputs(n_heads: usize) -> Vec<Head> {
    let mut rng = Pcg64::seed(INPUT_SEED + n_heads as u64);
    (0..n_heads)
        .map(|_| Head {
            q: rows(L, D, 0.2, &mut rng),
            k: rows(L, D, 0.2, &mut rng),
            v: darkformer::linalg::Matrix::from_rows(&rows(
                L, DV, 1.0, &mut rng,
            )),
        })
        .collect()
}

#[test]
fn generic_f64_path_matches_frozen_pre_refactor_bitwise() {
    with_both_dispatch_modes(|dispatch| {
        for (mode, est) in estimators() {
            for n_heads in [1usize, 4] {
                let banks =
                    draw_head_banks(&est, n_heads, &mut Pcg64::seed(BANK_SEED));
                let heads = head_inputs(n_heads);
                for chunk in [1usize, 7, 64] {
                    let cfg = EngineConfig { chunk, threads: 1 };
                    let got = multi_head_causal_attention(&banks, &heads, &cfg);
                    for (h, (bank, head)) in
                        banks.iter().zip(&heads).enumerate()
                    {
                        let phi_q = frozen_feature_matrix64(bank, &head.q);
                        let phi_k = frozen_feature_matrix64(bank, &head.k);
                        let want = frozen_forward64(
                            &phi_q,
                            &phi_k,
                            head.v.data(),
                            L,
                            M,
                            DV,
                            chunk,
                        );
                        assert_eq!(
                            got[h].data(),
                            &want[..],
                            "{mode} heads={n_heads} chunk={chunk} head={h} \
                             ({dispatch} kernels): generic f64 path is not \
                             bitwise the pre-refactor f64 path"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn generic_f32_path_matches_frozen_pre_refactor_bitwise() {
    with_both_dispatch_modes(|dispatch| {
        for (mode, est) in estimators() {
            for n_heads in [1usize, 4] {
                let banks =
                    draw_head_banks(&est, n_heads, &mut Pcg64::seed(BANK_SEED));
                let heads = head_inputs(n_heads);
                for chunk in [1usize, 7, 64] {
                    let cfg = EngineConfig { chunk, threads: 1 };
                    let got =
                        multi_head_causal_attention32(&banks, &heads, &cfg);
                    for (h, (bank, head)) in
                        banks.iter().zip(&heads).enumerate()
                    {
                        let phi_q = frozen_feature_matrix32(bank, &head.q);
                        let phi_k = frozen_feature_matrix32(bank, &head.k);
                        // Pre-refactor head boundary: v rounded to f32.
                        let v32: Vec<f32> = head
                            .v
                            .data()
                            .iter()
                            .map(|&x| x as f32)
                            .collect();
                        let want = frozen_forward32(
                            &phi_q, &phi_k, &v32, L, M, DV, chunk,
                        );
                        assert_eq!(
                            got[h].data(),
                            &want[..],
                            "{mode} heads={n_heads} chunk={chunk} head={h} \
                             ({dispatch} kernels): generic f32 path is not \
                             bitwise the pre-refactor CausalState32 semantics"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn generic_feature_maps_match_frozen_pre_refactor_bitwise() {
    // The feature-map layer alone, both precisions: Mat<T> instantiations
    // vs the frozen `feature_matrix{,32}` bodies.
    with_both_dispatch_modes(|dispatch| {
        for (mode, est) in estimators() {
            let bank = FeatureBank::draw(&est, &mut Pcg64::seed(BANK_SEED));
            let xs = rows(33, D, 0.3, &mut Pcg64::seed(0xfea7));
            let phi64 = bank.feature_matrix(&xs);
            assert_eq!(
                phi64.data(),
                &frozen_feature_matrix64(&bank, &xs)[..],
                "{mode} ({dispatch} kernels): generic f64 feature map drifted"
            );
            let phi32 = bank.feature_matrix32(&xs);
            assert_eq!(
                phi32.data(),
                &frozen_feature_matrix32(&bank, &xs)[..],
                "{mode} ({dispatch} kernels): generic f32 feature map drifted"
            );
        }
    });
}
